"""Cross-process serving demo: the §4.1 expert-finding analysis, run by a
*client process* against a Ringo server it spawned.

Ringo's premise (§2.1) is many analysts sharing one big-memory machine.
``examples/stackoverflow_experts.py`` runs that workload in-process; this
example runs the *identical* workload body through the wire protocol:

    server process   python -m repro.serve.server        (spawned here)
        one GraphService: shared Workspace, admission control, fair-share
        scheduler, fusion + result cache
    this process     RemoteService -> RemoteSession      (serve/client.py)
        declarative requests as binary frames; results stream back with
        their provenance chains, so even `export_script` of a remotely
        computed table works locally

It finishes by asserting the remote run's expert scores match an in-process
run bit-for-bit, then asks the server to drain and exit.

Run:  PYTHONPATH=src python examples/remote_analytics.py
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from stackoverflow_experts import run_workload  # noqa: E402

from repro.serve.client import RemoteService  # noqa: E402
from repro.serve.graph_service import GraphService  # noqa: E402
from repro.serve.server import spawn_server  # noqa: E402


def main():
    proc, port = spawn_server(("--workers", "2"))
    print(f"spawned server pid={proc.pid} on port {port}")
    try:
        client = RemoteService(port=port)
        print(f"connected: conn={client.conn_id} "
              f"server_pid={client.server_pid} "
              f"(client pid={os.getpid()})")
        assert client.server_pid != os.getpid(), "not actually remote?!"

        # smaller dataset than the in-process demo: this example runs the
        # workload twice (wire + in-process) to prove equality
        S_remote = run_workload(
            client, n_questions=800,
            export_path="/tmp/remote_analytics_export.py")

        # same workload, in-process: scores must be identical
        S_local = run_workload(GraphService(), n_questions=800)
        np.testing.assert_array_equal(np.asarray(S_remote.column("Scr")),
                                      np.asarray(S_local.column("Scr")))
        np.testing.assert_array_equal(np.asarray(S_remote.column("User")),
                                      np.asarray(S_local.column("User")))
        print("remote scores == in-process scores ✓")

        client.shutdown_server()
        client.close()
        rc = proc.wait(timeout=120)
        print(f"server exited rc={rc}")
        assert rc == 0, "server did not shut down cleanly"
    finally:
        if proc.poll() is None:      # failure path: don't leak the server
            proc.kill()


if __name__ == "__main__":
    main()
