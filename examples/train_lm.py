"""End-to-end training driver (deliverable (b)): train a ~100M-param dense
LM for a few hundred steps on a graph-derived corpus, with checkpointing
and restart.

The corpus is DeepWalk-style random walks over an R-MAT graph produced by
the Ringo engine — the paper's tables->graph->results loop feeding the LM
substrate (DESIGN.md §4).

Run (fast demo):    PYTHONPATH=src python examples/train_lm.py
Run (full 100M):    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""

import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.graph import Graph
from repro.data.graph_corpus import RandomWalkCorpus
from repro.data.rmat import rmat_edges
from repro.checkpoint.store import (config_hash, latest_step, load_checkpoint,
                                    save_checkpoint)
from repro.train.optimizer import OptHyper
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slow on 1 CPU core)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # graph-derived corpus: random walks over an R-MAT graph
    s, d = rmat_edges(scale=12, edge_factor=8, seed=7)
    keep = s != d
    g = Graph.from_edges(s[keep], d[keep], dedupe=True)
    print(f"[corpus] walking {g}")
    vocab = g.n_nodes

    base = get_config("qwen2.5-3b")
    if args.full:
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab_size=vocab, head_dim=64, remat="none",
            param_dtype="float32", compute_dtype="float32")
    else:
        cfg = reduced(base, vocab_size=vocab)
    n_params = cfg.param_count()
    print(f"[model] {cfg.name}-family, ~{n_params/1e6:.1f}M params")

    corpus = RandomWalkCorpus(g, batch=args.batch, seq_len=args.seq, seed=0)
    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, OptHyper(lr=1e-3),
                                      attn_chunk=args.seq),
                      donate_argnums=(0, 1))

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_train_lm")
    start = 0
    if args.resume and latest_step(ckpt_dir) is not None:
        start, state, meta = load_checkpoint(ckpt_dir,
                                             {"p": params, "o": opt_state})
        assert meta["config"] == config_hash(cfg), "config changed"
        params, opt_state = state["p"], state["o"]
        print(f"[ckpt] resumed from step {start}")

    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch_at(i).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.int32(i))
        if (i + 1) % 10 == 0 or i == start:
            print(f"[train] step {i+1:4d}  loss {float(metrics['loss']):.4f}"
                  f"  |grad| {float(metrics['grad_norm']):.3f}")
        if (i + 1) % 50 == 0:
            save_checkpoint(ckpt_dir, i + 1, {"p": params, "o": opt_state},
                            meta={"config": config_hash(cfg)})
            print(f"[ckpt] saved step {i+1} -> {ckpt_dir}")
    print("[done] final loss should be well below ln(vocab) =",
          f"{np.log(vocab):.2f}")


if __name__ == "__main__":
    main()
