"""Quickstart: the Ringo loop — tables -> graph -> analytics -> tables.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.table import Table, INT
from repro.core import relational as R
from repro.core import algorithms as A
from repro.core.convert import to_graph, table_from_map, graph_to_edge_table


def main():
    # 1. load an edge table (any relational source; here synthetic follows)
    rng = np.random.default_rng(0)
    t = Table.from_columns(
        {"src": INT, "dst": INT, "weight": INT},
        {"src": rng.integers(0, 200, 2000),
         "dst": rng.integers(0, 200, 2000),
         "weight": rng.integers(1, 10, 2000)})
    print("edge table:", t)

    # 2. relational preprocessing: keep strong edges only
    strong = R.select(t, "weight", ">=", 5)
    print("after select:", strong)

    # 3. sort-first conversion to the graph object (paper §2.4)
    g = to_graph(strong, "src", "dst", drop_self_loops=True)
    print("graph:", g)

    # 4. graph analytics (paper Table 3/6 algorithms)
    pr = A.pagerank(g, n_iter=10)
    tri = A.triangle_count(g.to_undirected())
    comp = A.connected_components(g)
    print(f"triangles={tri}  components={len(set(np.asarray(comp).tolist()))}")

    # 5. results back to a table, top-ranked first (paper §4.1)
    ranked = table_from_map(g, pr, "node", "pagerank")
    top = ranked.to_pydict()
    print("top-5 nodes:", list(zip(top["node"][:5],
                                   [round(s, 5) for s in top["pagerank"][:5]])))

    # 6. and graphs convert back to edge tables (paper Table 5)
    print("round trip:", graph_to_edge_table(g))


if __name__ == "__main__":
    main()
