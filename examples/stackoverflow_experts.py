"""Paper §4.1 demo: find the top Java experts on StackOverflow — now run the
way the paper runs it: through the *interactive service* (§2.1), with every
derived object carrying provenance, and the finished analysis exported as a
standalone script (§4).

Mirrors the paper's Ringo commands on a synthetic StackOverflow (the real
dump isn't shipped in this container):

    P  = ringo.LoadTableTSV(schema, 'posts.tsv')
    JP = ringo.Select(P, 'Tag=Java')
    Q  = ringo.Select(JP, 'Type=question')
    A  = ringo.Select(JP, 'Type=answer')
    QA = ringo.Join(Q, A, 'AnswerId', 'PostId')
    G  = ringo.ToGraph(QA, 'UserId-1', 'UserId-2')
    PR = ringo.GetPageRank(G)
    S  = ringo.TableFromHashMap(PR, 'User', 'Scr')

Each command becomes a declarative request to :class:`GraphService`; repeated
queries hit the versioned result cache, concurrent single-source traversals
fuse into one vmapped engine call, and the final table's provenance chain is
exported with ``export_script`` and re-executed to verify identical scores.

The workload body (:func:`run_workload`) is transport-agnostic: it takes any
object mirroring the service surface (``.workspace``, ``.session``,
``.stats``), so the same code runs against the in-process
:class:`GraphService` (this file's ``main``) or a
:class:`repro.serve.client.RemoteService` speaking the wire protocol to a
separate server process (``examples/remote_analytics.py``) — the acceptance
bar for the cross-process subsystem is that both produce identical scores
and provenance.

Run:  PYTHONPATH=src python examples/stackoverflow_experts.py
"""

import numpy as np

from repro.core import algorithms as A
from repro.core import provenance
from repro.core.graph import EdgeDelta
from repro.core.table import Table, INT, STR
from repro.serve.graph_service import GraphService


def synthetic_stackoverflow(n_users=500, n_questions=3000, seed=0):
    """Questions + accepted answers; a few 'expert' users answer often."""
    rng = np.random.default_rng(seed)
    experts = rng.choice(n_users, 12, replace=False)
    post_id, ptype, tag, user, answer_id = [], [], [], [], []
    pid = 0
    for q in range(n_questions):
        qtag = rng.choice(["Java", "Python", "C++"], p=[0.5, 0.3, 0.2])
        asker = int(rng.integers(0, n_users))
        q_pid = pid
        post_id.append(q_pid); ptype.append("question"); tag.append(qtag)
        user.append(asker)
        # answer posts; the accepted one is linked from the question
        if rng.random() < 0.6:
            answerer = int(rng.choice(experts)) if rng.random() < 0.7 \
                else int(rng.integers(0, n_users))
            pid += 1
            post_id.append(pid); ptype.append("answer"); tag.append(qtag)
            user.append(answerer)
            answer_id.append(pid)       # question's accepted answer
        else:
            answer_id.append(-1)
        answer_id.extend([-1] * (pid - q_pid))  # answers have no AnswerId
        pid += 1
    return Table.from_columns(
        {"PostId": INT, "Type": STR, "Tag": STR, "UserId": INT,
         "AnswerId": INT},
        {"PostId": post_id, "Type": ptype, "Tag": tag, "UserId": user,
         "AnswerId": answer_id})


def run_workload(service, *, n_questions=3000,
                 export_path="/tmp/stackoverflow_experts_export.py"):
    """The paper's §4.1 command sequence against any service transport.

    Returns the final experts table; asserts the exported provenance script
    re-executes to identical scores along the way.
    """
    service.workspace.put("posts",                             # LoadTableTSV
                          synthetic_stackoverflow(n_questions=n_questions))
    sess = service.session("analyst")
    print("posts:", sess.get("posts"))

    sess.execute({"op": "select", "table": "posts",                # Tag=Java
                  "params": {"col": "Tag", "op": "==", "value": "Java"},
                  "as": "jp"})
    sess.execute({"op": "select", "table": "jp",                  # questions
                  "params": {"col": "Type", "op": "==", "value": "question"},
                  "as": "q"})
    sess.execute({"op": "select", "table": "jp",                  # answers
                  "params": {"col": "Type", "op": "==", "value": "answer"},
                  "as": "a"})
    sess.execute({"op": "join", "left": "q", "right": "a",        # accepted
                  "params": {"lcol": "AnswerId", "rcol": "PostId"},
                  "as": "qa"})
    print("QA pairs:", sess.get("qa"))
    # edge: asker -> accepted answerer
    sess.execute({"op": "to_graph", "table": "qa",                # ToGraph
                  "params": {"src_col": "UserId_1", "dst_col": "UserId_2"},
                  "as": "g"})
    sess.execute({"op": "pagerank", "graph": "g",                 # GetPageRank
                  "params": {"n_iter": 20}, "as": "pr"})
    S = sess.execute({"op": "table_from_map",            # TableFromHashMap
                      "graph": "g", "scores": "pr",
                      "params": {"key_name": "User", "value_name": "Scr"},
                      "as": "experts"})
    top = S.to_pydict()
    print("top Java experts (user, score):")
    for u, s in list(zip(top["User"], top["Scr"]))[:10]:
        print(f"  user {u:4d}  {s:.5f}")

    # trial-and-error is free: the re-issued query hits the result cache
    sess.execute({"op": "pagerank", "graph": "g", "params": {"n_iter": 20}})
    print("service stats after repeat query:", service.stats)

    # the paper's alternative metric: HITS authorities
    sess.execute({"op": "hits", "graph": "g", "params": {"n_iter": 20},
                  "as": "hits"})
    _, auth = sess.get("hits")
    sess.put("auth", auth)
    S2 = sess.execute({"op": "table_from_map", "graph": "g", "scores": "auth",
                       "params": {"key_name": "User",
                                  "value_name": "Authority"}})
    print("top by HITS authority:", S2.to_pydict()["User"][:10])

    # §4: export the whole analysis as a standalone runnable script, then
    # re-execute it and verify the PageRank scores are identical.  This
    # works even when S was computed in another process: results adopt
    # their provenance chains across the wire, and the posts root the
    # client put() is bound to its server-assigned version token.
    script = provenance.export_script(S)
    with open(export_path, "w") as f:
        f.write(script)
    print(f"exported provenance script ({len(script.splitlines())} lines) "
          f"-> {export_path}")
    ns = {}
    exec(compile(script, export_path, "exec"), ns)
    S_rebuilt = ns["rebuild"]()
    np.testing.assert_array_equal(S_rebuilt.column_np("Scr"),
                                  np.asarray(S.column("Scr")))
    np.testing.assert_array_equal(S_rebuilt.column_np("User"),
                                  np.asarray(S.column("User")))
    print("re-executed export: PageRank scores identical ✓")

    # §2.3 dynamism: a fresh batch of accepted answers lands while the
    # analyst is still looking at the ranking.  ``apply_delta`` is the one
    # functional update with a wire form, so this epilogue runs unchanged
    # over the remote transport: the service patches the CSR instead of
    # rebuilding, and the re-issued ranking warm-starts from the previous
    # vector instead of solving from scratch.
    sess.execute({"op": "pagerank", "graph": "g",       # converged baseline
                  "params": {"tol": 1e-6}, "as": "pr_live"})
    sess.publish("g")                  # updates are workspace-level
    g_now = service.workspace.get("g")
    ids = np.asarray(g_now.node_ids)[:g_now.n_nodes]
    rng = np.random.default_rng(1)
    new_edges = EdgeDelta.inserts(ids[rng.integers(0, len(ids), 16)],
                                  ids[rng.integers(0, len(ids), 16)])
    service.workspace.apply_delta("g", new_edges)
    refreshed = sess.execute({"op": "pagerank", "graph": "g",
                              "params": {"tol": 1e-6}})
    assert service.stats["warm_starts"] >= 1, \
        "refresh did not warm-start from the parent vector"
    cold = A.pagerank(service.workspace.get("g"), tol=1e-6)
    np.testing.assert_allclose(np.asarray(refreshed), np.asarray(cold),
                               atol=1e-5)
    print(f"live update: +{new_edges.n_adds} answer edges, ranking "
          f"refreshed warm (warm_starts="
          f"{service.stats['warm_starts']}) == cold recompute ✓")
    return S


def main():
    run_workload(GraphService())


if __name__ == "__main__":
    main()
