"""Paper §4.1 demo: find the top Java experts on StackOverflow.

Mirrors the paper's Ringo commands line-for-line on a synthetic StackOverflow
(the real dump isn't shipped in this container):

    P  = ringo.LoadTableTSV(schema, 'posts.tsv')
    JP = ringo.Select(P, 'Tag=Java')
    Q  = ringo.Select(JP, 'Type=question')
    A  = ringo.Select(JP, 'Type=answer')
    QA = ringo.Join(Q, A, 'AnswerId', 'PostId')
    G  = ringo.ToGraph(QA, 'UserId-1', 'UserId-2')
    PR = ringo.GetPageRank(G)
    S  = ringo.TableFromHashMap(PR, 'User', 'Scr')

Run:  PYTHONPATH=src python examples/stackoverflow_experts.py
"""

import numpy as np

from repro.core.table import Table, INT, STR
from repro.core import relational as R
from repro.core import algorithms as A
from repro.core.convert import to_graph, table_from_map


def synthetic_stackoverflow(n_users=500, n_questions=3000, seed=0):
    """Questions + accepted answers; a few 'expert' users answer often."""
    rng = np.random.default_rng(seed)
    experts = rng.choice(n_users, 12, replace=False)
    post_id, ptype, tag, user, answer_id = [], [], [], [], []
    pid = 0
    for q in range(n_questions):
        qtag = rng.choice(["Java", "Python", "C++"], p=[0.5, 0.3, 0.2])
        asker = int(rng.integers(0, n_users))
        q_pid = pid
        post_id.append(q_pid); ptype.append("question"); tag.append(qtag)
        user.append(asker)
        # answer posts; the accepted one is linked from the question
        if rng.random() < 0.6:
            answerer = int(rng.choice(experts)) if rng.random() < 0.7 \
                else int(rng.integers(0, n_users))
            pid += 1
            post_id.append(pid); ptype.append("answer"); tag.append(qtag)
            user.append(answerer)
            answer_id.append(pid)       # question's accepted answer
        else:
            answer_id.append(-1)
        answer_id.extend([-1] * (pid - q_pid))  # answers have no AnswerId
        pid += 1
    return Table.from_columns(
        {"PostId": INT, "Type": STR, "Tag": STR, "UserId": INT,
         "AnswerId": INT},
        {"PostId": post_id, "Type": ptype, "Tag": tag, "UserId": user,
         "AnswerId": answer_id})


def main():
    P = synthetic_stackoverflow()                      # LoadTableTSV
    print("posts:", P)
    JP = R.select(P, "Tag", "==", "Java")              # Select Tag=Java
    Q = R.select(JP, "Type", "==", "question")         # Select questions
    Ans = R.select(JP, "Type", "==", "answer")         # Select answers
    QA = R.join(Q, Ans, "AnswerId", "PostId")          # Join on accepted
    print("QA pairs:", QA)
    # edge: asker -> accepted answerer
    G = to_graph(QA, "UserId_1", "UserId_2")           # ToGraph
    PR = A.pagerank(G, n_iter=20)                      # GetPageRank
    S = table_from_map(G, PR, "User", "Scr")           # TableFromHashMap
    top = S.to_pydict()
    print("top Java experts (user, score):")
    for u, s in list(zip(top["User"], top["Scr"]))[:10]:
        print(f"  user {u:4d}  {s:.5f}")

    # the paper's alternative metric: HITS authorities
    hub, auth = A.hits(G, n_iter=20)
    S2 = table_from_map(G, auth, "User", "Authority")
    print("top by HITS authority:", S2.to_pydict()["User"][:10])


if __name__ == "__main__":
    main()
