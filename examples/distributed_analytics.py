"""Distributed graph analytics on a multi-device mesh (the pod story,
scaled to host devices).

Must run with placeholder devices (this is the ONLY example that needs the
flag — set it before python starts):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_analytics.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import numpy as np                      # noqa: E402
import jax                              # noqa: E402
import jax.numpy as jnp                 # noqa: E402

from repro.core.graph import Graph      # noqa: E402
from repro.core import algorithms as A  # noqa: E402
from repro.core.distributed import (    # noqa: E402
    make_graph_mesh, shard_graph, pagerank_distributed,
    distributed_to_graph, triangle_count_distributed,
    shard_graph_2d, pagerank_distributed_2d)
from repro.data.rmat import rmat_edges  # noqa: E402


def main():
    print("devices:", len(jax.devices()))
    s, d = rmat_edges(scale=11, edge_factor=8, seed=2)
    keep = s != d
    g = Graph.from_edges(s[keep], d[keep], dedupe=True)
    print("graph:", g)

    # 1D engine: the pod as one big-memory machine
    mesh = make_graph_mesh()
    dg = shard_graph(g, mesh)
    pr = pagerank_distributed(dg, mesh, n_iter=10)
    pr_ref = A.pagerank(g, n_iter=10)
    print(f"1D pagerank max err vs local: "
          f"{float(jnp.abs(pr - pr_ref).max()):.2e}")

    # distributed sort-first conversion (paper §2.4 over ICI)
    sd, dd = g.out_edges()
    dg2 = distributed_to_graph(sd, dd, g.n_nodes, mesh)
    pr2 = pagerank_distributed(dg2, mesh, n_iter=10)
    print(f"distributed-conversion pagerank err: "
          f"{float(jnp.abs(pr2 - pr_ref).max()):.2e}")

    # distributed triangles
    u = g.to_undirected()
    t_d = triangle_count_distributed(u, mesh, edge_chunk=2048)
    print(f"triangles: distributed={t_d} local={A.triangle_count(u)}")

    # 2D SUMMA partition (the §Perf optimization): square sub-grid
    mesh2 = jax.make_mesh((2, 2), ("data", "model"),
                          devices=np.asarray(jax.devices()[:4]))
    dg3 = shard_graph_2d(g, mesh2)
    pr3 = pagerank_distributed_2d(dg3, mesh2, n_iter=10)
    print(f"2D pagerank err: {float(jnp.abs(pr3 - pr_ref).max()):.2e} "
          f"(collectives Θ(N/√P) vs Θ(N))")


if __name__ == "__main__":
    main()
