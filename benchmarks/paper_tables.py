"""Benchmarks mirroring every Ringo table (paper §3), CPU-scaled.

The paper's machine is an 80-hyperthread 1 TB box on LiveJournal (69 M
edges) and Twitter2010 (1.5 B edges); this container is one CPU core, so
each benchmark runs an R-MAT graph / synthetic table sized to finish in
seconds and reports the same **rates** the paper reports (rows/s, edges/s)
next to the paper's numbers for context.  The absolute comparison lives in
EXPERIMENTS.md; the dry-run cells cover pod-scale structure.

Tables:
  2 — memory footprint of graph vs table objects
  3 — parallel PageRank + triangle counting
  4 — select / join rates
  5 — table↔graph conversion rates
  6 — "sequential" 3-core / SSSP / SCC
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.table import Table, INT, FLOAT
from repro.core import algorithms as A
from repro.core import relational as R
from repro.core.convert import graph_to_edge_table, to_graph
from repro.data.rmat import rmat_edges

RESULTS: List[Tuple[str, float, str]] = []


def timed(name: str, fn: Callable, derived: Callable[[float], str] = None,
          repeat: int = 3):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, jax.Array) else None
        best = min(best, time.perf_counter() - t0)
    extra = derived(best) if derived else ""
    RESULTS.append((name, best * 1e6, extra))
    return out


def _bench_graph(scale: int = 14, edge_factor: int = 16):
    s, d = rmat_edges(scale=scale, edge_factor=edge_factor, seed=1)
    keep = s != d
    return Graph.from_edges(s[keep], d[keep], dedupe=True)


def table2_memory() -> None:
    g = _bench_graph()
    et = graph_to_edge_table(g)
    RESULTS.append(("table2.graph_bytes_per_edge",
                    g.nbytes() / max(g.n_edges, 1) * 1e6 / 1e6,
                    f"bytes/edge={g.nbytes()/max(g.n_edges,1):.1f} "
                    f"(paper: ~9.4 LiveJournal graph)"))
    RESULTS.append(("table2.table_bytes_per_row",
                    et.nbytes() / max(len(et), 1) * 1e6 / 1e6,
                    f"bytes/row={et.nbytes()/max(len(et),1):.1f} "
                    f"(paper: ~16 LiveJournal table)"))


def table3_algorithms() -> None:
    g = _bench_graph()
    e = g.n_edges
    timed("table3.pagerank_10it", lambda: A.pagerank(g, n_iter=10),
          lambda t: f"{10*e/t/1e6:.1f} Medge-iter/s "
                    f"(paper LJ: {10*69e6/2.76/1e6:.0f})")
    u = g.to_undirected()
    timed("table3.triangles", lambda: jnp.asarray(A.triangle_count(u)),
          lambda t: f"{u.n_edges/t/1e6:.2f} Medges/s "
                    f"(paper LJ: {69e6/6.13/1e6:.1f})", repeat=1)


def table4_tables(n_rows: int = 1_000_000) -> None:
    rng = np.random.default_rng(0)
    t = Table.from_columns({"k": INT, "v": FLOAT},
                           {"k": rng.integers(0, 1 << 30, n_rows),
                            "v": rng.normal(size=n_rows)})
    pivot = int(np.sort(t.column_np("k"))[10_000])
    timed("table4.select_10k", lambda: R.select(t, "k", "<", pivot),
          lambda tm: f"{n_rows/tm/1e6:.1f} Mrows/s (paper LJ: 405.9)")
    timed("table4.select_all_minus_10k", lambda: R.select(t, "k", ">=", pivot),
          lambda tm: f"{n_rows/tm/1e6:.1f} Mrows/s (paper LJ: 575.0)")
    keys_small = Table.from_columns(
        {"k": INT}, {"k": rng.choice(t.column_np("k"), 10_000, replace=False)})
    timed("table4.join_10k", lambda: R.join(t, keys_small, "k", "k"),
          lambda tm: f"{(n_rows+10_000)/tm/1e6:.1f} Mrows/s (paper LJ: 109.5)")


def table5_conversions() -> None:
    g = _bench_graph()
    et = graph_to_edge_table(g)
    e = g.n_edges
    timed("table5.table_to_graph", lambda: to_graph(et, "src", "dst",
                                                    dedupe=False),
          lambda t: f"{e/t/1e6:.2f} Medges/s (paper LJ: 13.0)", repeat=1)
    timed("table5.graph_to_table", lambda: graph_to_edge_table(g),
          lambda t: f"{e/t/1e6:.2f} Medges/s (paper LJ: 46.0)")


def table6_sequential() -> None:
    g = _bench_graph(scale=13)
    timed("table6.3core", lambda: A.k_core(g, 3),
          lambda t: f"n={g.n_nodes} e={g.n_edges} (paper LJ: 31.0s)",
          repeat=1)
    timed("table6.sssp", lambda: A.sssp(g, 0),
          lambda t: f"(paper LJ: 7.4s)", repeat=1)
    timed("table6.scc", lambda: A.strongly_connected_components(g),
          lambda t: f"(paper LJ: 18.0s)", repeat=1)


def run_all() -> List[Tuple[str, float, str]]:
    table2_memory()
    table3_algorithms()
    table4_tables()
    table5_conversions()
    table6_sequential()
    return RESULTS
