"""Engine smoke benchmark — per-backend PageRank latency → BENCH_engine.json.

Runs PageRank through the unified traversal engine on an RMAT graph (default
2^16 nodes, the paper-table scale knob) once per backend and records wall
time plus the one-off plan build cost, so the perf trajectory of the
plan/engine substrate is tracked across PRs.

Also records dense-vs-frontier BFS latency on a 2^15-node RMAT graph (from
the max-out-degree source, so the traversal actually covers the giant
component): the "bfs" block carries ``dense_ms`` / ``frontier_ms`` /
``speedup`` and ``ci_check.sh`` gates frontier >= 1.5x dense.

The "delta" block measures incremental maintenance on a 0.1% edge delta at
the same scale: plan patching vs full re-derivation, warm-started
tol-stopped pagerank vs cold, and frontier re-seeded BFS vs cold.
``ci_check.sh`` gates ``plan_patch_speedup`` >= 5x and
``warm_pagerank_speedup`` >= 2x — both ratios of same-host wall times, so
the gates are hardware-independent.

The Pallas/BSR backends execute in interpret mode off-TPU, which is a
correctness emulation, not a speed path — on non-TPU hosts they are measured
at a reduced scale (recorded in the JSON) to keep the smoke run fast.
"""

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.core import algorithms as A
from repro.core.graph import EdgeDelta, Graph
from repro.data.rmat import rmat_edges


def _sync_plan(plan):
    jax.block_until_ready((plan.in_src, plan.in_dst, plan.out_src,
                           plan.out_dst, plan.inv_out_deg))


def bench_backend(backend: str, scale: int, edge_factor: int, n_iter: int,
                  repeats: int) -> dict:
    src, dst = rmat_edges(scale, edge_factor=edge_factor, seed=0)
    # shape warm-up: an identically-shaped throwaway graph pays the
    # per-shape op-compile cost, so plan_build_ms measures per-graph work
    _sync_plan(Graph.from_edges(src, dst).plan())
    g = Graph.from_edges(src, dst)
    t0 = time.perf_counter()
    plan = g.plan()
    _sync_plan(plan)
    plan_ms = (time.perf_counter() - t0) * 1e3
    # warmup: jit compile + lazy plan structures (BSR tiles / chunk layouts)
    A.pagerank(g, n_iter=n_iter, backend=backend).block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        A.pagerank(g, n_iter=n_iter, backend=backend).block_until_ready()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return {"scale": scale, "n_nodes": g.n_nodes, "n_edges": g.n_edges,
            "n_iter": n_iter, "plan_build_ms": round(plan_ms, 3),
            "pagerank_ms": round(best, 3)}


def bench_bfs(scale: int, edge_factor: int, repeats: int) -> dict:
    """Dense Bellman-Ford vs frontier-sparse BFS on one RMAT graph."""
    src, dst = rmat_edges(scale, edge_factor=edge_factor, seed=0)
    g = Graph.from_edges(src, dst)
    source = int(np.argmax(np.asarray(g.plan().out_deg)))

    def best(backend):
        A.bfs(g, source, backend=backend).block_until_ready()   # warm/trace
        b = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            A.bfs(g, source, backend=backend).block_until_ready()
            b = min(b, (time.perf_counter() - t0) * 1e3)
        return b

    dense_ms = best("xla")
    frontier_ms = best("frontier")
    levels = np.asarray(A.bfs(g, source, backend="frontier"))
    return {"scale": scale, "n_nodes": g.n_nodes, "n_edges": g.n_edges,
            "source": source, "reached": int((levels >= 0).sum()),
            "dense_ms": round(dense_ms, 3),
            "frontier_ms": round(frontier_ms, 3),
            "speedup": round(dense_ms / frontier_ms, 3)}


def bench_delta(scale: int, edge_factor: int, repeats: int,
                frac: float = 0.001, tol: float = 1e-6) -> dict:
    """Incremental maintenance vs from-scratch on a small (``frac``) delta.

    Three hardware-independent ratios on one RMAT graph:

    * ``plan_patch_speedup`` — ``apply_delta`` + patched plan build vs
      ``add_edges`` + full plan re-derivation (same resulting CSR);
    * ``warm_pagerank_speedup`` — end-to-end refreshed pagerank after the
      delta: incremental (``apply_delta`` + patched plan + tol-stopped
      solve warm-started from the parent vector) vs from-scratch
      (``add_edges`` + re-derived plan + cold solve), both converging to
      the same tolerance.  Solver-only times are recorded alongside as
      ``cold_solve_ms`` / ``warm_solve_ms`` — on fast-mixing RMAT graphs
      the solver alone converges in a handful of iterations either way, so
      the interactive win lives in maintenance + solve, which is what an
      analyst waiting on a refreshed ranking actually pays;
    * ``bfs_reseed_speedup`` — frontier re-seeded BFS from the parent levels
      vs a cold traversal (bit-identical results, asserted).
    """
    src, dst = rmat_edges(scale, edge_factor=edge_factor, seed=0)
    g = Graph.from_edges(src, dst)
    _sync_plan(g.plan())
    ids = np.asarray(g.node_ids)[:g.n_nodes]
    rng = np.random.default_rng(7)
    n_delta = max(1, int(g.n_edges * frac))
    add_s = ids[rng.integers(0, g.n_nodes, n_delta)].astype(np.int32)
    add_d = ids[rng.integers(0, g.n_nodes, n_delta)].astype(np.int32)
    delta = EdgeDelta.inserts(add_s, add_d)

    def best(fn):
        fn()                                     # shape/trace warm-up
        b = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            b = min(b, (time.perf_counter() - t0) * 1e3)
        return b

    # plan maintenance: patch (delta merge into the parent's sorted arrays)
    # vs re-derive (full device sort of the grown edge list).  A fresh child
    # every run — the plan is identity-memoized per graph.
    patch_ms = best(lambda: _sync_plan(g.apply_delta(delta).plan()))
    rederive_ms = best(lambda: _sync_plan(g.add_edges(add_s, add_d).plan()))

    child = g.apply_delta(delta)
    assert child._delta is not None, "delta fast path did not engage"
    _sync_plan(child.plan())

    parent_pr = A.pagerank(g, tol=tol).block_until_ready()
    cold_solve_ms = best(
        lambda: A.pagerank(child, tol=tol).block_until_ready())
    warm_solve_ms = best(
        lambda: A.pagerank(child, tol=tol,
                           init=parent_pr).block_until_ready())
    # end-to-end refresh: what a session waits for after publishing the
    # delta — graph + plan maintenance and the solve, on a fresh child
    # every run (plan and graph caches are identity-memoized)
    cold_refresh_ms = best(lambda: A.pagerank(
        g.add_edges(add_s, add_d), tol=tol).block_until_ready())
    warm_refresh_ms = best(lambda: A.pagerank(
        g.apply_delta(delta), tol=tol, init=parent_pr).block_until_ready())

    source = int(np.argmax(np.asarray(g.plan().out_deg)))
    parent_bfs = A.bfs(g, source).block_until_ready()
    cold_bfs_ms = best(lambda: A.bfs(child, source).block_until_ready())
    warm_bfs = A.incremental_bfs(child, source, parent_bfs)
    assert warm_bfs is not None, "incremental bfs fell back"
    if not np.array_equal(np.asarray(warm_bfs),
                          np.asarray(A.bfs(child, source))):
        raise AssertionError("incremental bfs diverged from cold run")
    warm_bfs_ms = best(lambda: jax.block_until_ready(
        A.incremental_bfs(child, source, parent_bfs)))

    return {"scale": scale, "n_nodes": g.n_nodes, "n_edges": g.n_edges,
            "n_delta_edges": int(n_delta), "tol": tol,
            "plan_patch_ms": round(patch_ms, 3),
            "plan_rederive_ms": round(rederive_ms, 3),
            "plan_patch_speedup": round(rederive_ms / patch_ms, 3),
            "cold_solve_ms": round(cold_solve_ms, 3),
            "warm_solve_ms": round(warm_solve_ms, 3),
            "warm_solve_speedup": round(cold_solve_ms / warm_solve_ms, 3),
            "cold_pagerank_ms": round(cold_refresh_ms, 3),
            "warm_pagerank_ms": round(warm_refresh_ms, 3),
            "warm_pagerank_speedup":
                round(cold_refresh_ms / warm_refresh_ms, 3),
            "cold_bfs_ms": round(cold_bfs_ms, 3),
            "warm_bfs_ms": round(warm_bfs_ms, 3),
            "bfs_reseed_speedup": round(cold_bfs_ms / warm_bfs_ms, 3)}


def bench_sharded(scale: int, edge_factor: int, n_iter: int, repeats: int,
                  n_shards: int) -> dict:
    """PageRank + BFS through the ``"sharded"`` backend at one shard count.

    Needs ``len(jax.devices()) >= n_shards`` — the device count is fixed at
    the first jax import, so the multi-device leg is spawned as a subprocess
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` when the
    ambient session is smaller (see ``_sharded_leg``).  Also records the
    halo-exchange volume per round, the hardware-independent number that
    tells you what a real multi-host mesh would put on the wire.
    """
    os.environ["REPRO_SHARD_COUNT"] = str(n_shards)
    try:
        src, dst = rmat_edges(scale, edge_factor=edge_factor, seed=0)
        g = Graph.from_edges(src, dst)
        plan = g.plan()
        _sync_plan(plan)
        t0 = time.perf_counter()
        sp = plan.sharded(n_shards)
        jax.block_until_ready((sp.pull.gather_idx, sp.push.gather_idx))
        shard_plan_ms = (time.perf_counter() - t0) * 1e3

        def best(fn):
            fn()                                 # trace/compile warm-up
            b = float("inf")
            for _ in range(repeats):
                t1 = time.perf_counter()
                fn()
                b = min(b, (time.perf_counter() - t1) * 1e3)
            return b

        pr_ms = best(lambda: A.pagerank(g, n_iter=n_iter,
                                        backend="sharded").block_until_ready())
        source = int(np.argmax(np.asarray(plan.out_deg)))
        bfs_ms = best(lambda: A.bfs(g, source,
                                    backend="sharded").block_until_ready())
        # the leg is only worth timing if it honours the bitwise contract
        np.testing.assert_array_equal(
            np.asarray(A.pagerank(g, n_iter=n_iter, backend="sharded")),
            np.asarray(A.pagerank(g, n_iter=n_iter, backend="xla")))
        return {"devices": n_shards, "scale": scale, "n_nodes": g.n_nodes,
                "n_edges": g.n_edges, "n_iter": n_iter,
                "shard_plan_build_ms": round(shard_plan_ms, 3),
                "pagerank_ms": round(pr_ms, 3), "bfs_ms": round(bfs_ms, 3),
                "halo_bytes_per_round": int(sp.halo_bytes_per_round())}
    finally:
        os.environ.pop("REPRO_SHARD_COUNT", None)


def _sharded_leg(n_shards: int, args) -> dict:
    """Run one sharded leg, in-process when the devices exist, else in a
    subprocess that raises the simulated host device count first."""
    if len(jax.devices()) >= n_shards:
        return bench_sharded(args.bfs_scale, args.edge_factor, args.n_iter,
                             args.repeats, n_shards)
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_shards}")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__),
         "--sharded-leg", str(n_shards), "--scale", str(args.scale),
         "--bfs-scale", str(args.bfs_scale),
         "--edge-factor", str(args.edge_factor),
         "--n-iter", str(args.n_iter), "--repeats", str(args.repeats)],
        capture_output=True, text=True, timeout=1800, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(f"sharded leg d={n_shards} failed:\n"
                           f"{proc.stdout}\n{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scale", type=int, default=16,
                   help="log2 nodes for the native backend run")
    p.add_argument("--interp-scale", type=int, default=9,
                   help="log2 nodes for interpret-mode backends off-TPU")
    p.add_argument("--bfs-scale", type=int, default=15,
                   help="log2 nodes for the dense-vs-frontier BFS gate")
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--n-iter", type=int, default=10)
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--out", default="BENCH_engine.json")
    p.add_argument("--sharded-leg", type=int, default=0,
                   help="internal: run ONE sharded leg at this shard count "
                        "and print its JSON block (used by the subprocess "
                        "re-entry that raises the simulated device count)")
    args = p.parse_args()

    if args.sharded_leg:
        print(json.dumps(bench_sharded(args.bfs_scale, args.edge_factor,
                                       args.n_iter, args.repeats,
                                       args.sharded_leg)))
        return

    on_tpu = jax.default_backend() == "tpu"
    scales = {"xla": args.scale,
              "pallas": args.scale if on_tpu else args.interp_scale,
              "bsr": args.scale if on_tpu else args.interp_scale}
    results = {"device": jax.default_backend(), "backends": {}}
    for backend, scale in scales.items():
        r = bench_backend(backend, scale, args.edge_factor, args.n_iter,
                          args.repeats)
        r["interpret_mode"] = not on_tpu and backend != "xla"
        results["backends"][backend] = r
        print(f"{backend:7s} scale={scale:2d} plan={r['plan_build_ms']:9.2f}ms"
              f" pagerank={r['pagerank_ms']:9.2f}ms"
              f"{'  (interpret)' if r['interpret_mode'] else ''}")

    results["bfs"] = bench_bfs(args.bfs_scale, args.edge_factor, args.repeats)
    b = results["bfs"]
    print(f"bfs     scale={b['scale']:2d} dense={b['dense_ms']:9.2f}ms"
          f" frontier={b['frontier_ms']:9.2f}ms speedup={b['speedup']:.2f}x")

    results["delta"] = bench_delta(args.bfs_scale, args.edge_factor,
                                   args.repeats)
    d = results["delta"]
    print(f"delta   scale={d['scale']:2d} ({d['n_delta_edges']} edges)"
          f" plan patch={d['plan_patch_ms']:.2f}ms vs"
          f" rederive={d['plan_rederive_ms']:.2f}ms"
          f" ({d['plan_patch_speedup']:.1f}x);"
          f" pagerank warm={d['warm_pagerank_ms']:.2f}ms vs"
          f" cold={d['cold_pagerank_ms']:.2f}ms"
          f" ({d['warm_pagerank_speedup']:.1f}x);"
          f" bfs reseed {d['bfs_reseed_speedup']:.1f}x")

    # sharded backend: 1 vs 8 simulated devices.  Absolute times are
    # info-only — the 8 "devices" share one CPU, so the dense (replicated)
    # portion of every round runs 8x over; the portable numbers here are
    # halo_bytes_per_round and the bitwise-identity assert inside each leg.
    leg1 = bench_sharded(args.bfs_scale, args.edge_factor, args.n_iter,
                         args.repeats, 1)
    leg8 = _sharded_leg(8, args)
    results["sharded"] = {
        "scale": args.bfs_scale, "legs": {"1": leg1, "8": leg8},
        "pagerank_ratio_8v1":
            round(leg1["pagerank_ms"] / leg8["pagerank_ms"], 3),
        "bfs_ratio_8v1": round(leg1["bfs_ms"] / leg8["bfs_ms"], 3)}
    for leg in (leg1, leg8):
        print(f"sharded d={leg['devices']} scale={leg['scale']:2d}"
              f" pagerank={leg['pagerank_ms']:9.2f}ms"
              f" bfs={leg['bfs_ms']:9.2f}ms"
              f" halo={leg['halo_bytes_per_round']}B/round")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
