"""Engine smoke benchmark — per-backend PageRank latency → BENCH_engine.json.

Runs PageRank through the unified traversal engine on an RMAT graph (default
2^16 nodes, the paper-table scale knob) once per backend and records wall
time plus the one-off plan build cost, so the perf trajectory of the
plan/engine substrate is tracked across PRs.

Also records dense-vs-frontier BFS latency on a 2^15-node RMAT graph (from
the max-out-degree source, so the traversal actually covers the giant
component): the "bfs" block carries ``dense_ms`` / ``frontier_ms`` /
``speedup`` and ``ci_check.sh`` gates frontier >= 1.5x dense.

The Pallas/BSR backends execute in interpret mode off-TPU, which is a
correctness emulation, not a speed path — on non-TPU hosts they are measured
at a reduced scale (recorded in the JSON) to keep the smoke run fast.
"""

import argparse
import json
import time

import jax
import numpy as np

from repro.core import algorithms as A
from repro.core.graph import Graph
from repro.data.rmat import rmat_edges


def _sync_plan(plan):
    jax.block_until_ready((plan.in_src, plan.in_dst, plan.out_src,
                           plan.out_dst, plan.inv_out_deg))


def bench_backend(backend: str, scale: int, edge_factor: int, n_iter: int,
                  repeats: int) -> dict:
    src, dst = rmat_edges(scale, edge_factor=edge_factor, seed=0)
    # shape warm-up: an identically-shaped throwaway graph pays the
    # per-shape op-compile cost, so plan_build_ms measures per-graph work
    _sync_plan(Graph.from_edges(src, dst).plan())
    g = Graph.from_edges(src, dst)
    t0 = time.perf_counter()
    plan = g.plan()
    _sync_plan(plan)
    plan_ms = (time.perf_counter() - t0) * 1e3
    # warmup: jit compile + lazy plan structures (BSR tiles / chunk layouts)
    A.pagerank(g, n_iter=n_iter, backend=backend).block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        A.pagerank(g, n_iter=n_iter, backend=backend).block_until_ready()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return {"scale": scale, "n_nodes": g.n_nodes, "n_edges": g.n_edges,
            "n_iter": n_iter, "plan_build_ms": round(plan_ms, 3),
            "pagerank_ms": round(best, 3)}


def bench_bfs(scale: int, edge_factor: int, repeats: int) -> dict:
    """Dense Bellman-Ford vs frontier-sparse BFS on one RMAT graph."""
    src, dst = rmat_edges(scale, edge_factor=edge_factor, seed=0)
    g = Graph.from_edges(src, dst)
    source = int(np.argmax(np.asarray(g.plan().out_deg)))

    def best(backend):
        A.bfs(g, source, backend=backend).block_until_ready()   # warm/trace
        b = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            A.bfs(g, source, backend=backend).block_until_ready()
            b = min(b, (time.perf_counter() - t0) * 1e3)
        return b

    dense_ms = best("xla")
    frontier_ms = best("frontier")
    levels = np.asarray(A.bfs(g, source, backend="frontier"))
    return {"scale": scale, "n_nodes": g.n_nodes, "n_edges": g.n_edges,
            "source": source, "reached": int((levels >= 0).sum()),
            "dense_ms": round(dense_ms, 3),
            "frontier_ms": round(frontier_ms, 3),
            "speedup": round(dense_ms / frontier_ms, 3)}


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scale", type=int, default=16,
                   help="log2 nodes for the native backend run")
    p.add_argument("--interp-scale", type=int, default=9,
                   help="log2 nodes for interpret-mode backends off-TPU")
    p.add_argument("--bfs-scale", type=int, default=15,
                   help="log2 nodes for the dense-vs-frontier BFS gate")
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--n-iter", type=int, default=10)
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--out", default="BENCH_engine.json")
    args = p.parse_args()

    on_tpu = jax.default_backend() == "tpu"
    scales = {"xla": args.scale,
              "pallas": args.scale if on_tpu else args.interp_scale,
              "bsr": args.scale if on_tpu else args.interp_scale}
    results = {"device": jax.default_backend(), "backends": {}}
    for backend, scale in scales.items():
        r = bench_backend(backend, scale, args.edge_factor, args.n_iter,
                          args.repeats)
        r["interpret_mode"] = not on_tpu and backend != "xla"
        results["backends"][backend] = r
        print(f"{backend:7s} scale={scale:2d} plan={r['plan_build_ms']:9.2f}ms"
              f" pagerank={r['pagerank_ms']:9.2f}ms"
              f"{'  (interpret)' if r['interpret_mode'] else ''}")

    results["bfs"] = bench_bfs(args.bfs_scale, args.edge_factor, args.repeats)
    b = results["bfs"]
    print(f"bfs     scale={b['scale']:2d} dense={b['dense_ms']:9.2f}ms"
          f" frontier={b['frontier_ms']:9.2f}ms speedup={b['speedup']:.2f}x")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
