"""CI smoke for cross-process serving: spawn a real server subprocess on an
ephemeral port, run a scripted client workload over the wire, assert a clean
drain-and-exit.

This is the fast-tier guard for the serving stack: it proves the subprocess
entry point (``python -m repro.serve.server``), the binary protocol, typed
admission errors, provenance adoption and graceful shutdown all work across
a genuine process boundary — in seconds, on a tiny graph.

Run:  PYTHONPATH=src python benchmarks/serve_smoke.py
"""

import sys
import time

import numpy as np


def main() -> int:
    t_start = time.perf_counter()
    from repro.core import provenance as prov
    from repro.core.table import INT, Table
    from repro.serve.client import RemoteService
    from repro.serve.policy import ServiceError
    from repro.serve.server import spawn_server

    proc, port = spawn_server(
        ("--workers", "2", "--rmat-scale", "8", "--edge-factor", "4"))
    print(f"smoke: server pid={proc.pid} port={port}")
    try:
        client = RemoteService(port=port, timeout=300.0)
        assert client.server_pid == proc.pid, "handshake pid mismatch"
        sess = client.session("smoke")

        # workspace round trip
        t = Table.from_columns({"x": INT}, {"x": [5, 1, 3]})
        client.workspace.put("t", t)
        assert client.workspace.get("t").to_pydict() == t.to_pydict()

        # a burst of traversals: fusion + out-of-order streaming exercised
        pendings = [sess.submit({"op": "bfs", "graph": "g",
                                 "params": {"source": s}})
                    for s in range(6)]
        dists = [np.asarray(p.result(timeout=300)) for p in pendings]
        assert all(d.shape == dists[0].shape for d in dists)

        # result cache: the repeat is served without a new engine call
        again = sess.submit({"op": "bfs", "graph": "g",
                             "params": {"source": 0}})
        np.testing.assert_array_equal(np.asarray(again.result(300)),
                                      dists[0])
        assert again.cached, "repeat query missed the result cache"

        # provenance crossed the wire: the remote result exports locally
        pr = sess.execute({"op": "pagerank", "graph": "g",
                           "params": {"n_iter": 5}, "as": "pr"})
        assert [r.op for r in prov.records_of(pr)] == ["algorithms.pagerank"]

        # typed errors: an unknown op is a ServiceError at the call site
        try:
            sess.submit({"op": "frobnicate", "graph": "g"})
        except ServiceError:
            pass
        else:
            raise AssertionError("unknown op did not raise ServiceError")

        stats = client.stats
        assert stats["requests"] >= 8
        print(f"smoke: {stats['requests']} requests, "
              f"{stats['cache_hits']} cache hits, "
              f"{stats['fused_requests']} fused")

        # observability over the wire: the server's metrics snapshot agrees
        # with the legacy stats counters, and the client can pull a Chrome
        # trace filtered to its own requests
        metrics = client.metrics()
        assert metrics["service.requests"]["value"] == stats["requests"]
        assert metrics["sched.engine_ms"]["count"] >= 1
        assert "# TYPE repro_service_requests counter" in client.metrics_text()
        doc = client.chrome_trace(trace=again.trace)
        names = {e["name"] for e in doc["traceEvents"]}
        assert "rpc.submit" in names and "service.submit" in names, names
        print(f"smoke: obs snapshot {len(metrics)} series, "
              f"{len(doc['traceEvents'])} trace events for cached repeat")

        client.shutdown_server()
        client.close()
    except BaseException:
        proc.kill()
        raise
    rc = proc.wait(timeout=120)
    assert rc == 0, f"server exited rc={rc} (expected clean drain)"
    print(f"serve smoke OK ({time.perf_counter() - t_start:.1f}s: "
          f"subprocess server, wire workload, clean shutdown)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
