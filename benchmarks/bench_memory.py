"""Memory-budget benchmark: bounded vs unbounded serving -> BENCH_memory.json.

Runs the fused+cached interactive workload twice, each leg in its **own
subprocess** so peak RSS (``VmHWM`` from ``/proc/self/status``) is a clean
per-leg number:

    unbounded   MemoryPolicy(budget_bytes=None) — accounting on, eviction
                off; records the tracked-bytes peak the workload reaches
    budgeted    budget = 25% of the unbounded leg's tracked peak; the
                byte-accounted LRU must evict continuously to stay inside

The workload mirrors ``bench_service.py``'s interactive profile: several
sessions, each round issuing single-source traversals from a small **hot**
source pool (repeat queries — should stay cache-resident under the budget)
plus one per-round **cold** source (queried once, never again — the LRU's
natural victims), with periodic PageRank re-runs and one pass of the
plan-family-heavy ops (connected components, triangles) so evictable plan
members carry real weight.

Per leg it records every post-query ``tracked_bytes`` sample, a sha256
digest chained over every result in submission order, wall time over the
query loop (after a warmup pass that absorbs JIT compilation in both legs
identically), and peak RSS.  The gates — enforced by ``ci_check.sh`` —
hold the PR 8 acceptance contract:

* ``within_budget``  — every budgeted-leg sample <= budget;
* ``bit_identical``  — the budgeted digest equals the unbounded digest
  (evicted cache entries re-execute, evicted plan members re-derive,
  nothing changes a single bit);
* ``slowdown``       — budgeted wall time <= 1.5x unbounded (same-run,
  same-machine ratio, hardware-independent);
* ``rss_ratio``      — budgeted peak RSS must not exceed unbounded's
  (with slack for allocator noise): bounding tracked bytes must not
  *grow* the actual process footprint.
"""

import argparse
import hashlib
import json
import os
import subprocess
import sys
import time

import numpy as np

#: budgeted leg's budget as a fraction of the unbounded tracked peak
BUDGET_FRACTION = 0.25


def peak_rss_bytes() -> int:
    """Peak resident set (VmHWM) of this process, from /proc."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return 0


def _digest_update(h, result) -> None:
    arr = np.asarray(result)
    h.update(arr.tobytes())


def run_leg(scale: int, edge_factor: int, sessions: int, rounds: int,
            hot_pool: int, budget: int) -> dict:
    from repro.core.graph import Graph
    from repro.data.rmat import rmat_edges
    from repro.serve.graph_service import GraphService
    from repro.serve.policy import MemoryPolicy

    s, d = rmat_edges(scale, edge_factor=edge_factor, seed=7)
    g = Graph.from_edges(s, d)
    n = g.n_nodes
    svc = GraphService(memory=MemoryPolicy(
        budget_bytes=budget if budget > 0 else None))
    svc.workspace.put("g", g)
    sess = [svc.session(f"s{i}") for i in range(sessions)]

    def q(i, req):
        return svc.execute(sess[i], req)

    # warmup: compile every op shape once so wall time measures serving, not
    # JIT (identical in both legs; results discarded from the digest)
    q(0, {"op": "bfs", "graph": "g", "params": {"source": 0}})
    q(0, {"op": "sssp", "graph": "g", "params": {"source": 0}})
    q(0, {"op": "pagerank", "graph": "g", "params": {"n_iter": 10}})

    h = hashlib.sha256()
    samples = []

    def sample():
        samples.append(int(svc.memory_stats()["tracked_bytes"]))

    t0 = time.perf_counter()
    # plan-family-heavy pass: materializes undirected/oriented members
    _digest_update(h, q(0, {"op": "connected_components", "graph": "g",
                            "params": {}}))
    sample()
    _digest_update(h, q(0, {"op": "triangle_count", "graph": "g",
                            "params": {}}))
    sample()
    n_queries = 2
    for r in range(rounds):
        for i in range(sessions):
            hot = (i + r) % hot_pool            # repeats across rounds
            cold = hot_pool + r * sessions + i  # unique: queried exactly once
            for src, op in ((hot, "sssp"), (cold % n, "bfs")):
                _digest_update(h, q(i, {"op": op, "graph": "g",
                                        "params": {"source": int(src)}}))
                sample()
                n_queries += 1
        if r % 3 == 2:
            _digest_update(h, q(0, {"op": "pagerank", "graph": "g",
                                    "params": {"n_iter": 10}}))
            sample()
            n_queries += 1
    wall_s = time.perf_counter() - t0

    st = dict(svc.stats)
    ms = svc.memory_stats()
    return {
        "budget_bytes": budget,
        "n_queries": n_queries,
        "wall_s": round(wall_s, 4),
        "qps": round(n_queries / wall_s, 1),
        "digest": h.hexdigest(),
        "tracked_peak": max(samples),
        "tracked_end": samples[-1],
        "n_samples": len(samples),
        "over_budget_samples": (sum(1 for b in samples if b > budget)
                                if budget > 0 else 0),
        "peak_rss_bytes": peak_rss_bytes(),
        "stats": {k: st[k] for k in
                  ("requests", "cache_hits", "engine_calls",
                   "evicted_results", "evicted_plan_families",
                   "evicted_bytes", "lineage_cuts")},
        "mem": ms,
    }


def _spawn_leg(args, budget: int) -> dict:
    out = f"{args.out}.leg{budget}.tmp"
    cmd = [sys.executable, os.path.abspath(__file__), "--_leg", out,
           "--budget", str(budget), "--scale", str(args.scale),
           "--edge-factor", str(args.edge_factor),
           "--sessions", str(args.sessions), "--rounds", str(args.rounds),
           "--hot-pool", str(args.hot_pool)]
    try:
        subprocess.run(cmd, check=True)
        with open(out) as f:
            return json.load(f)
    finally:
        if os.path.exists(out):
            os.remove(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_memory.json")
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--hot-pool", type=int, default=4)
    ap.add_argument("--budget", type=int, default=0,
                    help="(worker legs) budget in bytes; 0 = unbounded")
    ap.add_argument("--_leg", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args._leg:  # worker subprocess: one leg, json to the named file
        r = run_leg(args.scale, args.edge_factor, args.sessions, args.rounds,
                    args.hot_pool, args.budget)
        with open(args._leg, "w") as f:
            json.dump(r, f)
        return

    import jax
    print(f"memory bench: 2^{args.scale} RMAT x{args.edge_factor}, "
          f"{args.sessions} sessions x {args.rounds} rounds, "
          f"hot pool {args.hot_pool}")
    unb = _spawn_leg(args, 0)
    print(f"unbounded: {unb['n_queries']} queries {unb['qps']} qps, tracked "
          f"peak {unb['tracked_peak']/1e6:.2f}MB, "
          f"rss peak {unb['peak_rss_bytes']/1e6:.1f}MB")

    budget = max(int(unb["tracked_peak"] * BUDGET_FRACTION), 64 * 1024)
    bud = _spawn_leg(args, budget)
    print(f"budgeted({budget/1e6:.2f}MB): {bud['n_queries']} queries "
          f"{bud['qps']} qps, tracked peak {bud['tracked_peak']/1e6:.2f}MB, "
          f"rss peak {bud['peak_rss_bytes']/1e6:.1f}MB, evicted "
          f"{bud['stats']['evicted_results']} results / "
          f"{bud['stats']['evicted_plan_families']} plan families "
          f"({bud['stats']['evicted_bytes']/1e6:.2f}MB)")

    results = {
        "device": jax.default_backend(),
        "scale": args.scale, "edge_factor": args.edge_factor,
        "sessions": args.sessions, "rounds": args.rounds,
        "hot_pool": args.hot_pool,
        "budget_fraction": BUDGET_FRACTION,
        "budget_bytes": budget,
        "unbounded": unb,
        "budgeted": bud,
        "within_budget": bud["over_budget_samples"] == 0,
        "bit_identical": bud["digest"] == unb["digest"],
        "slowdown": round(bud["wall_s"] / unb["wall_s"], 3),
        "rss_ratio": round(bud["peak_rss_bytes"]
                           / max(unb["peak_rss_bytes"], 1), 3),
    }
    print(f"within_budget={results['within_budget']} "
          f"bit_identical={results['bit_identical']} "
          f"slowdown={results['slowdown']}x rss_ratio={results['rss_ratio']}")
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
