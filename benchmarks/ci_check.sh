#!/usr/bin/env bash
# Make-free tier-1 gate: full test suite + serving smoke + perf gates.
#
#   benchmarks/ci_check.sh            # tests + smoke + benchmarks + gates
#   benchmarks/ci_check.sh --fast     # fast tier: tests + server smoke only
#   benchmarks/ci_check.sh --scale 12 # extra args forwarded to bench_engine
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# stage <name> <cmd...>: run one pipeline stage, echoing its elapsed wall
# time so slow stages are attributable straight from the Actions log.
# set -e still aborts on the first failing stage (fail fast).
stage() {
  local name="$1"; shift
  local t0=$SECONDS
  echo "--- stage: ${name}"
  "$@"
  echo "--- stage: ${name} done in $(( SECONDS - t0 ))s"
}

FAST=0
ARGS=()
for a in "$@"; do
  case "$a" in
    --fast) FAST=1 ;;
    *) ARGS+=("$a") ;;
  esac
done

stage tests python -m pytest -x -q
# serving smoke: spawn a real server subprocess on an ephemeral port, run a
# scripted wire-protocol client workload, assert a clean drain-and-exit
stage serve_smoke python benchmarks/serve_smoke.py
# observability smoke: traced in-process workload, Chrome trace-event JSON
# schema validated, metrics snapshot non-empty
stage obs_smoke python benchmarks/obs_smoke.py
if [[ "$FAST" == "1" ]]; then
  echo "ci_check OK (--fast tier: tests + server/obs smoke, benchmarks skipped)"
  exit 0
fi

# Snapshot the committed bench numbers before the benchmarks overwrite them:
# bench_delta.py diffs the fresh run against this baseline at the end.
BASELINE_DIR="$(mktemp -d)"
trap 'rm -rf "$BASELINE_DIR"' EXIT
for f in BENCH_engine.json BENCH_service.json BENCH_memory.json; do
  [[ -f "$f" ]] && cp "$f" "$BASELINE_DIR/"
done

stage bench_engine python benchmarks/bench_engine.py --out BENCH_engine.json \
  ${ARGS[@]+"${ARGS[@]}"}
# frontier gate: sparse BFS must beat the dense relaxation on 2^15 RMAT
python - <<'EOF'
import json
b = json.load(open("BENCH_engine.json"))["bfs"]
assert b["speedup"] >= 1.5, \
    f"frontier BFS speedup {b['speedup']}x < 1.5x gate (dense {b['dense_ms']}ms, " \
    f"frontier {b['frontier_ms']}ms)"
print(f"engine gate OK: frontier BFS {b['speedup']}x vs dense")
EOF
# incremental-maintenance gates: on a 0.1% edge delta, plan patching must
# beat full re-derivation >= 5x, and a warm-started pagerank refresh
# (delta apply + patched plan + tol solve from the parent vector) must beat
# the from-scratch refresh >= 2x — both same-run ratios, hardware-independent
python - <<'EOF'
import json
d = json.load(open("BENCH_engine.json"))["delta"]
assert d["plan_patch_speedup"] >= 5.0, \
    f"plan patch speedup {d['plan_patch_speedup']}x < 5x gate " \
    f"(patch {d['plan_patch_ms']}ms, rederive {d['plan_rederive_ms']}ms)"
assert d["warm_pagerank_speedup"] >= 2.0, \
    f"warm pagerank refresh speedup {d['warm_pagerank_speedup']}x < 2x gate " \
    f"(warm {d['warm_pagerank_ms']}ms, cold {d['cold_pagerank_ms']}ms)"
print(f"delta gate OK: plan patch {d['plan_patch_speedup']}x, "
      f"warm pagerank refresh {d['warm_pagerank_speedup']}x, "
      f"bfs re-seed {d['bfs_reseed_speedup']}x")
EOF
# interactive service: concurrent-session throughput/latency on 2^15 RMAT
# with/without fusion + caching (gate: fused_cached >= 2x sequential), plus
# the overload run — 1 flooding session vs 8 interactive under fifo vs
# fair-share scheduling (gate: interactive p99 >= 3x better under fair)
stage bench_service python benchmarks/bench_service.py --out BENCH_service.json
python - <<'EOF'
import json
r = json.load(open("BENCH_service.json"))
assert r["speedup_fused_cached"] >= 2.0, \
    f"service fused+cached speedup {r['speedup_fused_cached']}x < 2x gate"
print(f"service gate OK: fused+cached {r['speedup_fused_cached']}x")
o = r["overload"]
assert o["p99_improvement"] >= 3.0, \
    f"overload gate: fair-share interactive p99 only " \
    f"{o['p99_improvement']}x better than FIFO (< 3x); " \
    f"fifo={o['modes']['fifo']['interactive_p99_ms']}ms " \
    f"fair={o['modes']['fair']['interactive_p99_ms']}ms"
print(f"overload gate OK: fair-share interactive p99 "
      f"{o['p99_improvement']}x better than FIFO")
m = r["remote"]
assert m["server_exit_code"] == 0, \
    f"remote gate: server exited rc={m['server_exit_code']}"
assert m["overhead_cached_p50"] <= 3.0, \
    f"remote gate: wire overhead for cached queries is " \
    f"{m['overhead_cached_p50']}x in-process p50 (> 3x, baseline " \
    f"floored at {m['overhead_floor_ms']}ms); " \
    f"in-process={m['inproc_cached_p50_ms']}ms " \
    f"remote={m['remote_cached_p50_ms']}ms"
print(f"remote gate OK: cached-query wire overhead "
      f"{m['overhead_cached_p50']}x in-process "
      f"({m['multiproc']['clients']} client processes, "
      f"{m['multiproc']['agg_qps']} qps aggregate)")
obs = r["obs_overhead"]
assert obs["ratio"] <= 1.05, \
    f"obs gate: instrumentation overhead {obs['ratio']}x > 1.05x " \
    f"(enabled {obs['enabled_median_s']}s, " \
    f"disabled {obs['disabled_median_s']}s)"
print(f"obs gate OK: instrumentation overhead {obs['ratio']}x (<= 1.05x)")
EOF
# memory-budget gates (ISSUE 8): the fused+cached workload re-run under a
# budget of 25% of its own unbounded tracked peak must stay inside the
# budget at every sample, answer bit-identically, stay within 1.5x wall
# time, and must not grow peak RSS — all same-run ratios except RSS, which
# gets allocator-noise slack
stage bench_memory python benchmarks/bench_memory.py --out BENCH_memory.json
python - <<'EOF'
import json
m = json.load(open("BENCH_memory.json"))
b, u = m["budgeted"], m["unbounded"]
assert m["within_budget"], \
    f"memory gate: {b['over_budget_samples']}/{b['n_samples']} samples over " \
    f"the {m['budget_bytes']} byte budget (peak {b['tracked_peak']})"
assert m["bit_identical"], \
    f"memory gate: budgeted results diverge from unbounded " \
    f"({b['digest'][:12]} != {u['digest'][:12]})"
assert m["slowdown"] <= 1.5, \
    f"memory gate: budgeted run {m['slowdown']}x slower than unbounded " \
    f"(> 1.5x; budgeted {b['wall_s']}s, unbounded {u['wall_s']}s)"
assert m["rss_ratio"] <= 1.2, \
    f"memory gate: budgeted peak RSS {m['rss_ratio']}x unbounded (> 1.2x; " \
    f"bounding tracked bytes must not grow the footprint)"
print(f"memory gate OK: budget {m['budget_bytes']/1e6:.2f}MB "
      f"({int(m['budget_fraction']*100)}% of unbounded peak), "
      f"bit-identical, slowdown {m['slowdown']}x, rss {m['rss_ratio']}x, "
      f"evicted {b['stats']['evicted_results']} results + "
      f"{b['stats']['evicted_plan_families']} plan families")
EOF
# regression delta: fresh ratios vs the committed baseline (>30% fails;
# absolute ms/qps are machine-relative and reported info-only)
stage bench_delta python benchmarks/bench_delta.py --old-dir "$BASELINE_DIR" --new-dir . \
  --threshold 0.30
