#!/usr/bin/env bash
# Make-free tier-1 gate: full test suite + engine perf smoke.
#
#   benchmarks/ci_check.sh            # tests + benchmark -> BENCH_engine.json
#   benchmarks/ci_check.sh --scale 12 # extra args forwarded to bench_engine
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python benchmarks/bench_engine.py --out BENCH_engine.json "$@"
