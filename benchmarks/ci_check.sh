#!/usr/bin/env bash
# Make-free tier-1 gate: full test suite + engine & service perf smoke.
#
#   benchmarks/ci_check.sh            # tests + benchmarks -> BENCH_*.json
#   benchmarks/ci_check.sh --scale 12 # extra args forwarded to bench_engine
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python benchmarks/bench_engine.py --out BENCH_engine.json "$@"
# frontier gate: sparse BFS must beat the dense relaxation on 2^15 RMAT
python - <<'EOF'
import json
b = json.load(open("BENCH_engine.json"))["bfs"]
assert b["speedup"] >= 1.5, \
    f"frontier BFS speedup {b['speedup']}x < 1.5x gate (dense {b['dense_ms']}ms, " \
    f"frontier {b['frontier_ms']}ms)"
print(f"engine gate OK: frontier BFS {b['speedup']}x vs dense")
EOF
# interactive service: concurrent-session throughput/latency on 2^15 RMAT,
# with/without fusion + caching (gate: fused_cached >= 2x sequential)
python benchmarks/bench_service.py --out BENCH_service.json
python - <<'EOF'
import json
r = json.load(open("BENCH_service.json"))
assert r["speedup_fused_cached"] >= 2.0, \
    f"service fused+cached speedup {r['speedup_fused_cached']}x < 2x gate"
print(f"service gate OK: fused+cached {r['speedup_fused_cached']}x")
EOF
