"""Bench regression delta: fresh BENCH_*.json vs the committed baseline.

``ci_check.sh`` snapshots the committed ``BENCH_engine.json`` /
``BENCH_service.json`` before re-running the benchmarks, then calls this
script to diff the throughput-bearing metrics:

* engine: per-backend ``pagerank_ms`` and the BFS ``dense_ms`` /
  ``frontier_ms`` (lower is better);
* service: per-mode ``qps`` (higher is better).

Every metric present in both files is printed old-vs-new with its relative
change; any metric more than ``--threshold`` (default 30%) *worse* than the
baseline fails the check.  Latency percentiles and the overload fairness
ratio are reported by the benchmarks but deliberately not delta-gated here —
they have their own absolute gates in ``ci_check.sh`` and are too noisy for
a tight relative bound.  Metrics that appear or disappear (new benchmark
blocks, renamed backends) are informational, never failures.

Usage::

    python benchmarks/bench_delta.py --old-dir /tmp/baseline --new-dir . \
        [--threshold 0.30]
"""

import argparse
import json
import os
import sys

#: metric -> direction; "lower" = ms-like (regression when it grows),
#: "higher" = qps-like (regression when it shrinks)
_FILES = ("BENCH_engine.json", "BENCH_service.json")


def _metrics(fname: str, data: dict) -> dict:
    out = {}
    if fname == "BENCH_engine.json":
        for be, blk in (data.get("backends") or {}).items():
            if "pagerank_ms" in blk:
                out[f"engine.{be}.pagerank_ms"] = (float(blk["pagerank_ms"]),
                                                   "lower")
        for k in ("dense_ms", "frontier_ms"):
            if k in (data.get("bfs") or {}):
                out[f"engine.bfs.{k}"] = (float(data["bfs"][k]), "lower")
    elif fname == "BENCH_service.json":
        for mode, blk in (data.get("modes") or {}).items():
            if "qps" in blk:
                out[f"service.{mode}.qps"] = (float(blk["qps"]), "higher")
    return out


def _load(path: str) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--old-dir", required=True,
                    help="directory holding the committed baseline jsons")
    ap.add_argument("--new-dir", default=".",
                    help="directory holding the freshly produced jsons")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="fail when a metric is this fraction worse than "
                         "the baseline (0.30 = 30%%)")
    args = ap.parse_args()

    failures = []
    rows = []
    for fname in _FILES:
        old = _metrics(fname, _load(os.path.join(args.old_dir, fname)))
        new = _metrics(fname, _load(os.path.join(args.new_dir, fname)))
        for key in sorted(set(old) | set(new)):
            if key not in old:
                rows.append((key, None, new[key][0], "new metric (info)"))
                continue
            if key not in new:
                rows.append((key, old[key][0], None, "dropped (info)"))
                continue
            ov, direction = old[key]
            nv, _ = new[key]
            if ov <= 0:
                rows.append((key, ov, nv, "no baseline (info)"))
                continue
            # "worse" is direction-aware: ms growing / qps shrinking
            worse = (nv - ov) / ov if direction == "lower" \
                else (ov - nv) / ov
            verdict = "OK"
            if worse > args.threshold:
                verdict = f"REGRESSION (> {args.threshold:.0%} worse)"
                failures.append(key)
            rows.append((key, ov, nv, f"{-worse:+.1%} {verdict}"))

    width = max((len(r[0]) for r in rows), default=10)
    print(f"bench delta vs committed baseline "
          f"(threshold {args.threshold:.0%}):")
    for key, ov, nv, note in rows:
        o = "-" if ov is None else f"{ov:10.2f}"
        n = "-" if nv is None else f"{nv:10.2f}"
        print(f"  {key:<{width}}  old={o:>10}  new={n:>10}  {note}")
    if failures:
        print(f"bench delta FAILED: {len(failures)} metric(s) regressed "
              f"more than {args.threshold:.0%}: {', '.join(failures)}")
        return 1
    print("bench delta OK: no metric regressed past the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
