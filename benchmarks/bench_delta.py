"""Bench regression delta: fresh BENCH_*.json vs the committed baseline.

``ci_check.sh`` snapshots the committed ``BENCH_engine.json`` /
``BENCH_service.json`` / ``BENCH_memory.json`` before re-running the
benchmarks, then calls this script to diff them.  **Only hardware-independent speedup ratios are
gated**; absolute numbers are printed for information but never fail:

* gated — ``engine.bfs.speedup`` (frontier vs dense), ``engine.delta.
  plan_patch_speedup`` / ``warm_pagerank_speedup`` / ``bfs_reseed_speedup``
  (incremental vs from-scratch), ``service.speedup_fused`` /
  ``speedup_fused_cached`` (vs sequential),
  ``service.overload.p99_improvement`` (fair vs fifo) and
  ``memory.slowdown`` (budgeted vs unbounded, also capped absolutely at
  1.5x).  Each compares two
  measurements from the *same run on the same machine*, so a
  differently-sized CI runner moves numerator and denominator together and
  the 30% bound means what it says.
* informational — per-backend ``pagerank_ms``, BFS ``dense_ms`` /
  ``frontier_ms``, the whole ``engine.sharded`` block (simulated devices
  share one CPU, so even its same-run 8-vs-1 ratios measure
  oversubscription, not scaling), per-mode ``qps``, and ``service.remote.
  overhead_cached_p50`` (its 1 ms baseline floor usually dominates the
  denominator, making it an absolute wire latency; ``ci_check.sh`` holds
  its own <= 3x gate).  Absolute numbers are machine-relative (the
  committed baselines come from the dev box) and gating them flaked on
  differently-sized CI runners — the exact failure mode this split fixes.

Metrics that appear or disappear (new benchmark blocks, renamed backends)
are informational, never failures.

Exceptions to "ratios only": ``service.obs_overhead.ratio`` (enabled /
disabled wall time of the fused service workload) carries an **absolute
cap** of 1.05x.  It is already a same-run, same-machine ratio, so the cap
is hardware-independent — and the observability contract ("under 5%
overhead") is absolute, not relative to whatever the baseline happened to
measure.  The cap fails the check even when no baseline file exists.
Symmetrically, ``service.overload.p99_improvement`` carries an **absolute
floor** of 3x instead of a delta gate — its FIFO denominator is measured
under deliberate saturation and swings ~2x between identical runs, so a
relative threshold flakes while the absolute serving contract does not.

Usage::

    python benchmarks/bench_delta.py --old-dir /tmp/baseline --new-dir . \
        [--threshold 0.30]
"""

import argparse
import json
import os
import sys

_FILES = ("BENCH_engine.json", "BENCH_service.json", "BENCH_memory.json")

#: absolute caps enforced on the *new* values regardless of any baseline:
#: metric -> max allowed value.  Used for contracts that are absolute by
#: nature (the observability subsystem promises <= 5% overhead; the memory
#: budget promises <= 1.5x eviction overhead on the budgeted re-run).
_ABS_MAX = {"service.obs_overhead.ratio": 1.05,
            "memory.slowdown": 1.5}

#: absolute floors, same idea in the other direction: metric -> min
#: required value.  The fair-share overload win is a ratio of two p99s
#: measured under deliberate CPU saturation — its FIFO denominator swings
#: ~2x run-to-run on a contended box with identical code, so delta-gating
#: it flakes; the serving contract ("fair share keeps interactive p99 at
#: least 3x better than FIFO under flood") is absolute, mirroring
#: ci_check.sh.
_ABS_MIN = {"service.overload.p99_improvement": 3.0}


def _metrics(fname: str, data: dict) -> dict:
    """metric -> (value, direction, gated).

    direction "lower" = ms-like (regression when it grows), "higher" =
    speedup/qps-like (regression when it shrinks).  gated=False metrics are
    printed but can never fail the check.
    """
    out = {}
    if fname == "BENCH_engine.json":
        for be, blk in (data.get("backends") or {}).items():
            if "pagerank_ms" in blk:
                out[f"engine.{be}.pagerank_ms"] = (
                    float(blk["pagerank_ms"]), "lower", False)
        bfs = data.get("bfs") or {}
        for k in ("dense_ms", "frontier_ms"):
            if k in bfs:
                out[f"engine.bfs.{k}"] = (float(bfs[k]), "lower", False)
        if "speedup" in bfs:
            out["engine.bfs.speedup"] = (float(bfs["speedup"]), "higher",
                                         True)
        delta = data.get("delta") or {}
        for k in ("plan_patch_ms", "plan_rederive_ms", "cold_pagerank_ms",
                  "warm_pagerank_ms", "cold_bfs_ms", "warm_bfs_ms"):
            if k in delta:
                out[f"engine.delta.{k}"] = (float(delta[k]), "lower", False)
        for k in ("plan_patch_speedup", "warm_pagerank_speedup",
                  "bfs_reseed_speedup"):
            if k in delta:
                out[f"engine.delta.{k}"] = (float(delta[k]), "higher", True)
        # sharded backend: everything info-only.  The N simulated devices
        # share one CPU, so even the 8-vs-1 same-run ratio measures
        # oversubscription, not scaling — tracked to watch the trend, never
        # gated (the bitwise-identity assert lives inside bench_engine.py
        # and the oracle tests gate correctness in the sharded-sim lane).
        sh = data.get("sharded") or {}
        for leg, blk in (sh.get("legs") or {}).items():
            for k in ("pagerank_ms", "bfs_ms", "shard_plan_build_ms",
                      "halo_bytes_per_round"):
                if k in blk:
                    out[f"engine.sharded.d{leg}.{k}"] = (
                        float(blk[k]), "lower", False)
        for k in ("pagerank_ratio_8v1", "bfs_ratio_8v1"):
            if k in sh:
                out[f"engine.sharded.{k}"] = (float(sh[k]), "higher", False)
    elif fname == "BENCH_service.json":
        for mode, blk in (data.get("modes") or {}).items():
            if "qps" in blk:
                out[f"service.{mode}.qps"] = (float(blk["qps"]), "higher",
                                              False)
        for k in ("speedup_fused", "speedup_fused_cached"):
            if k in data:
                out[f"service.{k}"] = (float(data[k]), "higher", True)
        overload = data.get("overload") or {}
        if "p99_improvement" in overload:
            # not delta-gated: the FIFO denominator swings ~2x run-to-run
            # under saturation; the _ABS_MIN floor holds the real contract
            out["service.overload.p99_improvement"] = (
                float(overload["p99_improvement"]), "higher", False)
        obs_blk = data.get("obs_overhead") or {}
        if "ratio" in obs_blk:
            # delta-gating is pointless here (1.00 vs 1.02 is noise); the
            # _ABS_MAX cap holds the real contract
            out["service.obs_overhead.ratio"] = (
                float(obs_blk["ratio"]), "lower", False)
        remote = data.get("remote") or {}
        if "overhead_cached_p50" in remote:
            # info-only: the 1 ms baseline floor usually dominates the
            # denominator, making this effectively an absolute wire
            # latency — machine-dependent, so delta-gating it would
            # reintroduce the runner-size flake.  ci_check.sh holds the
            # absolute <= 3x gate for it instead.
            out["service.remote.overhead_cached_p50"] = (
                float(remote["overhead_cached_p50"]), "lower", False)
    elif fname == "BENCH_memory.json":
        if "slowdown" in data:
            # eviction-overhead ratio: budgeted vs unbounded wall time of
            # the same workload in the same run — hardware-independent, so
            # both delta-gated and capped absolutely (_ABS_MAX, mirroring
            # the ci_check.sh gate)
            out["memory.slowdown"] = (float(data["slowdown"]), "lower", True)
        if "rss_ratio" in data:
            # same-run ratio but allocator-noise-dominated: info only,
            # ci_check.sh holds the absolute <= 1.2x gate
            out["memory.rss_ratio"] = (float(data["rss_ratio"]), "lower",
                                       False)
        for leg in ("unbounded", "budgeted"):
            blk = data.get(leg) or {}
            if "tracked_peak" in blk:
                out[f"memory.{leg}.tracked_peak"] = (
                    float(blk["tracked_peak"]), "lower", False)
            if "qps" in blk:
                out[f"memory.{leg}.qps"] = (float(blk["qps"]), "higher",
                                            False)
    return out


def _load(path: str) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--old-dir", required=True,
                    help="directory holding the committed baseline jsons")
    ap.add_argument("--new-dir", default=".",
                    help="directory holding the freshly produced jsons")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="fail when a gated ratio is this fraction worse "
                         "than the baseline (0.30 = 30%%)")
    args = ap.parse_args()

    failures = []
    rows = []
    for fname in _FILES:
        old = _metrics(fname, _load(os.path.join(args.old_dir, fname)))
        new = _metrics(fname, _load(os.path.join(args.new_dir, fname)))
        for key in sorted(set(old) | set(new)):
            cap = _ABS_MAX.get(key)
            if cap is not None and key in new and new[key][0] > cap:
                failures.append(key)
                rows.append((key, old[key][0] if key in old else None,
                             new[key][0],
                             f"EXCEEDS ABSOLUTE CAP {cap} (hard gate)"))
                continue
            floor = _ABS_MIN.get(key)
            if floor is not None and key in new and new[key][0] < floor:
                failures.append(key)
                rows.append((key, old[key][0] if key in old else None,
                             new[key][0],
                             f"BELOW ABSOLUTE FLOOR {floor} (hard gate)"))
                continue
            if key not in old:
                rows.append((key, None, new[key][0], "new metric (info)"))
                continue
            if key not in new:
                rows.append((key, old[key][0], None, "dropped (info)"))
                continue
            ov, direction, gated = old[key]
            nv, _, _ = new[key]
            if ov <= 0:
                rows.append((key, ov, nv, "no baseline (info)"))
                continue
            # "worse" is direction-aware: ms/overhead growing, ratio shrinking
            worse = (nv - ov) / ov if direction == "lower" \
                else (ov - nv) / ov
            if not gated:
                rows.append((key, ov, nv, f"{-worse:+.1%} (info only)"))
                continue
            verdict = "OK"
            if worse > args.threshold:
                verdict = f"REGRESSION (> {args.threshold:.0%} worse)"
                failures.append(key)
            rows.append((key, ov, nv, f"{-worse:+.1%} {verdict}"))

    width = max((len(r[0]) for r in rows), default=10)
    print(f"bench delta vs committed baseline — gated metrics are "
          f"hardware-independent ratios (threshold {args.threshold:.0%}):")
    for key, ov, nv, note in rows:
        o = "-" if ov is None else f"{ov:10.2f}"
        n = "-" if nv is None else f"{nv:10.2f}"
        print(f"  {key:<{width}}  old={o:>10}  new={n:>10}  {note}")
    if failures:
        print(f"bench delta FAILED: {len(failures)} ratio(s) regressed "
              f"more than {args.threshold:.0%}: {', '.join(failures)}")
        return 1
    print("bench delta OK: no gated ratio regressed past the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
