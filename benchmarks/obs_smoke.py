"""CI smoke for the observability subsystem: run a traced in-process
workload through the full service stack, then validate the two export
surfaces — the Chrome trace-event JSON schema and the metrics snapshot.

This is the fast-tier guard for ``repro.obs``: if an instrumentation hook
regresses (spans stop nesting, the exporter emits malformed events, a
counter family disappears), this fails in seconds on a tiny graph long
before the overhead bench or a human looking at chrome://tracing would.

Run:  PYTHONPATH=src python benchmarks/obs_smoke.py
"""

import json
import sys
import tempfile
import time


def main() -> int:
    t_start = time.perf_counter()
    import numpy as np

    from repro import obs
    from repro.core import algorithms as A
    from repro.core.graph import Graph
    from repro.serve.graph_service import GraphService, Workspace

    obs.reset()

    rng = np.random.default_rng(7)
    n, m = 512, 2048
    g = Graph.from_edges(rng.integers(0, n, m).astype(np.int32),
                         rng.integers(0, n, m).astype(np.int32))

    # traced service workload: traversal burst + cached repeat + pagerank
    ws = Workspace()
    ws.put("g", g)
    svc = GraphService(ws, workers=2)
    try:
        sess = svc.session("obs-smoke")
        trace = obs.new_trace_id()
        pend = [svc.submit(sess, {"op": "bfs", "graph": "g",
                                  "params": {"source": s}}, trace=trace)
                for s in range(4)]
        svc.flush()
        for p in pend:
            p.result(timeout=120)
        repeat = svc.submit(sess, {"op": "bfs", "graph": "g",
                                   "params": {"source": 0}}, trace=trace)
        repeat.result(timeout=120)
        assert repeat.cached, "repeat query missed the result cache"
        svc.execute(sess, {"op": "pagerank", "graph": "g",
                           "params": {"n_iter": 5}})
    finally:
        svc.close()

    # the frontier engine emits per-round spans with frontier sizes
    with obs.span("smoke.frontier", trace=trace):
        A.bfs(g, 0, backend="frontier")

    # --- Chrome trace export: validate the trace-event schema -------------
    with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
        doc = obs.export_chrome_trace(f.name, trace=trace)
        assert json.load(open(f.name)) == doc, "on-disk trace != export"
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs, "empty trace"
    for e in evs:
        assert e["ph"] in ("X", "i", "M"), e
        assert isinstance(e["name"], str) and isinstance(e["pid"], int)
        if e["ph"] in ("X", "i"):
            assert isinstance(e["ts"], float) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert e["dur"] >= 0
    names = {e["name"] for e in evs}
    for want in ("service.submit", "sched.queued", "sched.execute",
                 "engine.bfs", "service.cache_hit_submit",
                 "engine.frontier_fixpoint", "engine.frontier.round"):
        assert want in names, f"span {want!r} missing from trace: {names}"
    rounds = [e for e in evs if e["name"] == "engine.frontier.round"]
    assert all("frontier" in e["args"] for e in rounds)

    # --- metrics snapshot: non-empty, and the core families are present ---
    snap = obs.dump_metrics()
    assert snap, "metrics snapshot is empty"
    assert snap["service.requests"]["value"] >= 5
    assert snap["service.cache_hits"]["value"] >= 1
    assert snap["sched.engine_ms"]["count"] >= 1
    assert snap["engine.frontier.rounds"]["value"] >= 1
    assert "# TYPE repro_service_requests counter" in obs.dump_metrics("prom")

    print(f"obs smoke OK ({time.perf_counter() - t_start:.1f}s: "
          f"{len(evs)} trace events, {len(snap)} metric series)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
