"""CI smoke for the observability subsystem: run a traced in-process
workload through the full service stack, then validate every export
surface — the Chrome trace-event JSON schema, the metrics snapshot, and
(PR 10) the judgment layer: SLO health/report schemas, the engine profile
report, and the flight-recorder debug bundle, both in-process and over a
real socket.

This is the fast-tier guard for ``repro.obs``: if an instrumentation hook
regresses (spans stop nesting, the exporter emits malformed events, a
counter family disappears, a bundle stops JSON-round-tripping), this fails
in seconds on a tiny graph long before the overhead bench or a human
looking at chrome://tracing would.

Run:  PYTHONPATH=src python benchmarks/obs_smoke.py
"""

import json
import sys
import tempfile
import time


def main() -> int:
    t_start = time.perf_counter()
    import numpy as np

    from repro import obs
    from repro.core import algorithms as A
    from repro.core.graph import Graph
    from repro.serve.graph_service import GraphService, Workspace

    obs.reset()
    # a deliberately-unmeetable objective on bfs: every bfs completion is
    # "slow", so the flight recorder is guaranteed to capture exemplars
    obs.SLO.set_objective("bfs", latency_ms=0.0)

    rng = np.random.default_rng(7)
    n, m = 512, 2048
    g = Graph.from_edges(rng.integers(0, n, m).astype(np.int32),
                         rng.integers(0, n, m).astype(np.int32))

    # traced service workload: traversal burst + cached repeat + pagerank
    ws = Workspace()
    ws.put("g", g)
    svc = GraphService(ws, workers=2)
    try:
        sess = svc.session("obs-smoke")
        trace = obs.new_trace_id()
        pend = [svc.submit(sess, {"op": "bfs", "graph": "g",
                                  "params": {"source": s}}, trace=trace)
                for s in range(4)]
        svc.flush()
        for p in pend:
            p.result(timeout=120)
        repeat = svc.submit(sess, {"op": "bfs", "graph": "g",
                                   "params": {"source": 0}}, trace=trace)
        repeat.result(timeout=120)
        assert repeat.cached, "repeat query missed the result cache"
        svc.execute(sess, {"op": "pagerank", "graph": "g",
                           "params": {"n_iter": 5}})
    finally:
        svc.close()

    # the frontier engine emits per-round spans with frontier sizes
    with obs.span("smoke.frontier", trace=trace):
        A.bfs(g, 0, backend="frontier")

    # --- Chrome trace export: validate the trace-event schema -------------
    with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
        doc = obs.export_chrome_trace(f.name, trace=trace)
        assert json.load(open(f.name)) == doc, "on-disk trace != export"
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs, "empty trace"
    for e in evs:
        assert e["ph"] in ("X", "i", "M"), e
        assert isinstance(e["name"], str) and isinstance(e["pid"], int)
        if e["ph"] in ("X", "i"):
            assert isinstance(e["ts"], float) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert e["dur"] >= 0
    names = {e["name"] for e in evs}
    for want in ("service.submit", "sched.queued", "sched.execute",
                 "engine.bfs", "service.cache_hit_submit",
                 "engine.frontier_fixpoint", "engine.frontier.round"):
        assert want in names, f"span {want!r} missing from trace: {names}"
    rounds = [e for e in evs if e["name"] == "engine.frontier.round"]
    assert all("frontier" in e["args"] for e in rounds)

    # --- metrics snapshot: non-empty, and the core families are present ---
    snap = obs.dump_metrics()
    assert snap, "metrics snapshot is empty"
    assert snap["service.requests"]["value"] >= 5
    assert snap["service.cache_hits"]["value"] >= 1
    assert snap["sched.engine_ms"]["count"] >= 1
    assert snap["engine.frontier.rounds"]["value"] >= 1
    assert "# TYPE repro_service_requests counter" in obs.dump_metrics("prom")

    # --- judgment layer: SLO health / report schemas ----------------------
    health = obs.health()
    assert health["status"] in ("ok", "degraded", "breaching"), health
    assert health["ops"]["bfs"]["slow"] >= 1, health["ops"]
    assert health["ops"]["bfs"]["status"] == "breaching"
    assert isinstance(health["reasons"], list) and health["reasons"]
    assert health["combined"]["status"] in ("ok", "degraded", "breaching")
    report = obs.slo_report()
    for key in ("ops", "objectives", "default_objective", "thresholds",
                "service", "window_s"):
        assert key in report, f"slo_report missing {key!r}"
    assert report["ops"]["bfs"]["n"] >= 5
    assert report["ops"]["bfs"]["burn_rate"] > 0

    # --- engine profiler: compile/execute split + report ------------------
    prof_series = [k for k in snap if k.startswith("engine.profile.")]
    assert prof_series, "engine profiler recorded nothing"
    prep = obs.profile_report()
    assert prep.startswith("engine profile"), prep
    assert "frontier" in prep

    # --- flight recorder: exemplars + bundle round trip -------------------
    exs = obs.FLIGHT.exemplars("bfs")
    assert exs and exs[-1]["slow"] and exs[-1]["spans"], \
        "forced-slow bfs must leave an exemplar with span evidence"
    with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
        bundle = obs.debug_bundle(f.name)
        assert json.load(open(f.name)) == bundle, "bundle != on-disk JSON"
    assert bundle["kind"] == "repro-debug-bundle" and bundle["version"] == 1
    for key in ("health", "slo", "metrics", "profile", "trace", "tracer",
                "flight", "exemplars", "log_tail", "config", "versions"):
        assert key in bundle, f"bundle missing {key!r}"
    assert bundle["exemplars"]["bfs"]
    from repro.obs.report import render_bundle
    assert "flight recorder" in render_bundle(bundle)

    # --- the same three surfaces over a real socket -----------------------
    from repro.serve.client import RemoteService
    from repro.serve.server import GraphServer
    ws2 = Workspace()
    ws2.put("g", g)
    server = GraphServer(GraphService(ws2, workers=0)).start()
    client = RemoteService(port=server.port, timeout=120.0)
    try:
        rs = client.session("obs-smoke-wire")
        rp = rs.submit({"op": "bfs", "graph": "g", "params": {"source": 1}})
        client.flush()
        rp.result(120)
        wh = client.health()
        assert wh["status"] in ("ok", "degraded", "breaching")
        assert client.slo_report()["ops"]["bfs"]["n"] >= 1
        wb = client.debug_bundle(trace=rp.trace)
        assert wb["kind"] == "repro-debug-bundle"
        assert wb["exemplars"]["bfs"][-1]["spans"], \
            "wire bundle lost exemplar span evidence"
        assert client.profile_report().startswith("engine profile")
    finally:
        client.close()
        server.shutdown()
    obs.reset()

    print(f"obs smoke OK ({time.perf_counter() - t_start:.1f}s: "
          f"{len(evs)} trace events, {len(snap)} metric series, "
          f"{len(bundle['exemplars'])} exemplar op(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
