"""Benchmark driver: one function per paper table + kernel validation +
roofline summary.  Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--skip-roofline]
"""

from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    from . import paper_tables
    rows = paper_tables.run_all()
    print("name,us_per_call,derived")
    for name, us, extra in rows:
        print(f"{name},{us:.1f},{extra}")

    # kernel sanity at benchmark scale (interpret mode on CPU)
    import numpy as np
    from repro.core.graph import Graph
    from repro.core import algorithms as A
    from repro.kernels import ops
    from repro.data.rmat import rmat_edges
    s, d = rmat_edges(scale=9, edge_factor=8, seed=3)
    keep = s != d
    g = Graph.from_edges(s[keep], d[keep], dedupe=True)
    pr_k = np.asarray(ops.pagerank_bsr(g, n_iter=3))
    pr_r = np.asarray(A.pagerank(g, n_iter=3))
    print(f"kernel.bsr_spmv_allclose,0,max_err={np.abs(pr_k-pr_r).max():.2e}")
    u = g.to_undirected()
    print(f"kernel.bsr_tricount_match,0,"
          f"{ops.triangle_count_bsr(u)}=={A.triangle_count(u)}")

    if not args.skip_roofline:
        # roofline summary from the dry-run cells (if present)
        try:
            from .roofline import load
            rl = load("baseline", "single")
            for r in rl:
                print(f"roofline.{r['arch']}.{r['shape']},0,"
                      f"dominant={r['dominant']} "
                      f"compute_ms={r['compute_s']*1e3:.1f} "
                      f"memory_ms={r['memory_s']*1e3:.1f} "
                      f"collective_ms={r['collective_s']*1e3:.1f}")
        except Exception as e:  # dry-run results absent: not an error here
            print(f"roofline.unavailable,0,{e!r}")


if __name__ == "__main__":
    main()
