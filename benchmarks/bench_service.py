"""Interactive service benchmark — concurrent-session query throughput and
latency -> BENCH_service.json.

Simulates the paper's multi-analyst trial-and-error loop against one shared
RMAT graph (default 2^15 nodes): every round, each session issues one
single-source traversal (sssp or bfs) from a small rotating source pool plus
periodic PageRank re-runs, exactly the redundancy profile of interactive
exploration.  The workload runs three ways:

    sequential    fusion off, cache off — every query is its own engine call
    fused         the scheduler coalesces each round's single-source queries
                  into one vmapped multi-source fixpoint
    fused_cached  fusion + the versioned result cache (repeat queries free)

and records throughput (qps) and per-query p50/p99 latency for each.  The
accept gate for the service subsystem is fused_cached >= 2x sequential
throughput on the same workload.

The **overload** block measures the scheduler's admission-control/fair-share
contract (ISSUE 4): one hostile session floods the service with expensive
non-fusable queries (held to its in-flight quota by admission control, its
spillover absorbed as RejectedError+retry-after backoff) while N interactive
sessions run a closed query loop.  The same workload runs under ``"fifo"``
(global arrival order — what a naive queue gives you) and ``"fair"``
(deficit-round-robin charged in engine-ms); the gate asserts interactive p99
under fair share is >= 3x better than FIFO.

The **remote** block (ISSUE 5) is the honest serving benchmark: a real
server subprocess (``python -m repro.serve.server``) and genuinely
independent client OS processes speaking the wire protocol.  It records
(a) the cached-query overhead of the wire — a single remote client vs the
same closed loop through an in-process worker-dispatched service, gated at
<= 3x p50 with a 1 ms floor on the baseline (see
``REMOTE_OVERHEAD_FLOOR_MS``: a dict-lookup baseline would make any socket
fail a pure ratio) — and (b) an aggregate multi-process block: N client
processes hammering one shared engine.
"""

import argparse
import json
import os
import subprocess
import sys
import threading
import time

import jax
import numpy as np

from repro import obs
from repro.core.graph import Graph
from repro.data.rmat import rmat_edges
from repro.serve.graph_service import (GraphService, RejectedError, Workspace)
from repro.serve.policy import (AdmissionPolicy, BatchPolicy, FairSharePolicy,
                                SchedulerPolicy)


def pctl(samples, q: float) -> float:
    """Interpolated percentile (numpy's default linear method), NaN-safe on
    empty input — at small n the interpolation estimates the tail instead
    of handing back the single worst outlier as p99."""
    xs = np.asarray(list(samples), dtype=np.float64)
    if xs.size == 0:
        return float("nan")
    return float(np.percentile(xs, q))


def latency_pctls(hist, samples):
    """(p50, p99) served from an obs histogram when it recorded the samples
    — the metrics registry is the latency source of truth now — with the
    hand-rolled interpolated :func:`pctl` kept as the fallback for runs
    where observability is disabled (the overhead measurement's off leg)
    and for degenerate histograms (quantile() returns None when all mass
    sits in the first or overflow bucket — e.g. an all-cache-hit workload
    whose sub-0.05ms latencies land entirely in the first bucket)."""
    if hist is not None and hist.count > 0:
        p50, p99 = hist.quantile(0.5), hist.quantile(0.99)
        if p50 is not None and p99 is not None:
            return p50, p99
    return pctl(samples, 50), pctl(samples, 99)


def jain_index(xs) -> float:
    """Jain's fairness index over per-session shares: 1.0 = perfectly even,
    1/n = one session took everything."""
    xs = np.asarray(list(xs), dtype=np.float64)
    if xs.size == 0 or float((xs ** 2).sum()) == 0.0:
        return 1.0
    return float(xs.sum() ** 2 / (xs.size * (xs ** 2).sum()))


def build_workload(n_sessions: int, n_rounds: int, source_pool: int):
    """Per-round request lists: deterministic mix with source reuse.

    Sessions 0..2/3 issue sssp, the rest bfs — the per-op group size stays
    constant across rounds so the vmapped fixpoint compiles once.  Sources
    rotate through a small pool (interactive users revisit the same seeds),
    and every 3rd round each session re-asks for the shared PageRank.
    """
    n_sssp = max((n_sessions * 2) // 3, 1)
    rounds = []
    for r in range(n_rounds):
        reqs = []
        for i in range(n_sessions):
            op = "sssp" if i < n_sssp else "bfs"
            src = (r * 7 + i * 3) % source_pool
            reqs.append((i, {"op": op, "graph": "g",
                             "params": {"source": int(src)}}))
            if r % 3 == 2:
                reqs.append((i, {"op": "pagerank", "graph": "g",
                                 "params": {"n_iter": 10}}))
        rounds.append(reqs)
    return rounds


def run_mode(graph, rounds, n_sessions, *, fuse: bool, cache: bool) -> dict:
    ws = Workspace()
    ws.put("g", graph)
    svc = GraphService(ws, fuse=fuse, cache=cache)
    sessions = [svc.session(f"u{i}") for i in range(n_sessions)]

    # warmup: pay jit compiles (single-source + the fused batch widths)
    for sid, req in rounds[0]:
        sessions[sid].submit(dict(req))
    svc.flush()
    for sid, req in rounds[0]:
        sessions[sid].execute(dict(req))
    warm_stats = dict(svc.stats)

    # scope the obs registry to the timed loop: end-to-end latencies land in
    # a histogram (the percentiles below read from it), and the scheduler's
    # own queued/engine histograms are reported from the same snapshot
    obs.reset()
    lat_hist = obs.histogram("bench.latency_ms")
    latencies = []
    t0 = time.perf_counter()
    n_queries = 0
    for reqs in rounds:
        pending = [sessions[sid].submit(dict(req)) for sid, req in reqs]
        svc.flush()
        for p in pending:
            p.result()
            latencies.append(p.latency_ms)
            lat_hist.observe(p.latency_ms)
        n_queries += len(pending)
    wall_s = time.perf_counter() - t0

    p50, p99 = latency_pctls(lat_hist, latencies)
    sched = {}
    snap = obs.dump_metrics()
    for key, label in (("sched.queued_ms", "queued"),
                       ("sched.engine_ms", "engine")):
        h = snap.get(key)
        if h and h.get("count"):
            for q, lab in ((0.5, "p50"), (0.99, "p99")):
                v = obs.quantile_from_snapshot(h, q)
                # None = degenerate histogram (all mass below the first
                # edge, e.g. an all-cached queue): skip rather than invent
                if v is not None:
                    sched[f"{label}_{lab}_ms"] = round(v, 3)
    for k in svc.stats:
        svc.stats[k] -= warm_stats[k]
    return {"n_queries": n_queries,
            "wall_s": round(wall_s, 4),
            "qps": round(n_queries / wall_s, 2),
            "p50_ms": round(p50, 3),
            "p99_ms": round(p99, 3),
            "sched": sched,
            "stats": dict(svc.stats)}


# ---------------------------------------------------------------------------
# observability overhead: the instrumentation must stay under 5%
# ---------------------------------------------------------------------------


def run_obs_overhead(graph, rounds, n_sessions, reps: int = 9) -> dict:
    """Fused-service workload with observability on vs off, interleaved.

    Each rep runs the fused+cached mode twice — once with the metrics
    registry + tracer + SLO/flight/profiler judgment layer enabled (the
    shipping default) and once fully disabled — alternating which leg goes
    first so thermal/JIT drift cannot systematically favor one side.  The
    gated ratio is **min over reps** of each leg: wall-clock noise on a
    shared machine is strictly additive, so the per-leg minimum is the
    best estimate of true cost (the ``timeit`` argument) — medians of
    ~1.5 s reps swing ±10% run-to-run, which a 1.05x gate cannot survive.
    Medians ride along for reference; ``ci_check.sh`` and
    ``bench_delta.py`` gate ``ratio`` at <= 1.05x.
    """
    walls = {"on": [], "off": []}
    try:
        for r in range(reps):
            order = ("on", "off") if r % 2 == 0 else ("off", "on")
            for which in order:
                (obs.enable if which == "on" else obs.disable)()
                res = run_mode(graph, rounds, n_sessions,
                               fuse=True, cache=True)
                walls[which].append(res["wall_s"])
    finally:
        obs.enable()
    on = float(min(walls["on"]))
    off = float(min(walls["off"]))
    out = {"reps": reps,
           "enabled_wall_s": walls["on"],
           "disabled_wall_s": walls["off"],
           "enabled_min_s": round(on, 4),
           "disabled_min_s": round(off, 4),
           "enabled_median_s": round(float(np.median(walls["on"])), 4),
           "disabled_median_s": round(float(np.median(walls["off"])), 4),
           "ratio": round(on / off, 4) if off > 0 else 1.0}
    print(f"obs overhead: enabled {on:.3f}s vs disabled {off:.3f}s "
          f"-> {out['ratio']}x (gate <= 1.05x)")
    return out


# ---------------------------------------------------------------------------
# overload: 1 flooding session vs N interactive, fifo vs fair share
# ---------------------------------------------------------------------------


def run_overload_mode(graph, *, mode: str, n_interactive: int,
                      queries_per_session: int, flood_quota: int,
                      source_pool: int = 64) -> dict:
    """One hostile flooding session vs N closed-loop interactive sessions.

    Fusion and caching are OFF: the comparison isolates *scheduling order*
    (every query is a real engine call in both modes).  The flood keeps its
    admission quota saturated with expensive PageRanks; each interactive
    session serially issues single-source BFS queries and waits.  Reported
    latencies are interactive submit->resolve times.
    """
    ws = Workspace()
    ws.put("g", graph)
    policy = SchedulerPolicy(
        mode=mode,
        admission=AdmissionPolicy(max_inflight=8,
                                  inflight_overrides={"flood": flood_quota}),
        fair=FairSharePolicy(quantum_ms=5.0),
        batch=BatchPolicy(window_ms=0.0))
    svc = GraphService(ws, fuse=False, cache=False, policy=policy, workers=1)

    # warmup: compile the two op shapes before any timing (several bfs
    # sources so the frontier path's size buckets are warm too)
    warm = svc.session("warm")
    warm.execute({"op": "pagerank", "graph": "g", "params": {"n_iter": 10}})
    for s in (0, 7, 19):
        warm.execute({"op": "bfs", "graph": "g", "params": {"source": s}})

    stop = threading.Event()
    flood = svc.session("flood")
    flood_submitted = [0]

    def flood_loop():
        while not stop.is_set():
            try:
                flood.submit({"op": "pagerank", "graph": "g",
                              "params": {"n_iter": 10}})
                flood_submitted[0] += 1
            except RejectedError as e:
                time.sleep(min(e.retry_after, 0.05))

    lat_by_session = {i: [] for i in range(n_interactive)}

    def interactive_loop(i):
        sess = svc.session(f"i{i}")
        for q in range(queries_per_session):
            src = (q * 13 + i * 5) % source_pool
            p = sess.submit({"op": "bfs", "graph": "g",
                             "params": {"source": int(src)}})
            p.result(timeout=600)
            lat_by_session[i].append(p.latency_ms)

    flooder = threading.Thread(target=flood_loop, daemon=True)
    flooder.start()
    time.sleep(0.4)              # let the flood build its quota-deep backlog
    t0 = time.perf_counter()
    threads = [threading.Thread(target=interactive_loop, args=(i,),
                                daemon=True) for i in range(n_interactive)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t0
    stop.set()
    flooder.join(timeout=5)
    svc.flush()                  # drain the flood's leftover backlog
    flood_stats = svc.session_stats("flood")
    svc.close()

    all_lat = [x for lats in lat_by_session.values() for x in lats]
    per_qps = [len(lats) / wall_s for lats in lat_by_session.values()]
    return {"wall_s": round(wall_s, 4),
            "interactive_p50_ms": round(pctl(all_lat, 50), 3),
            "interactive_p99_ms": round(pctl(all_lat, 99), 3),
            "per_session_p99_ms": {f"i{i}": round(pctl(lats, 99), 3)
                                   for i, lats in lat_by_session.items()},
            "fairness_index": round(jain_index(per_qps), 4),
            "flood_submitted": flood_submitted[0],
            "flood_completed": flood_stats["completed"],
            "flood_rejected": flood_stats["rejected"],
            "flood_engine_ms": flood_stats["engine_ms"]}


def run_overload(scale: int, edge_factor: int, n_interactive: int,
                 queries_per_session: int, flood_quota: int) -> dict:
    src, dst = rmat_edges(scale, edge_factor=edge_factor, seed=1)
    g = Graph.from_edges(src, dst)
    g.plan()
    out = {"scale": scale, "n_nodes": g.n_nodes, "n_edges": g.n_edges,
           "interactive_sessions": n_interactive,
           "queries_per_session": queries_per_session,
           "flood_quota": flood_quota, "modes": {}}
    for mode in ("fifo", "fair"):
        r = run_overload_mode(g, mode=mode, n_interactive=n_interactive,
                              queries_per_session=queries_per_session,
                              flood_quota=flood_quota)
        out["modes"][mode] = r
        print(f"overload/{mode:4s}  interactive p50={r['interactive_p50_ms']:8.1f}ms"
              f"  p99={r['interactive_p99_ms']:8.1f}ms"
              f"  fairness={r['fairness_index']:.3f}"
              f"  flood done/rejected={r['flood_completed']}/{r['flood_rejected']}")
    fifo99 = out["modes"]["fifo"]["interactive_p99_ms"]
    fair99 = out["modes"]["fair"]["interactive_p99_ms"]
    out["p99_improvement"] = round(fifo99 / fair99, 2) if fair99 > 0 else 0.0
    print(f"overload: fair-share interactive p99 {out['p99_improvement']}x "
          f"better than FIFO")
    return out


# ---------------------------------------------------------------------------
# remote: real server subprocess + independent client processes (ISSUE 5)
# ---------------------------------------------------------------------------


def _remote_client_loop(port: int, worker_id: int, queries: int,
                        source_pool: int) -> dict:
    """Closed-loop cached-query workload over the wire (one connection)."""
    from repro.serve.client import RemoteService

    cli = RemoteService(port=port, timeout=600.0)
    sess = cli.session("w")
    # warm: touch every source once (first toucher pays the engine call,
    # everyone else hits the shared result cache)
    for s in range(source_pool):
        sess.execute({"op": "bfs", "graph": "g", "params": {"source": s}})
    lat = []
    t0 = time.perf_counter()
    for q in range(queries):
        src = (q * 13 + worker_id * 5) % source_pool
        p = sess.submit({"op": "bfs", "graph": "g",
                         "params": {"source": int(src)}})
        p.result(timeout=600)
        lat.append(p.latency_ms)
    wall_s = time.perf_counter() - t0
    cli.close()
    return {"worker": worker_id, "wall_s": round(wall_s, 4),
            "queries": queries, "latencies_ms": lat}


def _worker_main(args) -> int:
    """Hidden subcommand: one client process of the multi-process phase."""
    out = _remote_client_loop(args.port, args.id, args.queries,
                              args.source_pool)
    with open(args.out, "w") as f:
        json.dump(out, f)
    return 0


#: a wire hop costs a fixed few hundred microseconds of framing + syscalls;
#: against an in-process cached hit (a dict lookup, ~0.05 ms) *any* socket
#: fails a pure latency ratio.  The overhead ratio therefore compares
#: against max(in-process p50, this floor): the serving contract is "the
#: wire adds at most ~a millisecond-scale constant", which the 3x gate then
#: bounds at ~3 ms absolute for sub-millisecond in-process baselines.
REMOTE_OVERHEAD_FLOOR_MS = 1.0


def run_remote(scale: int, edge_factor: int, clients: int,
               queries: int, source_pool: int) -> dict:
    """Remote serving vs in-process: cached-query overhead + multi-process
    aggregate throughput against one spawned server."""
    from repro.serve.client import RemoteService
    from repro.serve.server import spawn_server

    # -- in-process baseline: same closed cached loop through the same
    # serving configuration (worker-dispatched service, submit -> result) --
    src, dst = rmat_edges(scale, edge_factor=edge_factor, seed=0)
    g = Graph.from_edges(src, dst)
    g.plan()
    svc = GraphService(workers=1)
    svc.workspace.put("g", g)
    base = svc.session("base")
    for s in range(source_pool):
        base.execute({"op": "bfs", "graph": "g", "params": {"source": s}})
    inproc_lat = []
    for q in range(queries):
        p = base.submit({"op": "bfs", "graph": "g",
                         "params": {"source": (q * 13) % source_pool}})
        p.result(timeout=600)
        inproc_lat.append(p.latency_ms)
    svc.close()

    # -- spawn the server (same RMAT seed -> same graph) -------------------
    # generous startup deadline: on a contended single-core box the child's
    # import + graph build can be starved for minutes without being wedged
    proc, port = spawn_server(("--rmat-scale", str(scale),
                               "--edge-factor", str(edge_factor),
                               "--workers", "2"), timeout=300.0)
    outs = []
    procs = []
    try:
        # phase a: one remote client, solo -> clean wire-overhead number
        solo = _remote_client_loop(port, 0, queries, source_pool)
        remote_lat = solo["latencies_ms"]

        # phase b: N genuinely independent client processes
        bench_path = os.path.abspath(__file__)
        env = dict(os.environ)
        for i in range(clients):
            out_path = f"/tmp/bench_remote_worker_{os.getpid()}_{i}.json"
            outs.append(out_path)
            procs.append(subprocess.Popen(
                [sys.executable, bench_path, "--_worker",
                 "--port", str(port), "--id", str(i),
                 "--queries", str(queries),
                 "--source-pool", str(source_pool), "--out", out_path],
                env=env))
        t0 = time.perf_counter()
        for cp in procs:
            rc = cp.wait(timeout=900)
            assert rc == 0, f"remote client worker failed rc={rc}"
        multi_wall = time.perf_counter() - t0
        workers = []
        for out_path in outs:
            with open(out_path) as f:
                workers.append(json.load(f))
            os.unlink(out_path)
        multi_lat = [x for w in workers for x in w["latencies_ms"]]

        # ask the server to drain and exit; a clean rc is part of the bench
        cli = RemoteService(port=port)
        cli.shutdown_server()
        cli.close()
        server_rc = proc.wait(timeout=120)
    except BaseException:
        proc.kill()
        for cp in procs:               # don't leave clients spinning
            if cp.poll() is None:
                cp.kill()
        for out_path in outs:
            if os.path.exists(out_path):
                os.unlink(out_path)
        raise

    overhead = pctl(remote_lat, 50) / max(pctl(inproc_lat, 50),
                                          REMOTE_OVERHEAD_FLOOR_MS)
    out = {"scale": scale, "n_nodes": g.n_nodes, "n_edges": g.n_edges,
           "queries": queries, "source_pool": source_pool,
           "overhead_floor_ms": REMOTE_OVERHEAD_FLOOR_MS,
           "inproc_cached_p50_ms": round(pctl(inproc_lat, 50), 3),
           "inproc_cached_p99_ms": round(pctl(inproc_lat, 99), 3),
           "remote_cached_p50_ms": round(pctl(remote_lat, 50), 3),
           "remote_cached_p99_ms": round(pctl(remote_lat, 99), 3),
           "overhead_cached_p50": round(overhead, 2),
           "server_exit_code": server_rc,
           "multiproc": {
               "clients": clients,
               "queries_per_client": queries,
               "total_queries": sum(w["queries"] for w in workers),
               "wall_s": round(multi_wall, 4),
               "agg_qps": round(sum(w["queries"] for w in workers)
                                / multi_wall, 2),
               "p50_ms": round(pctl(multi_lat, 50), 3),
               "p99_ms": round(pctl(multi_lat, 99), 3),
               "per_client_qps": [round(w["queries"] / w["wall_s"], 2)
                                  for w in workers]}}
    print(f"remote: cached p50 in-process {out['inproc_cached_p50_ms']}ms "
          f"vs wire {out['remote_cached_p50_ms']}ms "
          f"-> overhead {out['overhead_cached_p50']}x "
          f"(baseline floored at {REMOTE_OVERHEAD_FLOOR_MS}ms); "
          f"{clients} client processes {out['multiproc']['agg_qps']} qps "
          f"aggregate (server rc={server_rc})")
    return out


def main():
    if "--_worker" in sys.argv:
        wp = argparse.ArgumentParser()
        wp.add_argument("--_worker", action="store_true")
        wp.add_argument("--port", type=int, required=True)
        wp.add_argument("--id", type=int, required=True)
        wp.add_argument("--queries", type=int, required=True)
        wp.add_argument("--source-pool", type=int, required=True)
        wp.add_argument("--out", required=True)
        sys.exit(_worker_main(wp.parse_args()))

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scale", type=int, default=15,
                   help="log2 nodes of the shared RMAT graph")
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--sessions", type=int, default=12)
    p.add_argument("--rounds", type=int, default=6)
    p.add_argument("--source-pool", type=int, default=16)
    p.add_argument("--obs-reps", type=int, default=9,
                   help="on/off repetitions of the obs-overhead measurement")
    p.add_argument("--overload-scale", type=int, default=13,
                   help="log2 nodes of the overload-mode RMAT graph")
    p.add_argument("--overload-sessions", type=int, default=8)
    p.add_argument("--overload-queries", type=int, default=4)
    p.add_argument("--flood-quota", type=int, default=16,
                   help="flooding session's in-flight admission quota")
    p.add_argument("--skip-overload", action="store_true")
    p.add_argument("--remote-scale", type=int, default=12,
                   help="log2 nodes of the remote-serving RMAT graph")
    p.add_argument("--remote-clients", type=int, default=3,
                   help="independent client OS processes in the remote "
                        "multi-process phase")
    p.add_argument("--remote-queries", type=int, default=60,
                   help="cached queries per client in the remote phases")
    p.add_argument("--remote-source-pool", type=int, default=8)
    p.add_argument("--skip-remote", action="store_true")
    p.add_argument("--out", default="BENCH_service.json")
    args = p.parse_args()

    src, dst = rmat_edges(args.scale, edge_factor=args.edge_factor, seed=0)
    g = Graph.from_edges(src, dst)
    g.plan()   # shared plan build paid once, like a workspace-resident graph
    rounds = build_workload(args.sessions, args.rounds, args.source_pool)

    modes = {
        "sequential": dict(fuse=False, cache=False),
        "fused": dict(fuse=True, cache=False),
        "fused_cached": dict(fuse=True, cache=True),
    }
    results = {"device": jax.default_backend(), "scale": args.scale,
               "n_nodes": g.n_nodes, "n_edges": g.n_edges,
               "sessions": args.sessions, "rounds": args.rounds,
               "source_pool": args.source_pool, "modes": {}}
    for name, kw in modes.items():
        r = run_mode(g, rounds, args.sessions, **kw)
        results["modes"][name] = r
        print(f"{name:13s} {r['n_queries']:4d} queries  {r['qps']:8.1f} qps"
              f"  p50={r['p50_ms']:8.2f}ms  p99={r['p99_ms']:8.2f}ms"
              f"  (hits={r['stats']['cache_hits']}, "
              f"fused={r['stats']['fused_requests']})")

    results["obs_overhead"] = run_obs_overhead(g, rounds, args.sessions,
                                               reps=args.obs_reps)

    base = results["modes"]["sequential"]["qps"]
    results["speedup_fused"] = round(results["modes"]["fused"]["qps"] / base, 2)
    results["speedup_fused_cached"] = round(
        results["modes"]["fused_cached"]["qps"] / base, 2)
    print(f"speedup: fused {results['speedup_fused']}x, "
          f"fused+cached {results['speedup_fused_cached']}x vs sequential")

    if not args.skip_overload:
        results["overload"] = run_overload(
            args.overload_scale, args.edge_factor, args.overload_sessions,
            args.overload_queries, args.flood_quota)

    if not args.skip_remote:
        results["remote"] = run_remote(
            args.remote_scale, args.edge_factor, args.remote_clients,
            args.remote_queries, args.remote_source_pool)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
