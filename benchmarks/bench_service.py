"""Interactive service benchmark — concurrent-session query throughput and
latency -> BENCH_service.json.

Simulates the paper's multi-analyst trial-and-error loop against one shared
RMAT graph (default 2^15 nodes): every round, each session issues one
single-source traversal (sssp or bfs) from a small rotating source pool plus
periodic PageRank re-runs, exactly the redundancy profile of interactive
exploration.  The workload runs three ways:

    sequential    fusion off, cache off — every query is its own engine call
    fused         the scheduler coalesces each round's single-source queries
                  into one vmapped multi-source fixpoint
    fused_cached  fusion + the versioned result cache (repeat queries free)

and records throughput (qps) and per-query p50/p99 latency for each.  The
accept gate for the service subsystem is fused_cached >= 2x sequential
throughput on the same workload.
"""

import argparse
import json
import time

import jax
import numpy as np

from repro.core.graph import Graph
from repro.data.rmat import rmat_edges
from repro.serve.graph_service import GraphService, Workspace


def build_workload(n_sessions: int, n_rounds: int, source_pool: int):
    """Per-round request lists: deterministic mix with source reuse.

    Sessions 0..2/3 issue sssp, the rest bfs — the per-op group size stays
    constant across rounds so the vmapped fixpoint compiles once.  Sources
    rotate through a small pool (interactive users revisit the same seeds),
    and every 3rd round each session re-asks for the shared PageRank.
    """
    n_sssp = max((n_sessions * 2) // 3, 1)
    rounds = []
    for r in range(n_rounds):
        reqs = []
        for i in range(n_sessions):
            op = "sssp" if i < n_sssp else "bfs"
            src = (r * 7 + i * 3) % source_pool
            reqs.append((i, {"op": op, "graph": "g",
                             "params": {"source": int(src)}}))
            if r % 3 == 2:
                reqs.append((i, {"op": "pagerank", "graph": "g",
                                 "params": {"n_iter": 10}}))
        rounds.append(reqs)
    return rounds


def run_mode(graph, rounds, n_sessions, *, fuse: bool, cache: bool) -> dict:
    ws = Workspace()
    ws.put("g", graph)
    svc = GraphService(ws, fuse=fuse, cache=cache)
    sessions = [svc.session(f"u{i}") for i in range(n_sessions)]

    # warmup: pay jit compiles (single-source + the fused batch widths)
    for sid, req in rounds[0]:
        sessions[sid].submit(dict(req))
    svc.flush()
    for sid, req in rounds[0]:
        sessions[sid].execute(dict(req))
    warm_stats = dict(svc.stats)

    latencies = []
    t0 = time.perf_counter()
    n_queries = 0
    for reqs in rounds:
        pending = [sessions[sid].submit(dict(req)) for sid, req in reqs]
        svc.flush()
        for p in pending:
            p.result()
            latencies.append(p.latency_ms)
        n_queries += len(pending)
    wall_s = time.perf_counter() - t0

    lat = np.asarray(latencies)
    for k in svc.stats:
        svc.stats[k] -= warm_stats[k]
    return {"n_queries": n_queries,
            "wall_s": round(wall_s, 4),
            "qps": round(n_queries / wall_s, 2),
            "p50_ms": round(float(np.percentile(lat, 50)), 3),
            "p99_ms": round(float(np.percentile(lat, 99)), 3),
            "stats": dict(svc.stats)}


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scale", type=int, default=15,
                   help="log2 nodes of the shared RMAT graph")
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--sessions", type=int, default=12)
    p.add_argument("--rounds", type=int, default=6)
    p.add_argument("--source-pool", type=int, default=16)
    p.add_argument("--out", default="BENCH_service.json")
    args = p.parse_args()

    src, dst = rmat_edges(args.scale, edge_factor=args.edge_factor, seed=0)
    g = Graph.from_edges(src, dst)
    g.plan()   # shared plan build paid once, like a workspace-resident graph
    rounds = build_workload(args.sessions, args.rounds, args.source_pool)

    modes = {
        "sequential": dict(fuse=False, cache=False),
        "fused": dict(fuse=True, cache=False),
        "fused_cached": dict(fuse=True, cache=True),
    }
    results = {"device": jax.default_backend(), "scale": args.scale,
               "n_nodes": g.n_nodes, "n_edges": g.n_edges,
               "sessions": args.sessions, "rounds": args.rounds,
               "source_pool": args.source_pool, "modes": {}}
    for name, kw in modes.items():
        r = run_mode(g, rounds, args.sessions, **kw)
        results["modes"][name] = r
        print(f"{name:13s} {r['n_queries']:4d} queries  {r['qps']:8.1f} qps"
              f"  p50={r['p50_ms']:8.2f}ms  p99={r['p99_ms']:8.2f}ms"
              f"  (hits={r['stats']['cache_hits']}, "
              f"fused={r['stats']['fused_requests']})")

    base = results["modes"]["sequential"]["qps"]
    results["speedup_fused"] = round(results["modes"]["fused"]["qps"] / base, 2)
    results["speedup_fused_cached"] = round(
        results["modes"]["fused_cached"]["qps"] / base, 2)
    print(f"speedup: fused {results['speedup_fused']}x, "
          f"fused+cached {results['speedup_fused_cached']}x vs sequential")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
