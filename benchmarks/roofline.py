"""Roofline analysis (deliverable (g)) over the dry-run JSONs.

Per (arch × shape × mesh):
    compute    = FLOPs_per_device / peak_FLOPs            (197 TF/s bf16)
    memory     = HBM_bytes_per_device / HBM_bw            (819 GB/s)
    collective = Σ collective_bytes_per_device / link_bw  (~50 GB/s/link;
                 ICI is bidirectional per axis — we charge the naive
                 single-link rate, a conservative upper bound)

FLOPs/bytes come from the scan-corrected HLO cost model (hlo_cost.py): XLA's
cost_analysis counts while bodies once, which would understate 36–94-layer
models by that factor.  MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for
train cells; 2·N(+attn) per token for serve cells.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--tag baseline] [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

HERE = os.path.dirname(os.path.abspath(__file__))
RESULTS = os.path.join(HERE, "dryrun_results")


def model_flops_for(rec: Dict) -> float:
    """Ideal model FLOPs for the whole step (global, not per-device)."""
    kind = rec.get("kind", "train")
    n_active = rec.get("active_params", rec.get("params", 0))
    shape = rec["shape"]
    toks = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
            "decode_32k": 128, "long_500k": 1}.get(shape, 0)
    if kind == "train":
        return 6.0 * n_active * toks
    if kind == "prefill":
        return 2.0 * n_active * toks
    if kind == "decode":
        return 2.0 * n_active * toks
    if kind == "graph":
        g = rec.get("graph", {})
        # PageRank SpMV: 2 flops/edge + damping per node
        return 2.0 * g.get("n_edges", 0) + 3.0 * g.get("n_nodes", 0)
    return 0.0


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n = rec["n_chips"]
    fl = rec["flops_per_device"]
    by = rec["bytes_per_device"]
    coll = sum(rec.get("collective_bytes_per_device", {}).values())
    compute_s = fl / PEAK_FLOPS
    memory_s = by / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_for(rec)
    ratio = mf / (fl * n) if fl else 0.0
    # roofline fraction: useful model flops per the time the dominant term
    # implies (how close the step is to the compute roofline)
    step_time = max(terms.values())
    mfu = (mf / n) / (step_time * PEAK_FLOPS) if step_time > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "multi" if rec["multi_pod"] else "single",
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": fl * n,
        "useful_ratio": ratio, "roofline_frac": mfu,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "compile_s": rec.get("compile_s", 0.0),
    }


def load(tag: str, mesh: Optional[str] = None) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"{tag}.*.json"))):
        rec = json.load(open(f))
        row = analyze(rec)
        if row is None:
            continue
        if mesh and row["mesh"] != mesh:
            continue
        rows.append(row)
    return rows


def fmt_row(r: Dict) -> str:
    return (f"{r['arch']:26s} {r['shape']:13s} {r['mesh']:6s} "
            f"{r['compute_s']*1e3:11.2f} {r['memory_s']*1e3:11.2f} "
            f"{r['collective_s']*1e3:11.2f} {r['dominant']:10s} "
            f"{r['useful_ratio']:7.3f} {r['roofline_frac']*100:6.1f}% "
            f"{r['peak_gib']:7.2f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--json", default=None, help="also dump rows to JSON")
    args = ap.parse_args(argv)
    rows = load(args.tag, args.mesh)
    hdr = (f"{'arch':26s} {'shape':13s} {'mesh':6s} {'compute_ms':>11s} "
           f"{'memory_ms':>11s} {'collect_ms':>11s} {'dominant':10s} "
           f"{'useful':>7s} {'RLfrac':>7s} {'peakGiB':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(fmt_row(r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
