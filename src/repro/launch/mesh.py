"""Production mesh construction (assignment spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py) set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import when they need placeholder devices.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "DATA_AXES"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod mesh, or 2×16×16 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Small mesh over the actually-present devices (tests/examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def DATA_AXES(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
