"""Production mesh construction (assignment spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py) set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import when they need placeholder devices.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_host_mesh", "DATA_AXES",
           "GRAPH_AXIS", "graph_mesh"]

#: mesh axis name used by the graph engine's 1-D vertex-range partition
#: (``core.plan.sharded`` / ``core.engine.ShardedExec`` /
#: ``core.distributed``)
GRAPH_AXIS = "gp"


@functools.lru_cache(maxsize=None)
def graph_mesh(n_shards: Optional[int] = None, axis: str = GRAPH_AXIS):
    """Cached 1-D mesh over the first ``n_shards`` devices.

    The graph engine's ``"sharded"`` backend partitions vertex ranges
    along a single mesh axis; every exec for the same shard count reuses
    the same ``Mesh`` object (it is hashable and participates in jit
    cache keys, so identity reuse keeps compiled runners warm).

    ``n_shards=None`` means all visible devices.  Raises when more
    shards are requested than devices exist — on CPU-only hosts set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the
    first jax import to simulate an N-device mesh.
    """
    devs = jax.devices()
    n = len(devs) if n_shards is None else int(n_shards)
    if n < 1:
        raise ValueError(f"graph_mesh needs >= 1 shard, got {n}")
    if n > len(devs):
        raise ValueError(
            f"graph_mesh({n}) but only {len(devs)} device(s) visible; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before importing jax to simulate a host mesh")
    return jax.make_mesh((n,), (axis,), devices=np.asarray(devs[:n]))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod mesh, or 2×16×16 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Small mesh over the actually-present devices (tests/examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def DATA_AXES(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
