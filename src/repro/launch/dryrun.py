import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count at first init.
# (This also means: no `from __future__ import annotations` in this module.)

"""Multi-pod dry-run driver (assignment deliverable (e)).


For every (architecture × input shape × mesh) cell:
    lowered  = jax.jit(step_fn).lower(*input_specs(...))
    compiled = lowered.compile()
    record memory_analysis() + cost_analysis() + collective bytes

Meshes: single-pod 16×16 ("data","model") and two-pod 2×16×16
("pod","data","model").  Kinds per shape: train_4k -> train_step,
prefill_32k -> prefill, decode_32k / long_500k -> serve (decode) step.

Results are cached as JSON under --out so the full sweep is resumable;
`--all` iterates cells in-process, the Makefile-style sweep in
benchmarks/run_dryruns.sh uses one subprocess per cell for isolation.
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from ..configs import base as cfgbase
from ..launch import sharding as shlib
from ..launch import specs as specs_mod
from ..launch.mesh import make_production_mesh
from ..models import transformer as model
from . import hlo_cost
from ..train.optimizer import OptHyper
from ..train.step import make_train_step

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*(\([^)]*\)|\S+)\s")
SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)"
                      r"\[([0-9,]*)\]")
BYTES_OF = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
            "pred": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8,
            "u64": 8}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-operand bytes of every collective op in the (post-SPMD)
    HLO.  Per-device numbers, like cost_analysis."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^\s(]+)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", stripped)
        if not m:
            continue
        shapes_blob, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(shapes_blob):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * BYTES_OF[dt]
        out[op] = out.get(op, 0.0) + float(nbytes)
    return out


def step_fn_for(cfg, kind: str, *, attn_chunk: int = 1024,
                skip_upper_triangle: bool = True):
    if kind == "train":
        return make_train_step(cfg, OptHyper(), attn_chunk=attn_chunk,
                               skip_upper_triangle=skip_upper_triangle)
    if kind == "prefill":
        def prefill_step(params, batch):
            max_seq = batch["tokens"].shape[1] + (cfg.n_patches or 0)
            return model.prefill(params, cfg, batch, max_seq=max_seq,
                                 chunk=attn_chunk)
        return prefill_step
    if kind == "decode":
        if cfg.is_encoder_decoder:
            def serve_step(params, cache, tokens, pos, enc_out):
                return model.decode_step(params, cfg, cache, tokens, pos,
                                         enc_out=enc_out)
        else:
            def serve_step(params, cache, tokens, pos):
                return model.decode_step(params, cfg, cache, tokens, pos)
        return serve_step
    raise ValueError(kind)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             attn_chunk: int = 1024, skip_upper_triangle: bool = True,
             want_hlo: bool = False, moe_impl: str = None,
             overrides: Dict = None) -> Dict:
    import dataclasses
    cfg = cfgbase.get_config(arch)
    if moe_impl:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if arch == "ringo-graph":
        from .ringo_cells import run_ringo_cell
        return run_ringo_cell(shape_name, multi_pod)
    shape = cfgbase.runnable_shapes(cfg).get(shape_name)
    if shape is None:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind
    t0 = time.time()
    rules, args = specs_mod.input_specs(cfg, shape, mesh, kind)
    fn = step_fn_for(cfg, kind, attn_chunk=attn_chunk,
                     skip_upper_triangle=skip_upper_triangle)
    with mesh, shlib.rules_ctx(rules):
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = hlo_cost.xla_cost_dict(compiled)
    hlo = compiled.as_text()
    # scan-corrected cost model (while bodies × trip counts) — see hlo_cost
    corrected = hlo_cost.analyze_hlo(hlo)
    n_chips = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "multi_pod": multi_pod, "status": "ok",
        "n_chips": int(n_chips),
        "compile_s": round(t1 - t0, 1),
        # raw XLA numbers (while bodies counted once — understated)
        "xla_flops_per_device": float(cost.get("flops", 0.0)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        # trip-count-corrected numbers (used by §Roofline)
        "flops_per_device": corrected.flops,
        "bytes_per_device": corrected.bytes,
        "collective_bytes_per_device": corrected.collective_bytes,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0) or
            (getattr(mem, "argument_size_in_bytes", 0)
             + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "options": {"attn_chunk": attn_chunk,
                    "skip_upper_triangle": skip_upper_triangle},
    }
    if want_hlo:
        result["hlo"] = hlo
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="benchmarks/dryrun_results")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--no-triangle-skip", action="store_true",
                    help="baseline attention: full rectangular chunk loop")
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "sorted", "expert_tp"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = ([args.arch] if args.arch else
             [a for a in cfgbase.list_archs() if a != "ringo-graph"])
    shapes = [args.shape] if args.shape else list(cfgbase.SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.list:
        for a in archs:
            cfg = cfgbase.get_config(a)
            runnable = list(cfgbase.runnable_shapes(cfg)) \
                if a != "ringo-graph" else ["pagerank_twitter",
                                            "pagerank_livejournal"]
            skipped = [s for s in cfgbase.SHAPES if s not in runnable]
            print(f"{a:26s} runs={runnable} skips={skipped}")
        return 0

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                fname = os.path.join(
                    args.out,
                    f"{args.tag}.{arch}.{shape}.{mesh_name}.json")
                if os.path.exists(fname) and not args.force:
                    print(f"[dryrun] cached {fname}")
                    continue
                try:
                    res = run_cell(arch, shape, mp,
                                   attn_chunk=args.attn_chunk,
                                   skip_upper_triangle=not args.no_triangle_skip,
                                   moe_impl=args.moe_impl)
                except Exception as e:  # record failures, keep sweeping
                    res = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                with open(fname, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    extra = (f" flops/dev={res['flops_per_device']:.3e}"
                             f" peak={res['memory']['peak_bytes']/2**30:.2f}GiB"
                             f" compile={res['compile_s']}s")
                print(f"[dryrun] {arch} × {shape} × {mesh_name}: {status}{extra}")
                if status == "error":
                    print(res["error"])
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
