"""Elastic / fault-tolerant coordination logic (DESIGN.md §5).

Pure, unit-testable decision logic for a 1000+-node deployment:

* **heartbeats** — workers report per-step wall time; a worker silent for
  ``dead_after`` seconds is declared dead;
* **straggler mitigation** — workers slower than ``straggler_factor × p50``
  over a sliding window are flagged; the planner first reroutes their data
  shards (skip-and-rebalance), then evicts persistent offenders;
* **re-mesh planning** — on a capacity change the planner picks the largest
  data-parallel degree that divides the surviving host count while keeping
  the model axis intact (TP groups must stay whole — a dead host kills its
  whole TP group), and signals a checkpoint-restore boundary.

The runtime side (launch/train.py) consumes plans: it checkpoints on
``plan.restart_required`` and reinitializes the mesh with ``plan.mesh_shape``.
In this single-process container the coordinator is exercised by unit tests
and a simulated-failure integration test.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["ElasticCoordinator", "RemeshPlan"]


@dataclass
class RemeshPlan:
    restart_required: bool
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    dropped_workers: Tuple[int, ...] = ()
    reason: str = ""


@dataclass
class WorkerState:
    last_seen: Optional[float] = None   # None = never heard from
    step_times: List[float] = field(default_factory=list)
    flagged: int = 0


class ElasticCoordinator:
    """Tracks worker health; plans meshes for the survivors."""

    def __init__(self, n_workers: int, hosts_per_tp_group: int,
                 dead_after: float = 60.0, straggler_factor: float = 1.5,
                 window: int = 20, evict_after_flags: int = 3):
        self.n_workers = n_workers
        self.tp = hosts_per_tp_group
        self.dead_after = dead_after
        self.straggler_factor = straggler_factor
        self.window = window
        self.evict_after_flags = evict_after_flags
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState() for i in range(n_workers)}

    # -- ingestion ---------------------------------------------------------
    def heartbeat(self, worker: int, step_time: float,
                  now: Optional[float] = None) -> None:
        w = self.workers.get(worker)
        if w is None:
            return
        w.last_seen = time.monotonic() if now is None else now
        w.step_times.append(step_time)
        if len(w.step_times) > self.window:
            w.step_times.pop(0)

    # -- analysis -----------------------------------------------------------
    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [i for i, w in self.workers.items()
                if w.last_seen is not None
                and now - w.last_seen > self.dead_after]

    def stragglers(self) -> List[int]:
        med = self._median_step_time()
        if med is None:
            return []
        out = []
        for i, w in self.workers.items():
            if len(w.step_times) >= 3:
                mine = sorted(w.step_times)[len(w.step_times) // 2]
                if mine > self.straggler_factor * med:
                    w.flagged += 1
                    out.append(i)
        return out

    def _median_step_time(self) -> Optional[float]:
        all_t = [sorted(w.step_times)[len(w.step_times) // 2]
                 for w in self.workers.values() if len(w.step_times) >= 3]
        if not all_t:
            return None
        return sorted(all_t)[len(all_t) // 2]

    # -- planning -----------------------------------------------------------
    def plan(self, now: Optional[float] = None) -> RemeshPlan:
        dead = set(self.dead_workers(now))
        evict = {i for i, w in self.workers.items()
                 if w.flagged >= self.evict_after_flags}
        dropped = sorted(dead | evict)
        alive = self.n_workers - len(dropped)
        if not dropped:
            return RemeshPlan(False, self._shape(self.n_workers),
                              self._axes(), (), "healthy")
        # keep TP groups whole: a lost worker drops its whole group
        groups_lost = {d // self.tp for d in dropped}
        alive_groups = self.n_workers // self.tp - len(groups_lost)
        if alive_groups < 1:
            return RemeshPlan(True, (0,), ("data",), tuple(dropped),
                              "no surviving TP group")
        # largest power-of-two data degree that fits the surviving groups
        dp = 1
        while dp * 2 <= alive_groups:
            dp *= 2
        for d in dropped:
            self.workers.pop(d, None)
        self.n_workers = alive
        return RemeshPlan(True, (dp, self.tp), ("data", "model"),
                          tuple(dropped),
                          f"lost {len(dropped)} workers; dp -> {dp}")

    def _shape(self, n: int) -> Tuple[int, ...]:
        return (n // self.tp, self.tp)

    def _axes(self) -> Tuple[str, ...]:
        return ("data", "model")
