"""Logical-axis sharding rules (MaxText-style) for the LM stack.

Model code annotates activations with *logical* axes ("batch", "heads", …);
the launcher installs a rule set mapping logical → mesh axes for the current
mesh.  Parameters get PartitionSpecs by path-pattern rules over the pytree.

Default production mapping (DESIGN.md §5):
  batch    -> ("pod", "data")     data parallel over pods × data axis
  heads/ff/vocab/experts -> "model"  tensor/expert parallel
  kv_seq   -> "data" for the 500k sequence-sharded decode path (batch=1
              frees the data axis; flash-decode combines partial softmaxes)
"""

from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LogicalRules", "default_rules", "rules_ctx", "shard", "logical_to_spec",
    "param_specs", "current_rules",
    "graph_shard_spec", "graph_replicated_spec",
]


# ---------------------------------------------------------------------------
# graph-engine shardings
# ---------------------------------------------------------------------------
# The graph side of the repo (core.plan / core.engine / core.distributed)
# lays every per-shard array out as a flat (d * per_shard,) buffer and
# range-partitions it along the mesh's single graph axis.  These two
# helpers are the only NamedShardings the graph engine constructs, so the
# placement convention lives in one spot.

def graph_shard_spec(mesh: Mesh, axis: str = "gp") -> NamedSharding:
    """Row sharding for flat ``(d * per_shard,)`` graph-engine buffers."""
    return NamedSharding(mesh, P(axis))


def graph_replicated_spec(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement on the graph mesh (scalars, small refs)."""
    return NamedSharding(mesh, P())

_state = threading.local()


class LogicalRules:
    def __init__(self, mapping: Dict[str, Any], mesh: Optional[Mesh] = None):
        self.mapping = dict(mapping)
        self.mesh = mesh

    def spec(self, logical: Sequence[Optional[str]]) -> P:
        axes = []
        for ax in logical:
            m = self.mapping.get(ax) if ax is not None else None
            axes.append(m)
        return P(*axes)


def default_rules(mesh: Optional[Mesh] = None, *, multi_pod: bool = False,
                  kv_seq_axis=None,
                  expert_axis_parallel: bool = True,
                  two_d_weights: bool = False) -> LogicalRules:
    """Logical -> mesh axis mapping.

    two_d_weights: additionally shard every weight's d_model dim over the
    data axis (FSDP/ZeRO-3 semantics under GSPMD) — required for the ≥300B
    archs whose TP-sharded weights alone exceed per-chip HBM (DESIGN.md §5).
    expert_axis_parallel: EP over "model" when n_experts divides; otherwise
    experts replicate and the expert FFN dim takes the TP axis (grok: 8
    experts < 16-way model axis).
    kv_seq_axis: shard the decode KV cache on its sequence dim — "model"
    for decode_32k (kv heads < model degree), ("data","model") for the
    batch=1 500k cells (flash-decode combine happens via GSPMD collectives).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    mapping: Dict[str, Any] = {
        "batch": dp,
        "seq": None,
        "embed": None,                      # activations: never sharded on d
        "w_embed": "data" if two_d_weights else None,   # weights' d_model dim
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "vocab": "model",
        # EP: experts take the model axis (w_embed covers data when 2D);
        # otherwise the per-expert FFN dim takes the TP axis
        "experts": "model" if expert_axis_parallel else None,
        "expert_ff": None if expert_axis_parallel else "model",
        "kv_seq": kv_seq_axis,
        "ssm_inner": "model",
        "state": None,
        "layers": None,
        "frames": None,
    }
    return LogicalRules(mapping, mesh)


def current_rules() -> Optional[LogicalRules]:
    return getattr(_state, "rules", None)


@contextmanager
def rules_ctx(rules: LogicalRules):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def logical_to_spec(logical: Sequence[Optional[str]]) -> Optional[P]:
    r = current_rules()
    if r is None:
        return None
    return r.spec(logical)


def shard(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Constrain activation sharding to the logical axes (no-op w/o rules)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.spec(logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, spec))


# ---------------------------------------------------------------------------
# parameter specs by path pattern
# ---------------------------------------------------------------------------

# Patterns are matched (re.search) against '/'-joined tree paths.  First hit
# wins; trailing dims map right-aligned so stacked (L, ...) leaves work
# unchanged.  These names must track the init_* functions in models/.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / unembedding
    (r"embed/tok/table", ("vocab", "w_embed")),
    (r"embed/pos/table", (None, "w_embed")),
    (r"lm_head/table", ("vocab", "w_embed")),
    # attention
    (r".*attn/wq/w", ("w_embed", "heads")),
    (r".*attn/wk/w", ("w_embed", "kv_heads")),
    (r".*attn/wv/w", ("w_embed", "kv_heads")),
    (r".*attn/wo/w", ("heads", "w_embed")),
    (r".*attn/w[qkv]/b", ("heads",)),
    (r".*attn/wo/b", ("w_embed",)),
    # dense mlp
    (r"mlp/w[ig]/w", ("w_embed", "ff")),
    (r"mlp/wo/w", ("ff", "w_embed")),
    (r"mlp/w[igo]/b", (None,)),
    # MoE
    (r"moe/router/w", ("w_embed", None)),
    (r"moe/w[ig]$", ("experts", "w_embed", "expert_ff")),
    (r"moe/wo$", ("experts", "expert_ff", "w_embed")),
    # mamba
    (r"mamba/in_proj/w", ("w_embed", "ssm_inner")),
    (r"mamba/gate_proj/w", ("w_embed", "ssm_inner")),
    (r"mamba/out_proj/w", ("ssm_inner", "w_embed")),
    (r"mamba/conv_w", (None, "ssm_inner")),
    (r"mamba/(x_proj_b|x_proj_c|x_proj_dt)/w", ("ssm_inner", None)),
    (r"mamba/(dt_bias|a_log|d_skip)", ("ssm_inner",)),
    # xlstm
    (r"b\d+_(mlstm|slstm)/(wq|wk|wv|wi|wf|wo_gate|wz)/w",
     ("w_embed", "ssm_inner")),
    (r"b\d+_(mlstm|slstm)/(wq|wk|wv|wi|wf|wo_gate|wz)/b", ("ssm_inner",)),
    (r"b\d+_(mlstm|slstm)/r_h/w", (None, "ssm_inner")),
    (r"b\d+_(mlstm|slstm)/proj_out/w", ("ssm_inner", "w_embed")),
    # norms & scalars: replicated
    (r".*(norm|ln)[^/]*/(scale|bias)", ()),
    (r".*", ()),  # fallback: replicate
)


def param_specs(params: Any, rules: LogicalRules) -> Any:
    """PartitionSpec pytree mirroring ``params`` via path-pattern rules."""

    def leaf_spec(path, leaf):
        pathstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
        for pat, logical in _PARAM_RULES:
            if re.search(pat, pathstr):
                if not logical:
                    return P()
                spec_axes = list(rules.spec(logical))
                # right-align for stacked layer leading dims
                extra = leaf.ndim - len(spec_axes)
                if extra < 0:   # scalar-ish leaf vs wide rule
                    spec_axes = spec_axes[-leaf.ndim:] if leaf.ndim else []
                    extra = 0
                return P(*([None] * extra + spec_axes))
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)
