"""HLO-text cost model with while-loop trip-count accounting.

``compiled.cost_analysis()`` counts each while-loop body **once**, but our
layers run under `lax.scan` (and attention/Mamba/xLSTM scan internally), so
raw numbers understate FLOPs/bytes/collective-bytes by the trip counts.
This walker parses the post-SPMD HLO, builds the computation call graph,
extracts each while's trip count from its condition (`compare(iv, const),
direction=LT` — the shape `lax.scan` lowers to), and accumulates:

  flops            — 2·numel(out)·K over every `dot` (batch dims included
                     via numel(out)); convolutions are absent from our
                     models (the causal conv lowers to multiplies).
  bytes            — Σ (operand + output bytes) of every op in non-fused
                     computations; fusion internals are skipped (the fusion
                     op's own operands/outputs are the HBM traffic).
  collective bytes — output bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute.

All numbers are per-device (the module is the per-device SPMD program).
Validated against cost_analysis on scan-free functions in tests.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost", "xla_cost_dict"]


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions.

    Older jax returns one dict per device in a list; newer returns a single
    dict.  Always yields a (possibly empty) dict.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
                "pred": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
                "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")


def _parse_op_line(line: str):
    """Tokenize `[ROOT] %name = TYPE opcode(args), attrs`.

    TYPE may be a tuple containing `/*index=N*/` comments (which contain
    '='), so a paren-balance walk is the only robust parse.
    """
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        out_blob = rest[:end + 1]
        rest = rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        out_blob = rest[:sp]
        rest = rest[sp + 1:].lstrip()
    m2 = _OPCODE_RE.match(rest)
    if not m2:
        return None
    opcode = m2.group(1)
    depth = 0
    start = m2.end() - 1
    end = len(rest) - 1
    for j in range(start, len(rest)):
        ch = rest[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    argstr = rest[start + 1:end]
    attrs = rest[end + 1:]
    return name, out_blob, opcode, argstr, attrs


def _shape_bytes(blob: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(blob):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(blob: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(blob)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    out_blob: str
    opcode: str
    args: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # symbol -> blob
    is_fused: bool = False


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    transcendentals: float = 0.0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    current: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.rstrip().endswith("{"):
            name = hdr.group(1)
            current = Computation(name=name,
                                  is_fused=name.startswith("fused_"))
            comps[name] = current
            if line.lstrip().startswith("ENTRY"):
                entry = name
            # parameters declared in the header: "%p.1: f32[4,4]"
            for pname, pblob in re.findall(r"%?([\w.\-]+):\s*([^,)]+)",
                                           hdr.group(2)):
                current.shapes[pname] = pblob
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, out_blob, opcode, argstr, attrs = parsed
        args = [a.strip().lstrip("%") for a in _split_args(argstr)]
        current.ops.append(Op(name, out_blob, opcode, args, attrs))
        current.shapes[name] = out_blob
    return comps, entry


def _split_args(argstr: str) -> List[str]:
    """Split top-level commas (shapes contain commas inside brackets)."""
    out, depth, cur = [], 0, []
    for ch in argstr:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    # each arg looks like "bf16[2,3]{1,0} %name" or "%name"
    names = []
    for a in out:
        a = a.strip()
        mm = re.search(r"%([\w.\-]+)\s*$", a)
        names.append(mm.group(1) if mm else a)
    return names


def _arg_shape_blob(comp: Computation, arg: str) -> str:
    return comp.shapes.get(arg, "")


def _dot_flops(comp: Computation, op: Op) -> float:
    out = _shape_dims(op.out_blob)
    if out is None:
        return 0.0
    _, out_dims = out
    numel_out = 1
    for d in out_dims:
        numel_out *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    k = 1
    if m and op.args:
        lhs_blob = _arg_shape_blob(comp, op.args[0])
        lhs = _shape_dims(lhs_blob)
        if lhs is not None:
            _, ldims = lhs
            for idx in m.group(1).split(","):
                if idx != "" and int(idx) < len(ldims):
                    k *= ldims[int(idx)]
    return 2.0 * numel_out * k


_TRIP_CONST_RE = re.compile(r"constant\((\d+)\)")


def _while_trips(comps: Dict[str, Computation], cond_name: str) -> float:
    """Fallback when backend_config lacks known_trip_count: find a
    comparison against a constant in the condition (descending into the
    wrapped fusion computations XLA emits)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1.0
    consts: Dict[str, int] = {}
    compare_ops: List[Op] = []

    def scan_comp(c: Computation):
        for op in c.ops:
            if op.opcode == "constant":
                mm = re.search(r"constant\((\d+)\)",
                               f"constant({op.args[0]})" if op.args
                               else (op.attrs or ""))
                if mm:
                    consts[op.name] = int(mm.group(1))
            elif op.opcode == "compare":
                compare_ops.append(op)
            elif op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m and m.group(1) in comps:
                    # map fusion args through to see the constant operands
                    for a in op.args:
                        if a in consts:
                            consts[f"__arg_{m.group(1)}"] = consts[a]
                    scan_comp(comps[m.group(1)])

    scan_comp(cond)
    # prefer LT comparisons with a known constant anywhere in the cond
    candidates = [v for k, v in consts.items()]
    if candidates and compare_ops:
        return float(max(candidates))
    return 1.0


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   # control ops alias their carried buffers in place; the
                   # loop body accounts for the actual reads/writes
                   "while", "conditional", "call", "optimization-barrier"}


def _comp_cost(comps: Dict[str, Computation], name: str,
               memo: Dict[str, HloCost]) -> HloCost:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    cost = HloCost()
    memo[name] = cost
    if comp is None:
        return cost
    for op in comp.ops:
        if op.opcode == "dot":
            cost.flops += _dot_flops(comp, op)
        elif op.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            if m:
                inner = _fused_flops(comps, m.group(1), memo)
                cost.flops += inner
            if not comp.is_fused:
                cost.bytes += _op_bytes(comp, op)
        elif op.opcode == "while":
            mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
            mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
            # XLA annotates the trip count it proved:
            mt = re.search(r'"known_trip_count":\{"n":"(\d+)"', op.attrs)
            if mt:
                trips = float(mt.group(1))
            elif mc:
                trips = _while_trips(comps, mc.group(1))
            else:
                trips = 1.0
            if mb:
                cost.add(_comp_cost(comps, mb.group(1), memo), trips)
            if mc:
                cost.add(_comp_cost(comps, mc.group(1), memo), trips)
        elif op.opcode in ("call", "async-start"):
            m = re.search(r"(?:to_apply|called_computation)=%?([\w.\-]+)",
                          op.attrs)
            if m:
                cost.add(_comp_cost(comps, m.group(1), memo), 1.0)
        elif op.opcode == "conditional":
            for m in re.finditer(r"(?:true_computation|false_computation|"
                                 r"branch_computations=\{)([\w.,\- %]+)",
                                 op.attrs):
                for b in re.findall(r"%?([\w.\-]+)", m.group(1)):
                    cost.add(_comp_cost(comps, b, memo), 1.0)
        if op.opcode in _COLLECTIVES or \
                any(op.opcode == c + "-start" for c in _COLLECTIVES):
            key = op.opcode.replace("-start", "")
            nbytes = _shape_bytes(op.out_blob)
            cost.collective_bytes[key] = cost.collective_bytes.get(key, 0.0) \
                + nbytes
        if not comp.is_fused and op.opcode not in _SKIP_BYTES_OPS and \
                op.opcode != "fusion":
            cost.bytes += _op_bytes(comp, op)
    return cost


def _fused_flops(comps: Dict[str, Computation], name: str,
                 memo: Dict[str, HloCost]) -> float:
    comp = comps.get(name)
    if comp is None:
        return 0.0
    total = 0.0
    for op in comp.ops:
        if op.opcode == "dot":
            total += _dot_flops(comp, op)
        elif op.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            if m:
                total += _fused_flops(comps, m.group(1), memo)
    return total


def _op_bytes(comp: Computation, op: Op) -> float:
    total = float(_shape_bytes(op.out_blob))
    for a in op.args:
        total += _shape_bytes(_arg_shape_blob(comp, a))
    return total


def analyze_hlo(hlo_text: str) -> HloCost:
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return HloCost()
    return _comp_cost(comps, entry, {})
