"""Dry-run cells for the paper's own workload: distributed PageRank at
Twitter2010/LiveJournal scale on the production mesh.

The graph engine treats the pod as one big-memory machine: edges live with
their destination owner across all 256 (or 512) chips — the mesh axes are
flattened into one logical "graph" axis via a (pod·data·model)-wide
PartitionSpec, matching `core/distributed.py` semantics.
"""

from __future__ import annotations

import functools
import time
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .mesh import make_production_mesh

GRAPHS = {
    # paper Table 2
    "pagerank_twitter": dict(n_nodes=41_700_000, n_edges=1_470_000_000),
    "pagerank_livejournal": dict(n_nodes=4_850_000, n_edges=69_000_000),
    # §Perf variants: 2D SUMMA partition (Θ(N/d) collectives) ± bf16 wire
    "pagerank_twitter_2d": dict(n_nodes=41_700_000, n_edges=1_470_000_000,
                                partition="2d"),
    "pagerank_twitter_2d_bf16": dict(n_nodes=41_700_000,
                                     n_edges=1_470_000_000,
                                     partition="2d", compress=True),
    "pagerank_twitter_bf16": dict(n_nodes=41_700_000, n_edges=1_470_000_000,
                                  compress=True),
}


def pagerank_step_fn(mesh, axes, n_nodes: int, ns: int, es: int,
                     damping: float = 0.85, compress_bf16: bool = False):
    """One distributed PageRank iteration over dst-partitioned edge shards."""

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axes), P(axes), P(axes), P(axes), P(axes)),
        out_specs=P(axes))
    def step(src, dst_local, evalid, inv_deg_shard, pr_shard):
        inv_full = jax.lax.all_gather(inv_deg_shard, axes, tiled=True)
        if compress_bf16:
            msg = jax.lax.optimization_barrier(pr_shard.astype(jnp.bfloat16))
        else:
            msg = pr_shard
        pr_full = jax.lax.all_gather(msg, axes, tiled=True
                                     ).astype(jnp.float32)
        contrib = jnp.where(evalid, pr_full[src] * inv_full[src], 0.0)
        local = jax.ops.segment_sum(contrib, dst_local, num_segments=ns,
                                    indices_are_sorted=True)
        dang = jax.lax.psum(
            jnp.sum(jnp.where(inv_deg_shard == 0.0, pr_shard, 0.0)), axes)
        return (1.0 - damping) / n_nodes + damping * (local + dang / n_nodes)

    return step


def run_ringo_cell(shape_name: str, multi_pod: bool) -> Dict:
    if shape_name not in GRAPHS:
        return {"arch": "ringo-graph", "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped",
                "reason": f"graph cells are {sorted(GRAPHS)}"}
    g = GRAPHS[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    d = mesh.devices.size

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, P(spec)))

    t0 = time.time()
    if g.get("partition") == "2d":
        if multi_pod:
            return {"arch": "ringo-graph", "shape": shape_name,
                    "multi_pod": multi_pod, "status": "skipped",
                    "reason": "2D partition defined on the square "
                              "single-pod grid; pods run independent rows"}
        from ..core.distributed import DistGraph2D, pagerank_distributed_2d
        side = mesh.shape["data"]
        nb = -(-g["n_nodes"] // side)
        es = -(-g["n_edges"] // d)
        grid = ("data", "model")
        dg = DistGraph2D(
            n_nodes=g["n_nodes"], n_edges=g["n_edges"], nb=nb, es=es,
            d=side,
            src_local=sds((d * es,), jnp.int32, grid),
            dst_local=sds((d * es,), jnp.int32, grid),
            evalid=sds((d * es,), jnp.bool_, grid),
            inv_deg_col=sds((side * nb,), jnp.float32, "model"),
        )
        fn = lambda dgx: pagerank_distributed_2d(
            dgx, mesh, n_iter=1, compress_bf16=bool(g.get("compress")),
            unshuffle=False)
        with mesh:
            lowered = jax.jit(fn).lower(dg)
            compiled = lowered.compile()
    else:
        axes = tuple(mesh.axis_names)
        ns = -(-g["n_nodes"] // d)
        es = -(-g["n_edges"] // d)
        args = (
            sds((d * es,), jnp.int32, axes),    # src (global ids)
            sds((d * es,), jnp.int32, axes),    # dst_local
            sds((d * es,), jnp.bool_, axes),    # edge valid
            sds((d * ns,), jnp.float32, axes),  # 1/out_degree
            sds((d * ns,), jnp.float32, axes),  # pagerank shard
        )
        fn = pagerank_step_fn(mesh, axes, g["n_nodes"], ns, es,
                              compress_bf16=bool(g.get("compress")))
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            compiled = lowered.compile()
    t1 = time.time()
    from .hlo_cost import analyze_hlo, xla_cost_dict
    mem = compiled.memory_analysis()
    cost = xla_cost_dict(compiled)
    corrected = analyze_hlo(compiled.as_text())
    return {
        "arch": "ringo-graph", "shape": shape_name, "kind": "graph",
        "multi_pod": multi_pod, "status": "ok",
        "n_chips": int(d), "compile_s": round(t1 - t0, 1),
        "xla_flops_per_device": float(cost.get("flops", 0.0)),
        "flops_per_device": corrected.flops or float(cost.get("flops", 0.0)),
        "bytes_per_device": corrected.bytes,
        "collective_bytes_per_device": corrected.collective_bytes,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0) or
            (getattr(mem, "argument_size_in_bytes", 0)
             + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "graph": g,
    }
