"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero device allocation (assignment spec §2).

Per cell kind:
  train   -> (params, opt_state, batch{tokens,targets[,enc,patch]}, step)
  prefill -> (params, batch)
  decode  -> (params, cache, tokens(B,1), pos[, enc_out])

All leaves carry their NamedSharding so `jit(...).lower(*specs)` needs no
separate in_shardings pytree.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..launch import sharding as shlib
from ..models import transformer as model
from ..models.layers import dtype_of
from ..train.optimizer import get_optimizer, opt_state_specs

GIANT_PARAM_BYTES = 8e9  # per-chip TP-sharded weight budget -> go 2D above


def is_giant(cfg: ArchConfig, model_par: int = 16) -> bool:
    return cfg.param_count() * (2 if cfg.param_dtype == "bfloat16" else 4) \
        / model_par > GIANT_PARAM_BYTES


def rules_for(cfg: ArchConfig, mesh: Mesh, kind: str,
              shape: Optional[ShapeSpec] = None) -> shlib.LogicalRules:
    multi_pod = "pod" in mesh.axis_names
    model_par = mesh.shape["model"]
    eap = cfg.n_experts > 0 and cfg.n_experts % model_par == 0
    two_d = is_giant(cfg, model_par)
    kv_axis = None
    if kind == "decode" and shape is not None:
        if shape.global_batch == 1:
            # batch=1 frees every DP axis: flash-decode shards the cache's
            # sequence dim across the whole mesh
            kv_axis = ("pod", "data", "model") if multi_pod \
                else ("data", "model")
        else:
            kv_axis = "model"
    rules = shlib.default_rules(mesh, multi_pod=multi_pod,
                                kv_seq_axis=kv_axis,
                                expert_axis_parallel=eap,
                                two_d_weights=two_d)
    # tiny batches can't shard over the DP axes (long_500k has batch=1)
    if shape is not None:
        dp = rules.mapping["batch"]
        dp_total = 1
        for ax in (dp if isinstance(dp, tuple) else (dp,)):
            dp_total *= mesh.shape[ax]
        if shape.global_batch % dp_total != 0:
            rules.mapping["batch"] = None
    return rules


def _with_shardings(shapes, specs, mesh):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)


def param_structs(cfg: ArchConfig, mesh: Mesh, rules) -> Tuple[Any, Any]:
    """(ShapeDtypeStructs-with-sharding, spec tree) for the params."""
    shapes = jax.eval_shape(lambda: model.init_params(
        cfg, jax.random.PRNGKey(0)))
    specs = shlib.param_specs(shapes, rules)
    return _with_shardings(shapes, specs, mesh), specs


def batch_structs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, rules,
                  with_targets: bool = True) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    dp = rules.mapping["batch"]
    emb_dt = dtype_of(cfg.compute_dtype)
    if cfg.n_patches:
        s_tok = s - cfg.n_patches
    else:
        s_tok = s
    out = {"tokens": jax.ShapeDtypeStruct(
        (b, s_tok), jnp.int32, sharding=NamedSharding(mesh, P(dp)))}
    if with_targets:
        out["targets"] = jax.ShapeDtypeStruct(
            (b, s_tok), jnp.int32, sharding=NamedSharding(mesh, P(dp)))
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq_len, cfg.d_model), emb_dt,
            sharding=NamedSharding(mesh, P(dp)))
    if cfg.n_patches:
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), emb_dt,
            sharding=NamedSharding(mesh, P(dp)))
    return out


def opt_structs(cfg: ArchConfig, mesh: Mesh, rules, param_shapes, param_specs):
    opt = get_optimizer(cfg.optimizer)
    s_shapes = jax.eval_shape(opt.init, param_shapes)
    s_specs = opt_state_specs(cfg.optimizer, param_specs, s_shapes, mesh,
                              data_axis="data")
    return _with_shardings(s_shapes, s_specs, mesh), s_specs


def cache_structs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, rules):
    b, s = shape.global_batch, shape.seq_len
    shapes = jax.eval_shape(lambda: model.init_cache(cfg, b, s))
    dp = rules.mapping["batch"]
    kv_axis = rules.mapping.get("kv_seq")

    dp_total = 1
    if dp is not None:
        for ax in (dp if isinstance(dp, tuple) else (dp,)):
            dp_total *= mesh.shape[ax]

    def spec_for(path, leaf):
        names = [str(getattr(k, "key", k)) for k in path]
        if leaf.ndim == 5 and names[-1] in ("k", "v"):
            return P(None, dp, kv_axis, None, None)
        # recurrent states (possibly with extra stacked leading dims):
        # find the batch axis by size, then shard the big inner dim on model
        axes = [None] * leaf.ndim
        batch_i = None
        if dp is not None:
            for i in range(leaf.ndim):
                if leaf.shape[i] == b and b % dp_total == 0:
                    axes[i] = dp
                    batch_i = i
                    break
        for i in range(leaf.ndim - 1, -1, -1):
            if i == batch_i:
                continue
            if leaf.shape[i] % mesh.shape["model"] == 0 and leaf.shape[i] >= 16:
                axes[i] = "model"
                break
        return P(*axes)

    specs = jax.tree_util.tree_map_with_path(spec_for, shapes)
    return _with_shardings(shapes, specs, mesh), specs


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                kind: Optional[str] = None):
    """Everything `dryrun` needs to lower the cell, keyed by kind."""
    kind = kind or shape.kind
    rules = rules_for(cfg, mesh, kind, shape)
    p_structs, p_specs = param_structs(cfg, mesh, rules)
    if kind == "train":
        o_structs, o_specs = opt_structs(cfg, mesh, rules, p_structs, p_specs)
        batch = batch_structs(cfg, shape, mesh, rules)
        step = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=NamedSharding(mesh, P()))
        return rules, (p_structs, o_structs, batch, step)
    if kind == "prefill":
        batch = batch_structs(cfg, shape, mesh, rules, with_targets=False)
        return rules, (p_structs, batch)
    if kind == "decode":
        cache, _ = cache_structs(cfg, shape, mesh, rules)
        dp = rules.mapping["batch"]
        toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                    sharding=NamedSharding(mesh, P(dp)))
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
        extras = ()
        if cfg.is_encoder_decoder:
            enc = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_seq_len, cfg.d_model),
                dtype_of(cfg.compute_dtype),
                sharding=NamedSharding(mesh, P(dp)))
            extras = (enc,)
        return rules, (p_structs, cache, toks, pos) + extras
    raise ValueError(kind)
