"""Training driver: mesh setup, sharded state, checkpoint/restart loop.

Usage (CPU example — reduced 100M-class model, see examples/train_lm.py):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production usage lowers the same ``train_step`` under the 16×16 mesh; the
dry-run driver (dryrun.py) proves that path compiles for every cell.

Fault tolerance: resumes from the newest complete checkpoint; the
ElasticCoordinator plans a re-mesh when capacity changes (simulated here —
real deployments feed it heartbeats from the cluster manager).
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint.store import (config_hash, latest_step, load_checkpoint,
                                save_checkpoint)
from ..configs import base as cfgbase
from ..data.pipeline import Prefetcher, SyntheticLM
from ..launch import sharding as shlib
from ..launch.elastic import ElasticCoordinator
from ..launch.mesh import make_host_mesh
from ..models import transformer as model
from ..train.optimizer import OptHyper, get_optimizer
from ..train.step import make_train_step


def build_sharded_state(cfg, mesh, rules, key):
    """Init params/opt-state directly into their shards (via jit out_shardings)."""
    opt = get_optimizer(cfg.optimizer)
    p_shapes = jax.eval_shape(lambda k: model.init_params(cfg, k), key)
    p_specs = shlib.param_specs(p_shapes, rules)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    params = jax.jit(lambda k: model.init_params(cfg, k),
                     out_shardings=p_shard)(key)
    s_shapes = jax.eval_shape(opt.init, p_shapes)
    from ..train.optimizer import opt_state_specs
    s_specs = opt_state_specs(cfg.optimizer, p_specs, s_shapes, mesh)
    s_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), s_specs)
    opt_state = jax.jit(opt.init, out_shardings=s_shard)(params)
    return params, opt_state, p_shard, s_shard


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", type=int, default=0, help="data-mesh degree")
    ap.add_argument("--model", type=int, default=1, help="model-mesh degree")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = cfgbase.get_config(args.arch)
    if args.reduced:
        cfg = cfgbase.reduced(cfg)

    mesh = make_host_mesh(args.data or None, args.model)
    rules = shlib.default_rules(mesh)
    key = jax.random.PRNGKey(args.seed)

    with mesh, shlib.rules_ctx(rules):
        params, opt_state, p_shard, s_shard = build_sharded_state(
            cfg, mesh, rules, key)
        hyper = OptHyper(lr=args.lr)
        step_fn = make_train_step(cfg, hyper, attn_chunk=min(1024, args.seq))
        batch_sharding = NamedSharding(mesh, P(("data",)))
        jstep = jax.jit(step_fn,
                        out_shardings=(p_shard, s_shard, None),
                        donate_argnums=(0, 1))

        start = 0
        if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            start, state, meta = load_checkpoint(
                args.ckpt_dir, {"params": params, "opt": opt_state})
            if meta.get("config") != config_hash(cfg):
                raise ValueError("checkpoint config mismatch")
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {start}")

        coord = ElasticCoordinator(n_workers=jax.process_count() or 1,
                                   hosts_per_tp_group=1)
        src = SyntheticLM(cfg.vocab_size, args.batch, args.seq, args.seed)
        pre = Prefetcher(src, depth=2, sharding=batch_sharding,
                         start_step=start)
        try:
            t_last = time.perf_counter()
            for i in range(start, args.steps):
                step_idx, batch = pre.next()
                assert step_idx == i
                params, opt_state, metrics = jstep(params, opt_state, batch,
                                                   jnp.int32(i))
                if (i + 1) % 5 == 0 or i == args.steps - 1:
                    loss = float(metrics["loss"])
                    dt = time.perf_counter() - t_last
                    t_last = time.perf_counter()
                    print(f"[train] step {i+1:5d} loss {loss:.4f} ({dt:.2f}s/5)")
                coord.heartbeat(0, time.perf_counter() - t_last)
                if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                    save_checkpoint(args.ckpt_dir, i + 1,
                                    {"params": params, "opt": opt_state},
                                    meta={"config": config_hash(cfg)})
            if args.ckpt_dir:
                save_checkpoint(args.ckpt_dir, args.steps,
                                {"params": params, "opt": opt_state},
                                meta={"config": config_hash(cfg)})
        finally:
            pre.stop()


if __name__ == "__main__":
    main()
