"""Checkpointing: sharded npz + manifest, atomic writes, resume.

Fault-tolerance contract (DESIGN.md §5):
* every save is atomic — arrays land in ``<dir>/tmp.<step>`` and are
  renamed to ``<dir>/step_<N>`` only after the manifest (with per-leaf
  checksums and the config hash) is fully written;
* ``latest_step`` ignores partial directories, so a crash mid-save can
  never corrupt restart;
* multi-host: each process writes ``shard_<process_index>.npz`` of its
  addressable shards; this container has one process, the layout is the
  general one.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any

_MANIFEST = "manifest.json"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_like(tree, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, tree, meta: Optional[Dict] = None,
                    process_index: int = 0) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{process_index}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    shard_path = os.path.join(tmp, f"shard_{process_index}.npz")
    np.savez(shard_path, **flat)
    checksums = {k: hashlib.sha256(v.tobytes()).hexdigest()[:16]
                 for k, v in flat.items()}
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "sha": checksums[k]} for k, v in flat.items()},
        "meta": meta or {},
        "n_processes": jax.process_count(),
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # atomic on POSIX
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
            steps.append(int(name[len("step_"):]))
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, like, step: Optional[int] = None,
                    process_index: int = 0) -> Tuple[int, Any, Dict]:
    """Restore the tree (shaped like ``like``) from the newest checkpoint."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, f"shard_{process_index}.npz")) as z:
        flat = {k: z[k] for k in z.files}
    for k, v in flat.items():
        want = manifest["leaves"][k]["sha"]
        got = hashlib.sha256(v.tobytes()).hexdigest()[:16]
        if want != got:
            raise IOError(f"checksum mismatch for {k} in {path}")
    tree = _unflatten_like(like, flat)
    return step, tree, manifest.get("meta", {})


def config_hash(cfg) -> str:
    import dataclasses
    blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]
