"""Layer 1 of the unified traversal engine: the memoized :class:`GraphPlan`.

Ringo's interactive loop (§2.2) repeatedly runs algorithms against the same
in-memory graph; the representation is pre-optimized once so every call after
the first is pure traversal.  Our seed re-derived the access structures on
every invocation (``out_edges()`` / ``_row_of_edge`` / orientation /
re-blocking).  ``GraphPlan`` hoists all of that into a per-``Graph`` cache,
keyed by graph *identity* via :meth:`repro.core.graph.Graph.plan` — functional
updates (``add_edges`` / ``delete_edges``) return fresh ``Graph`` objects, so
a stale plan can never be observed.

Eagerly built (cheap, needed by every traversal):

    in_src / in_dst    edge arrays sorted by destination (pull order)
    out_src / out_dst  edge arrays sorted by source (push order)
    out_deg / in_deg   degree vectors
    inv_out_deg        1/out-degree (0 for sinks) — PageRank mass split
    dangling           out_deg == 0 mask

Lazily built and cached on first use:

    undirected()       symmetrized simple-graph view (CC / k-core / LP / tri)
    oriented()         degeneracy-oriented padded adjacency (triangles)
    csr_out()          trimmed out-CSR (ptr, idx, deg_pad) — the frontier
                       backend's push-side gather: adjacency slices of only
                       the active vertices (sparse BFS/SSSP)
    csr_in()           trimmed in-CSR, the pull-side dual
    in_perm_out()      permutation taking in-edge-order per-edge values
                       (the sssp weight convention) to out-edge order, so
                       the frontier push relaxes with the same weights
    bsr(block)         128x128 BSR tiles of M[dst, src] (SpMV pull backend)
    bsr_t(block)       transpose tiles M[src, dst] (SpMV push backend — the
                       HITS hub step and every other out-edge reduction)
    tri_triples(block) BSR tile triples for A.(A@A) triangle counting
    chunk_layout_in / chunk_layout_out
                       static chunk structure for the Pallas segment-sum
                       backend (pull / push reduction order respectively)
    sharded(d)         per-shard arrays for the multi-device "sharded"
                       backend: contiguous vertex-range partition of both
                       CSR orders, halo/boundary index sets for the cut
                       edges, and padded degree slices — one ShardPlan per
                       device count, placed on the 1-D graph mesh

The execution primitives that consume these live in
:mod:`repro.core.engine`; per-backend ``Exec`` pytrees are cached here in
``execs`` so repeated calls reuse both the arrays *and* the jit caches.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import EdgeDelta, Graph
from ..kernels.segment_sum import DEFAULT_BLOCK, DEFAULT_CHUNK, chunk_layout

__all__ = ["GraphPlan", "ShardPlan", "EVICTABLE_FAMILIES"]

# Derived-array families a plan can drop and rebuild on next touch.  "base"
# (the eager sorted-edge/degree arrays) and the graph's own CSR storage are
# deliberately absent: they are the plan, not a cache over it.
EVICTABLE_FAMILIES: Tuple[str, ...] = (
    "undirected", "oriented", "csr", "perm", "bsr", "tri", "chunks",
    "sharded", "execs")


class _ShardDir(NamedTuple):
    """One direction (pull or push) of a vertex-range partition.

    All per-shard buffers use the flat ``(d * per_shard,)`` layout and are
    replicated on the graph mesh; the engine's manual regions slice shard
    ``i``'s block out via ``axis_index``.  ``gather_idx`` addresses the
    concatenation ``[local x (ns values), halo (d * halo values)]`` built
    inside each round's boundary exchange; ``seg_local`` maps each edge
    slot to its shard-local segment, with padding slots pointing at the
    overflow segment ``ns`` (sliced off after the reduction, so pad slots
    can never perturb a real vertex — not even by adding a signed zero).
    """

    es: int                 # padded edge slots per shard
    halo: int               # boundary slots per shard (max cut fan-in)
    gather_idx: jax.Array   # (d*es,) int32 into [local(ns) | halo(d*halo)]
    seg_local: jax.Array    # (d*es,) int32 local segment id, pad -> ns
    edge_slot: jax.Array    # (E,) int32: global edge order -> flat slot
    boundary: jax.Array     # (d*halo,) int32 local ids each shard exports


class ShardPlan(NamedTuple):
    """Per-device-count derived arrays for the "sharded" engine backend.

    ``pull`` partitions the dst-sorted in-edges by destination range (each
    vertex's whole in-segment stays on its owner, in order — this is what
    makes the shard-local segment reduction bit-identical to the global
    one); ``push`` partitions the src-sorted out-edges by source range.
    ``out_deg`` / ``in_deg`` are the degree vectors padded to ``d * ns``,
    replicated on the mesh like every other per-shard buffer.
    """

    d: int                  # shard / device count
    ns: int                 # vertices per shard (ceil(n / d), >= 1)
    axis: str               # mesh axis name
    mesh: object            # the 1-D jax Mesh (hashable, identity-cached)
    pull: _ShardDir
    push: _ShardDir
    out_deg: jax.Array      # (d*ns,) padded, mesh-replicated
    in_deg: jax.Array       # (d*ns,) padded, mesh-replicated

    def halo_bytes_per_round(self, itemsize: int = 4) -> int:
        """Bytes materialized per device by one pull-side halo all-gather."""
        return self.d * self.pull.halo * itemsize


def _build_shard_dir(key: np.ndarray, other: np.ndarray, d: int, ns: int,
                     spec) -> _ShardDir:
    """Partition one edge order by contiguous ``key`` ranges.

    ``key`` is the sorted segment endpoint (dst for pull, src for push),
    ``other`` the gathered endpoint.  Shard ``i`` owns vertices
    ``[i*ns, (i+1)*ns)`` and therefore the contiguous edge slice whose keys
    fall in that range.  Cut edges (``other`` owned elsewhere) index into
    the halo: owner ``o`` exports its sorted unique referenced vertices
    (its boundary set), and the flat halo position is
    ``ns + o*halo + rank``.
    """
    e = int(key.shape[0])
    key = key.astype(np.int64)
    other = other.astype(np.int64)
    starts = np.searchsorted(key, np.arange(d, dtype=np.int64) * ns,
                             side="left")
    ends = np.searchsorted(key, np.arange(1, d + 1, dtype=np.int64) * ns,
                           side="left")
    es = max(int((ends - starts).max()) if d else 0, 1)
    shard_of = key // ns
    owner_of = other // ns
    remote = owner_of != shard_of
    bnd_sets = [np.unique(other[remote & (owner_of == o)]) for o in range(d)]
    halo = max(max((v.size for v in bnd_sets), default=0), 1)
    boundary = np.zeros((d, halo), np.int32)
    for o, vs in enumerate(bnd_sets):
        boundary[o, : vs.size] = (vs - o * ns).astype(np.int32)
    gidx_e = np.where(remote, 0, other - shard_of * ns)
    for o in range(d):
        m = remote & (owner_of == o)
        if m.any():
            gidx_e[m] = ns + o * halo + np.searchsorted(bnd_sets[o], other[m])
    gidx = np.zeros((d, es), np.int32)
    seg = np.full((d, es), ns, np.int32)
    slot = np.zeros((e,), np.int32)
    for i in range(d):
        s0, s1 = int(starts[i]), int(ends[i])
        c = s1 - s0
        gidx[i, :c] = gidx_e[s0:s1]
        seg[i, :c] = key[s0:s1] - i * ns
        slot[s0:s1] = i * es + np.arange(c, dtype=np.int32)
    return _ShardDir(
        es=es, halo=halo,
        gather_idx=jax.device_put(jnp.asarray(gidx.reshape(-1)), spec),
        seg_local=jax.device_put(jnp.asarray(seg.reshape(-1)), spec),
        edge_slot=jnp.asarray(slot),
        boundary=jax.device_put(jnp.asarray(boundary.reshape(-1)), spec))


def _tree_bytes(obj, seen: set) -> int:
    """Sum array bytes in a nested structure, counting each buffer once.

    ``seen`` carries the ids of buffers already charged elsewhere (the
    graph's own CSR storage, the parent plan's arrays a patched member
    shares) so aliased members — ``csr_out()`` returning ``g.out_idx``, a
    patched BSR sharing the parent's ``rows``/``cols``, exec pytrees holding
    references into plan arrays — never double-count.
    """
    if obj is None:
        return 0
    if isinstance(obj, (tuple, list)):
        return sum(_tree_bytes(x, seen) for x in obj)
    if isinstance(obj, dict):
        return sum(_tree_bytes(x, seen) for x in obj.values())
    if isinstance(obj, Graph):
        total = _tree_bytes((obj.node_ids, obj.out_ptr, obj.out_idx,
                             obj.in_ptr, obj.in_idx), seen)
        if obj._plan is not None:
            total += sum(obj._plan.nbytes_by_family().values())
        return total
    if hasattr(obj, "dtype") and hasattr(obj, "size"):
        k = id(obj)
        if k in seen:
            return 0
        seen.add(k)
        return int(obj.size) * int(np.dtype(obj.dtype).itemsize)
    try:                               # exec pytrees and anything jax knows
        leaves = jax.tree_util.tree_leaves(obj)
    except Exception:
        return 0
    if len(leaves) == 1 and leaves[0] is obj:
        return 0                       # opaque scalar leaf, not a container
    return sum(_tree_bytes(x, seen) for x in leaves)


@dataclass
class GraphPlan:
    """Precomputed traversal arrays for one :class:`Graph` (identity-cached)."""

    graph: Graph
    n_nodes: int
    n_edges: int
    in_src: jax.Array
    in_dst: jax.Array
    out_src: jax.Array
    out_dst: jax.Array
    out_deg: jax.Array
    in_deg: jax.Array
    inv_out_deg: jax.Array
    dangling: jax.Array
    # lazy caches — never hashed/compared, filled on first use
    execs: Dict = field(default_factory=dict, repr=False, compare=False)
    _undirected: Optional[Graph] = field(default=None, repr=False, compare=False)
    _oriented: Optional[Tuple] = field(default=None, repr=False, compare=False)
    _csr_out: Optional[Tuple] = field(default=None, repr=False, compare=False)
    _csr_in: Optional[Tuple] = field(default=None, repr=False, compare=False)
    _in_perm_out: Optional[jax.Array] = field(default=None, repr=False,
                                              compare=False)
    _bsr: Dict = field(default_factory=dict, repr=False, compare=False)
    _bsr_t: Dict = field(default_factory=dict, repr=False, compare=False)
    _tri_triples: Dict = field(default_factory=dict, repr=False, compare=False)
    _chunks_in: Dict = field(default_factory=dict, repr=False, compare=False)
    _chunks_out: Dict = field(default_factory=dict, repr=False, compare=False)
    _sharded: Dict = field(default_factory=dict, repr=False, compare=False)
    # delta lineage (set by :meth:`patch` only): dense ids of the vertices
    # the delta touched, the parent's plan, and the _DeltaInfo it came from
    dirty_vertices: Optional[np.ndarray] = field(default=None, repr=False,
                                                 compare=False)
    _parent: Optional["GraphPlan"] = field(default=None, repr=False,
                                           compare=False)
    _info: Optional[object] = field(default=None, repr=False, compare=False)

    # -- construction -----------------------------------------------------------
    @classmethod
    def build(cls, g: Graph) -> "GraphPlan":
        in_src, in_dst = g.in_edges()
        out_src, out_dst = g.out_edges()
        out_deg = g.out_degrees()
        in_deg = g.in_degrees()
        out_deg_f = out_deg.astype(jnp.float32)
        inv_out_deg = jnp.where(out_deg > 0,
                                1.0 / jnp.maximum(out_deg_f, 1.0), 0.0)
        dangling = out_deg == 0
        return cls(graph=g, n_nodes=g.n_nodes, n_edges=g.n_edges,
                   in_src=in_src, in_dst=in_dst,
                   out_src=out_src, out_dst=out_dst,
                   out_deg=out_deg, in_deg=in_deg,
                   inv_out_deg=inv_out_deg, dangling=dangling)

    @classmethod
    def patch(cls, g: Graph, info) -> "GraphPlan":
        """Derive the plan from the parent's instead of re-sorting.

        ``info`` is the ``_DeltaInfo`` left by ``Graph.apply_delta``'s fast
        path: it already holds the merged edge lists in both CSR orders as
        host arrays, so the eager fields are direct uploads (no device
        lexsort, no ``_row_of_edge`` searchsorted), and degrees are cheap
        slices of the already-patched row pointers.  The lazy structures
        below patch the parent's cached versions where that is sound
        (undirected view, BSR tiles, weight permutation) and rebuild
        otherwise.  ``dirty_vertices`` feeds incremental recomputation in
        :mod:`repro.core.algorithms`.
        """
        parent = info.parent.plan()
        out_deg = g.out_degrees()
        in_deg = g.in_degrees()
        out_deg_f = out_deg.astype(jnp.float32)
        inv_out_deg = jnp.where(out_deg > 0,
                                1.0 / jnp.maximum(out_deg_f, 1.0), 0.0)
        return cls(graph=g, n_nodes=g.n_nodes, n_edges=g.n_edges,
                   in_src=jnp.asarray(info.in_src),
                   in_dst=jnp.asarray(info.in_dst),
                   out_src=jnp.asarray(info.out_src),
                   out_dst=jnp.asarray(info.out_dst),
                   out_deg=out_deg, in_deg=in_deg,
                   inv_out_deg=inv_out_deg, dangling=out_deg == 0,
                   dirty_vertices=info.dirty, _parent=parent, _info=info)

    # -- lazy derived structures -------------------------------------------------
    def undirected(self) -> Graph:
        """Symmetrized simple-graph view, built once per plan.

        For an insert-only delta child this *patches* the parent's
        undirected view via ``apply_delta`` (symmetrize the inserted
        non-loop edges in original-id space) instead of re-symmetrizing the
        whole graph — and the patched view carries its own delta lineage,
        which is what lets connected-components warm-start.  Deletions fall
        back to a full rebuild.
        """
        if self._undirected is None:
            info = self._info
            if info is not None and info.insert_only:
                osrc = np.asarray(self.graph.original_of(info.add_src))
                odst = np.asarray(self.graph.original_of(info.add_dst))
                keep = osrc != odst
                self._undirected = self._parent.undirected().apply_delta(
                    EdgeDelta.inserts(
                        np.concatenate([osrc[keep], odst[keep]]),
                        np.concatenate([odst[keep], osrc[keep]])))
            else:
                self._undirected = self.graph.to_undirected()
        return self._undirected

    def oriented(self) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        """Degeneracy-oriented padded adjacency ``(osrc, odst, nbr, odeg)``.

        Orient each undirected edge from its lower-(degree, id) endpoint to
        the higher one; every triangle then has exactly one "apex" and is
        counted once.  Max oriented out-degree is O(sqrt(E)) — this bounds
        the padded matrix width, the TPU dual of the paper's per-node
        adjacency vectors.
        """
        if self._oriented is None:
            src, dst = self.out_src, self.out_dst
            deg = self.out_deg
            n = self.n_nodes
            keep = (deg[src] < deg[dst]) | ((deg[src] == deg[dst]) & (src < dst))
            n_keep = int(jnp.sum(keep))
            perm = jnp.argsort(~keep, stable=True)[: max(n_keep, 1)]
            osrc, odst = src[perm][:n_keep], dst[perm][:n_keep]
            odeg = jnp.bincount(osrc, length=n)
            max_deg = int(jnp.max(odeg)) if n_keep else 0
            order_ = jnp.lexsort((odst, osrc))
            s_sorted, d_sorted = osrc[order_], odst[order_]
            ptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(odeg).astype(jnp.int32)])
            # scatter into (n, max_deg) padded matrix; pad with n (sorts last)
            slot = jnp.arange(n_keep, dtype=jnp.int32) - ptr[s_sorted]
            nbr = jnp.full((n, max(max_deg, 1)), n, dtype=jnp.int32)
            nbr = nbr.at[s_sorted, slot].set(d_sorted)
            self._oriented = (osrc, odst, nbr, odeg.astype(jnp.int32))
        return self._oriented

    def csr_out(self) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Out-CSR for frontier gathers: ``(ptr, idx, deg_pad)``.

        ``ptr`` is the trimmed ``(n+1,)`` row-pointer prefix (``ptr[n]`` is
        the edge count), ``idx`` the capacity-padded neighbor array, and
        ``deg_pad`` an ``(n+1,)`` degree vector whose sentinel row ``n``
        (the frontier pad vertex) has degree 0 — padded frontier slots
        contribute no edges.
        """
        if self._csr_out is None:
            g, n = self.graph, self.n_nodes
            deg_pad = jnp.concatenate(
                [self.out_deg, jnp.zeros((1,), self.out_deg.dtype)])
            self._csr_out = (g.out_ptr[: n + 1], g.out_idx, deg_pad)
        return self._csr_out

    def csr_in(self) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """In-CSR ``(ptr, idx, deg_pad)`` — the pull-side frontier dual.

        Reserved for a sparse *pull* phase (gathering in-edges of only the
        unsettled vertices); today's direction-optimized dense pull reduces
        over the sorted edge arrays directly, so nothing in the engine
        consumes this yet.
        """
        if self._csr_in is None:
            g, n = self.graph, self.n_nodes
            deg_pad = jnp.concatenate(
                [self.in_deg, jnp.zeros((1,), self.in_deg.dtype)])
            self._csr_in = (g.in_ptr[: n + 1], g.in_idx, deg_pad)
        return self._csr_in

    def in_perm_out(self) -> jax.Array:
        """Permutation ``p`` with ``w_out = w_in[p]``.

        Per-edge values follow the sssp convention (in-edge order, sorted by
        dst); the frontier push walks out-edge CSR order (sorted by src).
        ``p[j]`` is the in-order position of the j-th out-order edge, so one
        gather re-keys weights once per call.
        """
        if self._in_perm_out is None:
            info = self._info
            if info is not None:
                p = _host_in_perm_out(info)
                if p is not None:
                    self._in_perm_out = jnp.asarray(p)
                    return self._in_perm_out
            # sorting the in-order edge list by (src, dst) yields out order
            self._in_perm_out = jnp.lexsort((self.in_dst, self.in_src)) \
                .astype(jnp.int32)
        return self._in_perm_out

    def bsr(self, block: int = DEFAULT_BLOCK
            ) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
        """Unweighted BSR tiles of M[dst, src] (the pull/SpMV layout)."""
        if block not in self._bsr:
            patched = self._patched_bsr(block, transpose=False)
            if patched is not None:
                self._bsr[block] = patched
            else:
                from ..kernels.ops import edges_to_bsr
                self._bsr[block] = edges_to_bsr(np.asarray(self.in_src),
                                                np.asarray(self.in_dst),
                                                self.n_nodes, block=block)
        return self._bsr[block]

    def bsr_t(self, block: int = DEFAULT_BLOCK
              ) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
        """Transpose BSR tiles: M[src, dst] (the push/SpMV layout).

        ``engine.push(x, "sum")`` is ``y[u] = Σ_{u→v} x[v]`` — an SpMV with
        the edge matrix oriented source-major.  Without these tiles the
        "bsr" backend silently fell back to XLA for every push (the HITS hub
        step, SCC's backward pass); with them the push takes the same MXU
        path as the pull.
        """
        if block not in self._bsr_t:
            patched = self._patched_bsr(block, transpose=True)
            if patched is not None:
                self._bsr_t[block] = patched
            else:
                from ..kernels.ops import edges_to_bsr
                # edges_to_bsr(a, b) builds M[b, a]: pass (dst, src) for M[src, dst]
                self._bsr_t[block] = edges_to_bsr(np.asarray(self.out_dst),
                                                  np.asarray(self.out_src),
                                                  self.n_nodes, block=block)
        return self._bsr_t[block]

    def _patched_bsr(self, block: int, transpose: bool):
        """Parent tiles + scatter-add of the inserted edges, when sound.

        Sound iff the delta is insert-only (a deleted pair's tile decrement
        would need its parent multiplicity) and every inserted edge lands in
        a tile the parent already materialized (tile *structure* unchanged,
        so ``rows``/``cols`` and any derived triples are shared).  Inserts
        are deduped by ``apply_delta``, so each adds exactly 1.0.
        """
        info = self._info
        if info is None or not info.insert_only:
            return None
        parent = self._parent
        cache = parent._bsr_t if transpose else parent._bsr
        if block not in cache:
            return None
        tiles, rows, cols, nb = cache[block]
        if info.add_src.size == 0:
            return (tiles, rows, cols, nb)
        if transpose:
            rv, cv = info.add_src, info.add_dst   # M[src, dst]
        else:
            rv, cv = info.add_dst, info.add_src   # M[dst, src]
        want = (rv // block).astype(np.int64) * nb + (cv // block)
        pkeys = np.asarray(rows).astype(np.int64) * nb + np.asarray(cols)
        if pkeys.size == 0:
            return None
        order = np.argsort(pkeys, kind="stable")
        pos = np.minimum(np.searchsorted(pkeys[order], want), pkeys.size - 1)
        if not bool(np.all(pkeys[order][pos] == want)):
            return None  # an insert opens a brand-new tile -> rebuild
        tidx = order[pos]
        new_tiles = tiles.at[jnp.asarray(tidx),
                             jnp.asarray(rv % block),
                             jnp.asarray(cv % block)].add(1.0)
        return (new_tiles, rows, cols, nb)

    def tri_triples(self, block: int = DEFAULT_BLOCK
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Tile triples (I,J),(I,K),(K,J) for the BSR triangle kernel."""
        if block not in self._tri_triples:
            _, rows, cols, _ = self.bsr(block)
            parent = self._parent
            if parent is not None and block in parent._tri_triples \
                    and block in parent._bsr \
                    and parent._bsr[block][1] is rows:
                # patched BSR kept the parent's tile structure -> the
                # (I,J),(I,K),(K,J) triples are byte-identical
                self._tri_triples[block] = parent._tri_triples[block]
            else:
                from ..kernels.ops import build_block_triples
                self._tri_triples[block] = build_block_triples(
                    np.asarray(rows), np.asarray(cols))
        return self._tri_triples[block]

    def chunk_layout_in(self, chunk: int = DEFAULT_CHUNK):
        """Pallas chunk structure for per-destination (pull) reductions."""
        if chunk not in self._chunks_in:
            self._chunks_in[chunk] = _device_layout(
                chunk_layout(np.asarray(self.in_dst), self.n_nodes, chunk))
        return self._chunks_in[chunk]

    def chunk_layout_out(self, chunk: int = DEFAULT_CHUNK):
        """Pallas chunk structure for per-source (push) reductions."""
        if chunk not in self._chunks_out:
            self._chunks_out[chunk] = _device_layout(
                chunk_layout(np.asarray(self.out_src), self.n_nodes, chunk))
        return self._chunks_out[chunk]

    def sharded(self, n_shards: int, axis: Optional[str] = None) -> ShardPlan:
        """Vertex-range partition over ``n_shards`` devices, memoized per count.

        Partitioning happens once on the host (numpy over the already-sorted
        edge arrays — contiguous range split is two searchsorteds per
        direction); the resulting buffers are placed on the cached 1-D graph
        mesh.  A delta child starts with an empty ``_sharded`` cache, so
        ``apply_delta`` invalidation falls out of plan identity exactly like
        every other family; :meth:`evict` can drop the whole dict and the
        next touch rebuilds bit-identically.
        """
        from ..launch.mesh import GRAPH_AXIS, graph_mesh
        from ..launch.sharding import graph_replicated_spec
        axis = GRAPH_AXIS if axis is None else axis
        d = int(n_shards)
        if d < 1:
            raise ValueError(f"sharded() needs >= 1 shard, got {d}")
        if d not in self._sharded:
            mesh = graph_mesh(d, axis)
            # replicated placement: the engine's manual regions take every
            # input full-shape (in_specs P()) and slice their own shard via
            # axis_index — see ShardedExec in core/engine.py for why GSPMD
            # is given no sharding decisions at all on this path
            spec = graph_replicated_spec(mesh)
            n = self.n_nodes
            ns = max(-(-n // d) if d else 1, 1)
            pull = _build_shard_dir(np.asarray(self.in_dst),
                                    np.asarray(self.in_src), d, ns, spec)
            push = _build_shard_dir(np.asarray(self.out_src),
                                    np.asarray(self.out_dst), d, ns, spec)
            pad = d * ns - n
            out_deg = jax.device_put(
                jnp.pad(self.out_deg, (0, pad)), spec)
            in_deg = jax.device_put(
                jnp.pad(self.in_deg, (0, pad)), spec)
            self._sharded[d] = ShardPlan(d=d, ns=ns, axis=axis, mesh=mesh,
                                         pull=pull, push=push,
                                         out_deg=out_deg, in_deg=in_deg)
        return self._sharded[d]

    # -- byte accounting + eviction ----------------------------------------------
    def _families(self) -> Dict[str, object]:
        """Family name -> the cached member(s) it covers (None/{} = cold)."""
        return {
            "base": (self.in_src, self.in_dst, self.out_src, self.out_dst,
                     self.out_deg, self.in_deg, self.inv_out_deg,
                     self.dangling),
            "undirected": self._undirected,
            "oriented": self._oriented,
            "csr": (self._csr_out, self._csr_in),
            "perm": self._in_perm_out,
            "bsr": (self._bsr, self._bsr_t),
            "tri": self._tri_triples,
            "chunks": (self._chunks_in, self._chunks_out),
            "sharded": self._sharded,
            "execs": self.execs,
            "lineage": self._info,
        }

    def _shared_ids(self) -> set:
        """Buffer ids charged to someone else: the graph's CSR storage and —
        for a patched plan — everything the parent plan already owns."""
        g = self.graph
        seen = {id(a) for a in (g.node_ids, g.out_ptr, g.out_idx,
                                g.in_ptr, g.in_idx)}
        parent = self._parent
        if parent is not None:
            sink: set = set()
            for member in parent._families().values():
                _tree_bytes(member, sink)
            seen |= sink
            pg = parent.graph
            seen |= {id(a) for a in (pg.node_ids, pg.out_ptr, pg.out_idx,
                                     pg.in_ptr, pg.in_idx)}
        return seen

    def nbytes_by_family(self) -> Dict[str, int]:
        """Derived bytes this plan holds, per family, aliases excluded.

        ``base`` is the eager sorted-edge/degree arrays (never evictable —
        they *are* the plan); ``lineage`` the host-side ``_DeltaInfo`` merge
        arrays a patched plan keeps for retention/warm starts.  Families in
        :data:`EVICTABLE_FAMILIES` can be dropped via :meth:`evict` and
        re-derive bit-identically on next touch.
        """
        seen = self._shared_ids()
        out: Dict[str, int] = {}
        for name, member in self._families().items():
            if name == "lineage":
                info = member
                out[name] = 0 if info is None else sum(
                    a.nbytes for a in (info.add_src, info.add_dst,
                                       info.del_src, info.del_dst, info.dirty,
                                       info.out_src, info.out_dst,
                                       info.in_src, info.in_dst))
            else:
                out[name] = _tree_bytes(member, seen)
        return out

    def nbytes(self) -> int:
        """Total derived bytes held by this plan (aliases excluded)."""
        return sum(self.nbytes_by_family().values())

    def evictable_bytes(self) -> int:
        fams = self.nbytes_by_family()
        return sum(fams[f] for f in EVICTABLE_FAMILIES)

    def evict(self, family: str) -> int:
        """Drop one re-derivable family; returns the bytes it held.

        Transparent by construction: every lazy getter rebuilds from the
        graph/base arrays (deterministically, so results are bit-identical),
        and evicting any array family also clears the cached ``Exec``
        pytrees, whose leaves reference the evicted buffers and would
        otherwise keep them alive.
        """
        if family not in EVICTABLE_FAMILIES:
            raise ValueError(f"family {family!r} is not evictable; "
                             f"have {EVICTABLE_FAMILIES}")
        fams = self.nbytes_by_family()
        freed = fams[family]
        if family == "undirected":
            self._undirected = None
        elif family == "oriented":
            self._oriented = None
        elif family == "csr":
            self._csr_out = None
            self._csr_in = None
        elif family == "perm":
            self._in_perm_out = None
        elif family == "bsr":
            self._bsr = {}
            self._bsr_t = {}
        elif family == "tri":
            self._tri_triples = {}
        elif family == "chunks":
            self._chunks_in = {}
            self._chunks_out = {}
        elif family == "sharded":
            self._sharded = {}
        if family != "execs" and self.execs:
            freed += fams["execs"]
            self.execs = {}
        elif family == "execs":
            self.execs = {}
        return freed

    def evict_all(self) -> int:
        """Drop every re-derivable family; returns total bytes freed."""
        return sum(self.evict(f) for f in EVICTABLE_FAMILIES)


def _host_in_perm_out(info) -> Optional[np.ndarray]:
    """Host-side weight permutation from the delta's merged edge lists.

    The in-order list is ascending in ``(dst, src)``, so the in-order slot
    of each out-order edge is one searchsorted over 64-bit pair keys — no
    device lexsort.  Duplicate edges make the key->slot map ambiguous;
    return None so the caller falls back to the stable lexsort.
    """
    ki = (info.in_dst.astype(np.int64) << 32) | info.in_src.astype(np.int64)
    if ki.size and bool(np.any(ki[1:] == ki[:-1])):
        return None
    ko = (info.out_dst.astype(np.int64) << 32) | info.out_src.astype(np.int64)
    return np.searchsorted(ki, ko).astype(np.int32)


def _device_layout(layout):
    entry_chunk, entry_slot, local_ids, chunk_block, nb, total = layout
    return (jnp.asarray(entry_chunk), jnp.asarray(entry_slot),
            jnp.asarray(local_ids), jnp.asarray(chunk_block), nb, total)
