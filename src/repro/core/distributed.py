"""Distributed graph engine — the pod as the big-memory machine.

Ringo argues a single 1 TB/80-core box beats clusters for all-but-largest
graphs.  A TPU pod *is* that box at 2025 scale: 256 chips × 16 GB HBM = 4 TB
of flat, fast memory behind an ICI mesh.  This module maps Ringo's OpenMP
loops onto `shard_map`:

* **node space** is range-partitioned into contiguous shards (the dual of
  Ringo's per-thread partitions in graph→table conversion, §2.4);
* **edges live with their destination's owner**, so the PageRank scatter is
  shard-local (contention-free, like the paper's thread-owned partitions)
  and the only collective is the rank-vector `all_gather`;
* **conversion** is the distributed sort-first: local bucket-sort by owner,
  one `all_to_all` to ship edges home, local CSR build — the same
  "sort, count explicitly, bulk copy" with the ICI doing the shuffle;
* results flow back to (sharded) tables, closing the paper's workflow loop.

Everything here also runs under the 512-device production mesh via
`launch/dryrun.py --arch ringo-graph` (see launch/ringo_cells.py).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..launch.sharding import graph_replicated_spec, graph_shard_spec
from .graph import Graph

__all__ = [
    "make_graph_mesh",
    "DistGraph",
    "shard_graph",
    "pagerank_distributed",
    "distributed_to_graph",
    "triangle_count_distributed",
    "degrees_distributed",
]


def make_graph_mesh(n_devices: Optional[int] = None, axis: str = "gp") -> Mesh:
    """1-D mesh over all (or the first n) devices for graph collectives.

    Delegates to :func:`repro.launch.mesh.graph_mesh`, so this module, the
    ``"sharded"`` engine backend, and the serving layer all share one cached
    ``Mesh`` object per device count (identity matters: it keys jit caches).
    """
    from ..launch.mesh import graph_mesh
    return graph_mesh(n_devices, axis)


# ---------------------------------------------------------------------------
# sharded graph container
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class DistGraph:
    """Destination-partitioned edge shards + node-range shards.

    Node space [0, n_pad) is split into D contiguous ranges of ``ns`` nodes.
    Shard d owns nodes [d·ns, (d+1)·ns) and every in-edge pointing to them.

    Arrays (sharded along axis 0 of a (D·X)-leading layout):
      src:       (D·es,)  global src id per edge (dst-owner order)
      dst_local: (D·es,)  dst id *within* the owner's range
      evalid:    (D·es,)  edge validity (padding is False)
      out_deg:   (D·ns,)  out-degree per owned node
      nvalid:    (D·ns,)  node validity
    """

    n_nodes: int
    n_edges: int
    ns: int            # nodes per shard
    es: int            # edge slots per shard
    src: jax.Array
    dst_local: jax.Array
    evalid: jax.Array
    out_deg: jax.Array
    nvalid: jax.Array

    def tree_flatten(self):
        return ((self.src, self.dst_local, self.evalid, self.out_deg,
                 self.nvalid),
                (self.n_nodes, self.n_edges, self.ns, self.es))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_nodes, n_edges, ns, es = aux
        return cls(n_nodes, n_edges, ns, es, *leaves)


def shard_graph(g: Graph, mesh: Mesh, axis: str = "gp") -> DistGraph:
    """Partition a host Graph across the mesh (host-side, once per graph)."""
    d = mesh.shape[axis]
    n = g.n_nodes
    ns = -(-max(n, 1) // d)
    src, dst = (np.asarray(a) for a in g.in_edges())   # sorted by dst
    owner_starts = np.searchsorted(dst, np.arange(d) * ns, side="left")
    owner_ends = np.searchsorted(dst, np.minimum((np.arange(d) + 1) * ns, n),
                                 side="left")
    counts = owner_ends - owner_starts
    es = max(int(counts.max()) if d else 1, 1)
    src_sh = np.zeros((d, es), np.int32)
    dstl_sh = np.zeros((d, es), np.int32)
    ev_sh = np.zeros((d, es), bool)
    for i in range(d):
        lo, hi = int(owner_starts[i]), int(owner_ends[i])
        c = hi - lo
        src_sh[i, :c] = src[lo:hi]
        dstl_sh[i, :c] = dst[lo:hi] - i * ns
        ev_sh[i, :c] = True
    out_deg = np.zeros((d * ns,), np.float32)
    out_deg[:n] = np.asarray(g.out_degrees(), np.float32)
    nvalid = np.zeros((d * ns,), bool)
    nvalid[:n] = True

    shard1 = graph_shard_spec(mesh, axis)
    put = lambda a: jax.device_put(jnp.asarray(a), shard1)
    return DistGraph(
        n_nodes=n, n_edges=g.n_edges, ns=ns, es=es,
        src=put(src_sh.reshape(-1)), dst_local=put(dstl_sh.reshape(-1)),
        evalid=put(ev_sh.reshape(-1)), out_deg=put(out_deg), nvalid=put(nvalid),
    )


# ---------------------------------------------------------------------------
# distributed PageRank
# ---------------------------------------------------------------------------


def pagerank_distributed(dg: DistGraph, mesh: Mesh, n_iter: int = 10,
                         damping: float = 0.85, axis: str = "gp",
                         compress_bf16: bool = False) -> jax.Array:
    """Edge-partitioned PageRank.

    Per iteration: `all_gather` the rank shard (N floats over ICI), gather
    contributions from global sources, `segment_sum` into the locally-owned
    destination range (contention-free — the owner writes its own nodes,
    exactly the paper's thread-partitioned scatter).

    ``compress_bf16`` halves all_gather bytes (beyond-paper optimization,
    recorded in EXPERIMENTS.md §Perf).
    """
    n, ns = dg.n_nodes, dg.ns

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis),
    )
    def run(src, dst_local, evalid, out_deg, nvalid):
        inv_deg = jnp.where(out_deg > 0, 1.0 / jnp.maximum(out_deg, 1.0), 0.0)
        inv_full = jax.lax.all_gather(inv_deg, axis, tiled=True)
        dangling = (out_deg == 0.0) & nvalid
        pr0 = jnp.where(nvalid, 1.0 / n, 0.0)

        def body(_, pr_shard):
            msg = pr_shard.astype(jnp.bfloat16) if compress_bf16 else pr_shard
            pr_full = jax.lax.all_gather(msg, axis, tiled=True).astype(jnp.float32)
            contrib = jnp.where(evalid, pr_full[src] * inv_full[src], 0.0)
            local = jax.ops.segment_sum(contrib, dst_local, num_segments=ns,
                                        indices_are_sorted=True)
            dang = jax.lax.psum(jnp.sum(jnp.where(dangling, pr_shard, 0.0)), axis)
            new = (1.0 - damping) / n + damping * (local + dang / n)
            return jnp.where(nvalid, new, 0.0)

        return jax.lax.fori_loop(0, n_iter, body, pr0)

    pr = run(dg.src, dg.dst_local, dg.evalid, dg.out_deg, dg.nvalid)
    return pr[: n]


# ---------------------------------------------------------------------------
# distributed sort-first conversion (edge table -> DistGraph)
# ---------------------------------------------------------------------------


def distributed_to_graph(src: jax.Array, dst: jax.Array, n_nodes: int,
                         mesh: Mesh, axis: str = "gp") -> DistGraph:
    """The paper's sort-first conversion, distributed.

    Rows (edges) arrive sharded arbitrarily.  Each shard (1) bucket-sorts its
    rows by destination owner — a local lexsort, contention-free; (2) ships
    each bucket to its owner with **one all_to_all**; (3) the owner sorts its
    received edges by destination and counts neighbors explicitly.  This is
    §2.4 verbatim with the ICI playing the memory bus.
    """
    d = mesh.shape[axis]
    ns = -(-max(n_nodes, 1) // d)
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    e = int(src.shape[0])
    per = -(-e // d)
    pad = per * d - e
    src = jnp.concatenate([src, jnp.zeros((pad,), jnp.int32)])
    dst = jnp.concatenate([dst, jnp.full((pad,), -1, jnp.int32)])  # invalid
    valid = jnp.arange(per * d) < e

    # bucket capacity: worst-case rows one shard sends to one owner
    owner = jnp.where(valid, dst // ns, d)  # invalid -> bucket d (dropped)
    owner_2d = owner.reshape(d, per)
    counts = jax.vmap(lambda o: jnp.bincount(o, length=d + 1))(owner_2d)
    cap = int(jnp.max(counts[:, :d]))
    cap = max(cap, 1)

    shard1 = graph_shard_spec(mesh, axis)
    src_s = jax.device_put(src, shard1)
    dst_s = jax.device_put(dst, shard1)
    val_s = jax.device_put(valid, shard1)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(axis), P(axis)),
                       out_specs=(P(axis), P(axis), P(axis)))
    def exchange(s, t, v):
        own = jnp.where(v, t // ns, d)
        order = jnp.argsort(own, stable=True)          # local bucket sort
        s, t, own = s[order], t[order], own[order]
        starts = jnp.searchsorted(own, jnp.arange(d))
        # gather each bucket into its fixed-capacity slot
        idx = starts[:, None] + jnp.arange(cap)[None, :]
        in_bucket = idx < jnp.searchsorted(own, jnp.arange(d), side="right")[:, None]
        idx = jnp.minimum(idx, s.shape[0] - 1)
        sb = jnp.where(in_bucket, s[idx], 0)
        tb = jnp.where(in_bucket, t[idx], 0)
        vb = in_bucket
        # one all_to_all: bucket j of shard i -> shard j slot i
        sb = jax.lax.all_to_all(sb, axis, split_axis=0, concat_axis=0, tiled=True)
        tb = jax.lax.all_to_all(tb, axis, split_axis=0, concat_axis=0, tiled=True)
        vb = jax.lax.all_to_all(vb, axis, split_axis=0, concat_axis=0, tiled=True)
        return sb.reshape(-1), tb.reshape(-1), vb.reshape(-1)

    sb, tb, vb = exchange(src_s, dst_s, val_s)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(axis), P(axis)),
                       out_specs=(P(axis), P(axis), P(axis), P(axis)))
    def finalize(s, t, v):
        # local sort-first: sort received edges by (dst, src); count explicitly
        me = jax.lax.axis_index(axis)
        tl = jnp.where(v, t - me * ns, ns)             # local dst; pad -> ns
        order = jnp.lexsort((s, tl))
        s, tl, v = s[order], tl[order], v[order]
        # out-degree: count srcs locally, reduce, slice the owned range
        # (invalid slots map to the overflow bucket ns*d)
        src_counts = jnp.bincount(jnp.where(v, s, ns * d),
                                  length=ns * d + 1)[: ns * d]
        out_deg_full = jax.lax.psum(src_counts, axis)
        out_deg = jax.lax.dynamic_slice_in_dim(out_deg_full, me * ns, ns)
        return s, tl, v, out_deg.astype(jnp.float32)

    s2, t2, v2, out_deg = finalize(sb, tb, vb)
    es = d * cap
    nvalid = jax.device_put(
        (jnp.arange(d * ns) < n_nodes), shard1)
    return DistGraph(n_nodes=n_nodes, n_edges=e, ns=ns, es=es,
                     src=s2, dst_local=jnp.where(v2, t2, 0), evalid=v2,
                     out_deg=out_deg, nvalid=nvalid)


# ---------------------------------------------------------------------------
# distributed triangle counting
# ---------------------------------------------------------------------------


def triangle_count_distributed(g: Graph, mesh: Mesh, axis: str = "gp",
                               edge_chunk: int = 1 << 14) -> int:
    """Oriented-edge-partitioned triangle counting.

    Each shard intersects the neighborhoods of its share of oriented edges
    (same binary-search core as `algorithms.triangle_count`) against the
    replicated oriented adjacency; `psum` merges the counts.  The adjacency
    is degeneracy-oriented, so its padded width is O(√E) — replication costs
    N·√E, acceptable through the low hundreds of millions of edges; beyond
    that the BSR kernel path shards tiles instead (see DESIGN.md).
    """
    if g.n_edges == 0:
        return 0
    osrc, odst, nbr, _ = g.plan().oriented()
    d = mesh.shape[axis]
    e = int(osrc.shape[0])
    per = -(-e // d)
    per = -(-per // edge_chunk) * edge_chunk   # full chunks: no slice clamping
    pad = per * d - e
    n = g.n_nodes
    osrc = jnp.concatenate([osrc, jnp.zeros((pad,), jnp.int32)])
    odst = jnp.concatenate([odst, jnp.zeros((pad,), jnp.int32)])
    evalid = jnp.arange(per * d) < e

    shard1 = graph_shard_spec(mesh, axis)
    osrc = jax.device_put(osrc, shard1)
    odst = jax.device_put(odst, shard1)
    evalid = jax.device_put(evalid, shard1)
    nbr_r = jax.device_put(nbr, graph_replicated_spec(mesh))  # replicated

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis), P(axis), P(axis), P()),
                       out_specs=P())
    def count(u, v, ev, nbr_l):
        pad_val = nbr_l.shape[0]

        def chunk_body(i, acc):
            lo = i * edge_chunk
            uu = jax.lax.dynamic_slice_in_dim(u, lo, edge_chunk)
            vv = jax.lax.dynamic_slice_in_dim(v, lo, edge_chunk)
            ee = jax.lax.dynamic_slice_in_dim(ev, lo, edge_chunk)
            cand = nbr_l[uu]
            rows = nbr_l[vv]
            pos = jnp.clip(jax.vmap(jnp.searchsorted)(rows, cand), 0,
                           rows.shape[1] - 1)
            hit = (jnp.take_along_axis(rows, pos, axis=1) == cand) & \
                  (cand != pad_val) & ee[:, None]
            return acc + jnp.sum(hit, dtype=jnp.int32)

        n_chunks = u.shape[0] // edge_chunk   # exact by construction
        init = jnp.int32(0)                   # device-varying carry
        if hasattr(jax.lax, "pvary"):         # required once jax >= 0.6
            init = jax.lax.pvary(init, (axis,))
        total = jax.lax.fori_loop(0, n_chunks, chunk_body, init)
        return jax.lax.psum(total, axis)

    return int(count(osrc, odst, evalid, nbr_r))


def degrees_distributed(dg: DistGraph, mesh: Mesh, axis: str = "gp") -> jax.Array:
    """In-degrees from the sharded structure (sanity/benchmark helper)."""
    ns = dg.ns

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(axis), P(axis)),
                       out_specs=P(axis))
    def run(dst_local, evalid):
        return jax.ops.segment_sum(evalid.astype(jnp.int32), dst_local,
                                   num_segments=ns, indices_are_sorted=True)

    return run(dg.dst_local, dg.evalid)[: dg.n_nodes]


# ---------------------------------------------------------------------------
# 2D (SUMMA-style) PageRank — §Perf optimization over the 1D baseline
# ---------------------------------------------------------------------------
#
# The 1D engine all-gathers the full rank vector every iteration (N floats
# per device).  A square 2D partition assigns device (r, c) the edges with
# dst ∈ block r and src ∈ block c; the rank vector lives in N/(d²)-sized
# "shuffle layout" slices.  Per iteration each device only needs
#   all_gather over rows  : its column block  (N/d values)
#   psum_scatter over cols: its partial sums  (N/d values)
# — Θ(N/d) communication instead of Θ(N): a d-fold reduction (16× on the
# 16×16 pod).  This is the vertex-cut insight of PowerGraph re-expressed as
# a dense 2D SpMV decomposition, applied beyond the paper's single machine.


@jax.tree_util.register_pytree_node_class
@dataclass
class DistGraph2D:
    """Square 2D edge partition. Device (r,c): dst ∈ block r, src ∈ block c."""

    n_nodes: int
    n_edges: int
    nb: int            # nodes per block  (N padded to d·nb)
    es: int            # edge slots per device
    d: int             # grid side
    src_local: jax.Array   # (d*d*es,) src offset within col block
    dst_local: jax.Array   # (d*d*es,) dst offset within row block
    evalid: jax.Array      # (d*d*es,)
    inv_deg_col: jax.Array  # (d*nb,) 1/outdeg in column-block layout (P(col))

    def tree_flatten(self):
        return ((self.src_local, self.dst_local, self.evalid,
                 self.inv_deg_col),
                (self.n_nodes, self.n_edges, self.nb, self.es, self.d))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_nodes, n_edges, nb, es, d = aux
        return cls(n_nodes, n_edges, nb, es, d, *leaves)


def shard_graph_2d(g: Graph, mesh: Mesh, row_axis: str = "data",
                   col_axis: str = "model") -> DistGraph2D:
    dr, dc = mesh.shape[row_axis], mesh.shape[col_axis]
    if dr != dc:
        raise ValueError(f"2D pagerank needs a square grid, got {dr}x{dc}")
    d = dr
    n = g.n_nodes
    nb = -(-max(n, 1) // d)
    src, dst = (np.asarray(a) for a in g.in_edges())
    rb, cb = dst // nb, src // nb
    dev = rb * d + cb
    order = np.argsort(dev, kind="stable")
    src, dst, dev = src[order], dst[order], dev[order]
    starts = np.searchsorted(dev, np.arange(d * d))
    ends = np.searchsorted(dev, np.arange(d * d), side="right")
    es = max(int((ends - starts).max()), 1)
    src_l = np.zeros((d * d, es), np.int32)
    dst_l = np.zeros((d * d, es), np.int32)
    ev = np.zeros((d * d, es), bool)
    for i in range(d * d):
        lo, hi = int(starts[i]), int(ends[i])
        c = hi - lo
        src_l[i, :c] = src[lo:hi] % nb
        dst_l[i, :c] = dst[lo:hi] % nb
        ev[i, :c] = True
    inv = np.zeros((d * nb,), np.float32)
    outdeg = np.asarray(g.out_degrees(), np.float32)
    inv[:n] = np.where(outdeg > 0, 1.0 / np.maximum(outdeg, 1.0), 0.0)

    grid = NamedSharding(mesh, P((row_axis, col_axis)))
    col_sh = NamedSharding(mesh, P(col_axis))
    put = jax.device_put
    return DistGraph2D(
        n_nodes=n, n_edges=g.n_edges, nb=nb, es=es, d=d,
        src_local=put(jnp.asarray(src_l.reshape(-1)), grid),
        dst_local=put(jnp.asarray(dst_l.reshape(-1)), grid),
        evalid=put(jnp.asarray(ev.reshape(-1)), grid),
        inv_deg_col=put(jnp.asarray(inv), col_sh),
    )


def pagerank_distributed_2d(dg: DistGraph2D, mesh: Mesh, n_iter: int = 10,
                            damping: float = 0.85, row_axis: str = "data",
                            col_axis: str = "model",
                            compress_bf16: bool = False,
                            unshuffle: bool = True) -> jax.Array:
    """2D PageRank; returns the rank vector in natural node order.

    ``unshuffle=False`` returns the internal shuffle-layout vector —
    iterations compose in that layout, so steady-state use (and the dry-run
    step) skips the one-time reorder epilogue."""
    n, nb, d = dg.n_nodes, dg.nb, dg.d
    slice_len = nb // d if nb % d == 0 else -(-nb // d)
    nb_pad = slice_len * d  # pad block so it splits evenly into d slices

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P((row_axis, col_axis)), P((row_axis, col_axis)),
                  P((row_axis, col_axis)), P(col_axis)),
        out_specs=P((row_axis, col_axis)))
    def run(src_l, dst_l, ev, inv_c):
        r = jax.lax.axis_index(row_axis)
        c = jax.lax.axis_index(col_axis)
        inv_pad = jnp.zeros((nb_pad,), jnp.float32).at[:nb].set(inv_c)
        # x slice for device (r,c): block c, sub-slice r  (shuffle layout)
        gstart = c * nb + r * slice_len
        valid = (jnp.arange(slice_len) + gstart) < n
        x0 = jnp.where(valid, 1.0 / n, 0.0)

        def body(_, x_slice):
            if compress_bf16:
                # barriers on BOTH sides keep the bf16 payload on the wire
                # (XLA otherwise folds the converts through the collective)
                msg = jax.lax.optimization_barrier(
                    x_slice.astype(jnp.bfloat16))
                x_c = jax.lax.optimization_barrier(
                    jax.lax.all_gather(msg, row_axis, tiled=True)
                ).astype(jnp.float32)                           # (nb_pad,)
            else:
                x_c = jax.lax.all_gather(x_slice, row_axis, tiled=True)
            contrib = jnp.where(ev, x_c[src_l] * inv_pad[src_l], 0.0)
            partial = jax.ops.segment_sum(contrib, dst_l, num_segments=nb_pad)
            # inv==0 marks both dangling and padding; mask the padding
            node_ok = (jnp.arange(nb) + c * nb) < n
            dang_local = jnp.sum(jnp.where((inv_pad[:nb] == 0.0) & node_ok,
                                           x_c[:nb], 0.0))
            # column block c is gathered by every row: scale by 1/d once
            dang = jax.lax.psum(jax.lax.psum(dang_local, col_axis),
                                row_axis) / d
            if compress_bf16:
                msg2 = jax.lax.optimization_barrier(
                    partial.astype(jnp.bfloat16))
                y = jax.lax.optimization_barrier(
                    jax.lax.psum_scatter(msg2, col_axis,
                                         scatter_dimension=0, tiled=True)
                ).astype(jnp.float32)
            else:
                y = jax.lax.psum_scatter(partial, col_axis,
                                         scatter_dimension=0, tiled=True)
            # y = slice [r*nb + c*slice_len, +slice_len) — the (c,r)-site
            # x-slot: transpose device grid to restore the shuffle layout
            y_t = _ppermute_2d(y, row_axis, col_axis, d)
            new_valid = (jnp.arange(slice_len) + gstart) < n
            return jnp.where(new_valid,
                             (1.0 - damping) / n + damping * (y_t + dang / n),
                             0.0)

        x = jax.lax.fori_loop(0, n_iter, body, x0)
        return x

    x = run(dg.src_local, dg.dst_local, dg.evalid, dg.inv_deg_col)
    if not unshuffle:
        return x
    # undo the shuffle layout: slice (r,c) holds [c*nb + r*slice_len ...);
    # each block's d slices span nb_pad >= nb, so truncate per block
    slices = jnp.reshape(x, (d, d, slice_len))
    blocks = [slices[:, c, :].reshape(-1)[:nb] for c in range(d)]
    return jnp.concatenate(blocks)[:n]


def _ppermute_2d(y: jax.Array, row_axis: str, col_axis: str, d: int
                 ) -> jax.Array:
    """Transpose the device grid: (r, c) receives from (c, r).

    Two ppermutes (a cyclic shift decomposition of the transpose would be
    cheaper on a real torus; point-to-point pairs express intent and XLA
    maps them onto the ICI)."""
    pairs = []
    for rr in range(d):
        for cc in range(d):
            src_lin = cc * d + rr
            dst_lin = rr * d + cc
            pairs.append((src_lin, dst_lin))
    return jax.lax.ppermute(y, (row_axis, col_axis), pairs)
