"""Layer 2 of the unified traversal engine: backend-dispatched push/pull.

Architecture map (Ringo §2.2: one shared in-memory representation serving a
whole algorithm library):

    core/graph.py       Graph         static-shape dual-CSR storage
        |  .plan()  (identity-memoized; functional updates -> fresh Graph)
        v
    core/plan.py        GraphPlan     cached derived arrays: dst-/src-sorted
        |                             edges, degrees, oriented adjacency,
        |                             BSR tiles, Pallas chunk layouts
        v
    core/engine.py      Exec          gather + segment-reduce primitives
        |   push / pull / fixpoint    with *backend dispatch*:
        |   frontier_fixpoint           "xla"    jax.ops.segment_{sum,min,max}
        |                               "pallas" kernels/segment_sum one-hot
        |                                        matmul (sum reductions)
        |                               "bsr"    kernels/bsr_spmv MXU SpMV
        |                                        (fused gather+sum pulls and
        |                                        pushes via transpose tiles)
        |                               "frontier" sparse compacted-frontier
        |                                        relaxation (monotone min)
        v                               "sharded" shard_map over a 1-D device
                                                 mesh: vertex-range partition
                                                 + halo boundary exchange
    core/algorithms.py  pagerank, hits, eigenvector_centrality, CC, SCC,
                        sssp/bfs (batched multi-source), k-core, label
                        propagation, triangles — thin compositions over the
                        engine, so a backend speedup applies to all of them.

Primitives (all methods of an ``Exec`` pytree, usable inside jit):

    pull(x, combine)        per-node reduce over in-edges of x[src]
    push(x, combine)        per-node reduce over out-edges of x[dst]
    in_src_vals / in_dst_vals / out_src_vals / out_dst_vals
                            edge-order gathers (pull order / push order)
    reduce_in / reduce_out  the bare segmented reductions

``fixpoint`` drives iteration: a fixed number of rounds (``n_iter``) or
until the state stops changing.  Bodies must be module-level functions
(the jitted runner is cached per body); per-call parameters go through
``args`` so they are traced, not baked into the compile cache.

``frontier_fixpoint`` is the sparse dual of ``fixpoint`` for **monotone
min-relaxations** (BFS / SSSP / min-label propagation): instead of relaxing
every edge each round, it keeps a compacted index array of the vertices
whose value changed last round (padded to a bucketed power of two so jit
re-traces are bounded by log2 n), gathers only their adjacency slices from
the plan's CSR offsets, and scatter-mins candidates into the state.  When
the frontier's out-edge count grows past a fraction of |E| it
direction-optimizes into a dense pull over all in-edges (Beamer-style
push/pull switch), which is round-for-round identical to the sparse push
for monotone relaxations — so backend choice never changes results.

Backend/primitive support matrix (unsupported cells transparently fall back
to the XLA primitives, so backend choice never changes semantics — only
speed):

    backend    pull/push sum      min/max     weighted    batched   frontier
    "xla"      segment reduce     yes         yes         yes       —
    "pallas"   one-hot matmul     fallback    yes (f32)   fallback  —
    "bsr"      MXU SpMV           fallback    fallback    fallback  —
    "frontier" fallback (xla)     fallback    —           —         sparse
    "sharded"  shard_map reduce   yes         yes         fallback  —

The "sharded" backend partitions both CSR orders by contiguous vertex
ranges over a 1-D device mesh (``plan.sharded(d)``): each device owns
``ceil(n/d)`` vertices, the whole in-segment of every owned destination
(pull) and out-segment of every owned source (push), plus halo index sets
for the cut edges.  Each round is one ``shard_map``: gather each shard's
exported boundary values, ``all_gather`` them into a halo, reduce locally.
Because a vertex's entire edge segment stays on its owner in global order,
the shard-local segment reduction is **bit-identical** to the global one —
backend neutrality holds exactly, not just approximately.

``select_backend(plan, backend, op=...)`` resolves op/backend combinations:
ops outside a backend's support set (``_FRONTIER_OPS`` for "frontier")
resolve to "xla" instead of failing.
"""

from __future__ import annotations

import functools
import os
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from .. import obs
from ..kernels.bsr_spmv import bsr_spmv
from ..kernels.ops import auto_interpret
from ..kernels.segment_sum import (DEFAULT_BLOCK, DEFAULT_CHUNK,
                                   segment_sum_chunked)
from .table import next_capacity

__all__ = ["BACKENDS", "select_backend", "get_exec", "push", "pull",
           "fixpoint", "frontier_fixpoint", "XlaExec", "PallasExec",
           "BsrExec", "FrontierExec", "ShardedExec"]

BACKENDS = ("xla", "pallas", "bsr", "frontier", "sharded")

# -- observability instruments (module-cached: no registry lookup on the hot
# path; all of them no-op on one attribute check when obs is disabled) -------
_C_BACKEND = {b: obs.counter(f"engine.backend.{b}") for b in BACKENDS}
_C_EXEC_HIT = obs.counter("engine.exec_cache.hits")
_C_EXEC_MISS = obs.counter("engine.exec_cache.misses")
_H_TOL_ITERS = obs.histogram("engine.fixpoint.tol_iters",
                             buckets=obs.COUNT_BUCKETS)
_H_FRONTIER = obs.histogram("engine.frontier.frontier_size",
                            buckets=obs.COUNT_BUCKETS)
_C_ROUNDS = obs.counter("engine.frontier.rounds")
_C_DENSE = obs.counter("engine.frontier.dense_rounds")
_C_SWITCH = obs.counter("engine.frontier.direction_switches")
_C_RELAX = obs.counter("engine.frontier.relaxed_edges")
_C_RETRACE = obs.counter("engine.frontier.retraces")
# (rows, node bucket, edge budget, weighted, dtype) signatures already traced
# by the bucketed-pow2 frontier steps: a new signature = one jit retrace
_TRACED_SHAPES: set = set()

# trace-time flag: True while tracing inside a ShardedExec shard_map manual
# region (``run_loop``), so nested primitive calls emit collectives directly
# instead of opening another (illegal) nested shard_map
_MANUAL_REGION = threading.local()

# Auto-selection thresholds: below them the re-blocked kernels cannot beat
# plain segment reductions (tile/chunk padding dominates).
_PALLAS_MIN_EDGES = 1 << 16
_BSR_MAX_NODES = 1 << 14  # tiles are dense 128x128: only small/dense graphs
# below this the frontier path's per-round host sync outweighs the saved
# edge relaxations (measured ~1.9x dense at 2^15 nodes / 2^18 edges on CPU)
_FRONTIER_MIN_EDGES = 1 << 15
# ops auto-routed to "frontier" on large graphs.  Deliberately narrower than
# _FRONTIER_OPS: batched multi-source runs (the fusion scheduler's case)
# union their frontiers and lose the sparsity win to the vmapped dense
# fixpoint, so algorithms only pass these op tags for single-source calls;
# CC's dense body pointer-jumps (O(log n) rounds vs frontier's O(diameter)),
# so it is frontier-only on request
_FRONTIER_AUTO_OPS = frozenset({"bfs", "sssp"})

# ops with a sparse monotone-relaxation formulation the frontier path serves;
# anything else on "frontier" resolves to "xla" (same results, dense speed)
_FRONTIER_OPS = frozenset({"bfs", "sssp", "connected_components",
                           "label_propagation"})

_REDUCERS = {
    "sum": jax.ops.segment_sum,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
}


def backend_supports(backend: str, op: Optional[str]) -> bool:
    """Whether ``backend`` has a dedicated path for ``op`` (None = generic)."""
    if backend == "frontier" and op is not None:
        return op in _FRONTIER_OPS
    return True


def select_backend(plan, backend: Optional[str] = None,
                   op: Optional[str] = None) -> str:
    """Resolve the backend: per-call override > env var > device/size auto.

    ``op`` (an algorithm name) gates op-aware fallback: a resolved backend
    without a dedicated path for that op — e.g. ``"frontier"`` asked to run
    ``"pagerank"``, which has no sparse monotone formulation — resolves to
    ``"xla"`` so the call succeeds with identical results.
    """
    resolved = _select_backend(plan, backend, op)
    if obs.REGISTRY.enabled:
        _C_BACKEND[resolved].inc()
    return resolved


def _select_backend(plan, backend: Optional[str],
                    op: Optional[str]) -> str:
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
        return backend if backend_supports(backend, op) else "xla"
    env = os.environ.get("REPRO_ENGINE_BACKEND")
    if env:
        return _select_backend(plan, env, op)
    # sparse-traversal ops on large graphs: the frontier path wins on any
    # device (it relaxes only active edges instead of all of them)
    if op in _FRONTIER_AUTO_OPS and plan.n_edges >= _FRONTIER_MIN_EDGES:
        return "frontier"
    if jax.default_backend() == "tpu":
        if plan.n_nodes <= _BSR_MAX_NODES and plan.n_edges >= _PALLAS_MIN_EDGES:
            return "bsr"
        if plan.n_edges >= _PALLAS_MIN_EDGES:
            return "pallas"
    return "xla"


# ---------------------------------------------------------------------------
# Exec pytrees — one per backend
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class XlaExec:
    """Traversal primitives over plan arrays; XLA segment reductions."""

    n_nodes: int
    n_edges: int
    in_src: jax.Array    # in-edge order = sorted by dst (pull order)
    in_dst: jax.Array
    out_src: jax.Array   # out-edge order = sorted by src (push order)
    out_dst: jax.Array

    def tree_flatten(self):
        return ((self.in_src, self.in_dst, self.out_src, self.out_dst),
                (self.n_nodes, self.n_edges))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*aux, *leaves)

    # -- edge-order gathers -----------------------------------------------------
    def in_src_vals(self, x: jax.Array) -> jax.Array:
        return x[self.in_src]

    def in_dst_vals(self, x: jax.Array) -> jax.Array:
        return x[self.in_dst]

    def out_src_vals(self, x: jax.Array) -> jax.Array:
        return x[self.out_src]

    def out_dst_vals(self, x: jax.Array) -> jax.Array:
        return x[self.out_dst]

    # -- segmented reductions ---------------------------------------------------
    def reduce_in(self, edge_vals: jax.Array, combine: str = "sum") -> jax.Array:
        """Per-destination reduction of in-edge-order values (sorted ids)."""
        return _REDUCERS[combine](edge_vals, self.in_dst,
                                  num_segments=self.n_nodes,
                                  indices_are_sorted=True)

    def reduce_out(self, edge_vals: jax.Array, combine: str = "sum") -> jax.Array:
        """Per-source reduction of out-edge-order values (sorted ids)."""
        return _REDUCERS[combine](edge_vals, self.out_src,
                                  num_segments=self.n_nodes,
                                  indices_are_sorted=True)

    # -- fixpoint hooks -----------------------------------------------------------
    def run_loop(self, loop, *args):
        """Run a fixpoint loop (identity wrapper for local backends).

        :class:`ShardedExec` overrides this to run the whole loop inside a
        shard_map manual region so the partitioner cannot turn the body's
        dense reductions into per-shard partials (see there).
        """
        return loop(self, *args)

    # -- fused traversal primitives ---------------------------------------------
    def pull(self, x: jax.Array, combine: str = "sum",
             edge_values: Optional[jax.Array] = None,
             edge_op: str = "mul") -> jax.Array:
        """out[v] = combine over in-edges (u -> v) of x[u] (o edge_values)."""
        ev = self.in_src_vals(x)
        if edge_values is not None:
            ev = ev * edge_values if edge_op == "mul" else ev + edge_values
        return self.reduce_in(ev, combine)

    def push(self, x: jax.Array, combine: str = "sum",
             edge_values: Optional[jax.Array] = None,
             edge_op: str = "mul") -> jax.Array:
        """out[u] = combine over out-edges (u -> v) of x[v] (o edge_values)."""
        ev = self.out_dst_vals(x)
        if edge_values is not None:
            ev = ev * edge_values if edge_op == "mul" else ev + edge_values
        return self.reduce_out(ev, combine)


@jax.tree_util.register_pytree_node_class
@dataclass
class PallasExec(XlaExec):
    """Sum reductions via the one-hot-matmul Pallas kernel.

    The chunk *structure* (which edge lands in which chunk/slot) is static
    per graph and comes precomputed from the plan; each reduction only
    scatters fresh values into the (C, L) chunk buffer on device.  min/max
    and batched reductions fall back to the XLA primitives.
    """

    p_chunk: jax.Array = None   # pull layout: (E,) chunk of edge
    p_slot: jax.Array = None    # (E,) slot within chunk
    p_lids: jax.Array = None    # (C, L) local ids, pad = 128
    p_blk: jax.Array = None     # (C,) owning output block
    q_chunk: jax.Array = None   # push layout (over out_src)
    q_slot: jax.Array = None
    q_lids: jax.Array = None
    q_blk: jax.Array = None
    nb_in: int = 0
    nb_out: int = 0
    interpret: bool = True

    def tree_flatten(self):
        return ((self.in_src, self.in_dst, self.out_src, self.out_dst,
                 self.p_chunk, self.p_slot, self.p_lids, self.p_blk,
                 self.q_chunk, self.q_slot, self.q_lids, self.q_blk),
                (self.n_nodes, self.n_edges, self.nb_in, self.nb_out,
                 self.interpret))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_nodes, n_edges, nb_in, nb_out, interpret = aux
        return cls(n_nodes, n_edges, *leaves, nb_in=nb_in, nb_out=nb_out,
                   interpret=interpret)

    def _chunked_sum(self, edge_vals, chunk_of, slot_of, lids, blk, nb):
        c, l = lids.shape
        cvals = jnp.zeros((c, l), jnp.float32)
        cvals = cvals.at[chunk_of, slot_of].set(edge_vals.astype(jnp.float32))
        out = segment_sum_chunked(cvals, lids, blk, nb,
                                  interpret=self.interpret)
        return out.reshape(-1)[: self.n_nodes]

    def reduce_in(self, edge_vals, combine="sum"):
        # non-sum, batched, and integer reductions fall back: the f32 matmul
        # path would change exactness/dtype, violating backend neutrality
        if (combine != "sum" or edge_vals.ndim != 1
                or not jnp.issubdtype(edge_vals.dtype, jnp.floating)):
            return super().reduce_in(edge_vals, combine)
        return self._chunked_sum(edge_vals, self.p_chunk, self.p_slot,
                                 self.p_lids, self.p_blk, self.nb_in)

    def reduce_out(self, edge_vals, combine="sum"):
        if (combine != "sum" or edge_vals.ndim != 1
                or not jnp.issubdtype(edge_vals.dtype, jnp.floating)):
            return super().reduce_out(edge_vals, combine)
        return self._chunked_sum(edge_vals, self.q_chunk, self.q_slot,
                                 self.q_lids, self.q_blk, self.nb_out)


@jax.tree_util.register_pytree_node_class
@dataclass
class BsrExec(XlaExec):
    """Fused gather+sum pulls AND pushes as MXU SpMV over 128x128 BSR tiles.

    ``pull(x, "sum")`` is ``M @ x`` with M[dst, src] = 1; ``push(x, "sum")``
    is ``Mᵀ @ x`` over a separately-blocked transpose tile stream
    (``plan.bsr_t``), so the HITS hub step takes the same MXU path as the
    authority step.  Everything else — min/max, weighted or batched
    reductions — falls back to XLA.
    """

    tiles: jax.Array = None
    rows: jax.Array = None
    cols: jax.Array = None
    tiles_t: jax.Array = None   # transpose stream: M[src, dst] (push layout)
    rows_t: jax.Array = None
    cols_t: jax.Array = None
    nb: int = 0
    block: int = DEFAULT_BLOCK
    interpret: bool = True

    def tree_flatten(self):
        return ((self.in_src, self.in_dst, self.out_src, self.out_dst,
                 self.tiles, self.rows, self.cols,
                 self.tiles_t, self.rows_t, self.cols_t),
                (self.n_nodes, self.n_edges, self.nb, self.block,
                 self.interpret))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_nodes, n_edges, nb, block, interpret = aux
        return cls(n_nodes, n_edges, *leaves, nb=nb, block=block,
                   interpret=interpret)

    def _spmv(self, tiles, rows, cols, x):
        nb, b = self.nb, self.block
        xp = jnp.zeros((nb * b,), jnp.float32)
        xp = xp.at[: self.n_nodes].set(x.astype(jnp.float32))
        y = bsr_spmv(tiles, rows, cols, xp.reshape(nb, b), nb,
                     interpret=self.interpret)
        return y.reshape(-1)[: self.n_nodes]

    def pull(self, x, combine="sum", edge_values=None, edge_op="mul"):
        if (combine != "sum" or edge_values is not None or x.ndim != 1
                or not jnp.issubdtype(x.dtype, jnp.floating)):
            return super().pull(x, combine, edge_values, edge_op)
        return self._spmv(self.tiles, self.rows, self.cols, x)

    def push(self, x, combine="sum", edge_values=None, edge_op="mul"):
        if (combine != "sum" or edge_values is not None or x.ndim != 1
                or not jnp.issubdtype(x.dtype, jnp.floating)):
            return super().push(x, combine, edge_values, edge_op)
        return self._spmv(self.tiles_t, self.rows_t, self.cols_t, x)


@jax.tree_util.register_pytree_node_class
@dataclass
class FrontierExec(XlaExec):
    """CSR-slice gathers for the sparse frontier path.

    Generic ``pull``/``push`` inherit the XLA reductions (the automatic
    fallback for ops without a sparse formulation); the frontier-specific
    state lives in the trimmed CSR offset arrays consumed by
    :func:`frontier_fixpoint`'s push step and in ``w_perm``, the
    in-order→out-order weight permutation.
    """

    out_ptr: jax.Array = None    # (n+1,) trimmed row pointers
    adj: jax.Array = None        # capacity-padded out-neighbor array
    deg_pad: jax.Array = None    # (n+1,) out-degrees, sentinel row n = 0
    w_perm: jax.Array = None     # (E,) in-order position of each out-order edge

    def tree_flatten(self):
        return ((self.in_src, self.in_dst, self.out_src, self.out_dst,
                 self.out_ptr, self.adj, self.deg_pad, self.w_perm),
                (self.n_nodes, self.n_edges))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*aux, *leaves)


@jax.tree_util.register_pytree_node_class
@dataclass
class ShardedExec(XlaExec):
    """Multi-device primitives: shard_map over a 1-D vertex-range mesh.

    Every 1-D ``pull``/``push``/``reduce_in``/``reduce_out`` runs as one
    ``shard_map`` round: each device gathers its exported boundary values
    (``*_bnd``), an ``all_gather`` concatenates them into the halo, each
    local edge slot gathers from ``[local | halo]`` via ``*_gidx`` and
    reduces into its shard-local segment (``*_seg``).  Padding slots
    reduce into the overflow segment ``ns`` (sliced off), so they cannot
    perturb real vertices even by a signed zero, and because each vertex's
    whole edge segment stays on its owner in global order the result is
    bit-identical to ``XlaExec``.  Batched (2-D) inputs and per-edge-order
    gathers fall back to the inherited global primitives.

    The mesh is static aux data in the pytree (``Mesh`` is hashable), so
    jitted fixpoint runners cache per (device-count, shape) signature and
    the same body re-runs warm on the same mesh.
    """

    d: int = 1                      # shard / device count
    ns: int = 1                     # vertices per shard
    axis: str = "gp"                # mesh axis name
    mesh: object = None             # 1-D jax Mesh (static, hashable)
    p_es: int = 1                   # pull: padded edge slots per shard
    p_halo: int = 1                 # pull: boundary slots per shard
    q_es: int = 1                   # push duals
    q_halo: int = 1
    p_gidx: jax.Array = None        # (d*p_es,) into [local(ns) | halo]
    p_seg: jax.Array = None         # (d*p_es,) local segment, pad -> ns
    p_slot: jax.Array = None        # (E,) in-edge order -> flat pull slot
    p_bnd: jax.Array = None         # (d*p_halo,) exported local ids
    q_gidx: jax.Array = None
    q_seg: jax.Array = None
    q_slot: jax.Array = None
    q_bnd: jax.Array = None

    def tree_flatten(self):
        return ((self.in_src, self.in_dst, self.out_src, self.out_dst,
                 self.p_gidx, self.p_seg, self.p_slot, self.p_bnd,
                 self.q_gidx, self.q_seg, self.q_slot, self.q_bnd),
                (self.n_nodes, self.n_edges, self.d, self.ns, self.axis,
                 self.mesh, self.p_es, self.p_halo, self.q_es, self.q_halo))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        (n_nodes, n_edges, d, ns, axis, mesh,
         p_es, p_halo, q_es, q_halo) = aux
        return cls(n_nodes, n_edges, *leaves[:4], d=d, ns=ns, axis=axis,
                   mesh=mesh, p_es=p_es, p_halo=p_halo, q_es=q_es,
                   q_halo=q_halo, p_gidx=leaves[4], p_seg=leaves[5],
                   p_slot=leaves[6], p_bnd=leaves[7], q_gidx=leaves[8],
                   q_seg=leaves[9], q_slot=leaves[10], q_bnd=leaves[11])

    # -- shard_map building blocks ----------------------------------------------
    #
    # Bit-identity vs "xla" is non-negotiable here, and it constrains the
    # whole design: any value the GSPMD partitioner is free to shard gets
    # its dense reductions (PageRank's dangling mass, HITS' norms) split
    # into per-shard partials + all-reduce — numerically fine, bitwise
    # different.  Sharding *constraints* do not help: the partitioner may
    # re-shard the consumers of a pinned value (observed: it slices the
    # fixpoint carry to f32[ns] per device and partializes the sums even
    # through an optimization_barrier).  So nothing is left to GSPMD:
    # every sharded computation — including the whole fixpoint loop, see
    # ``run_loop`` — executes inside a shard_map *manual* region, where
    # dense ops run full-shape and replicated on every device in exactly
    # the single-device order, and only the explicitly written collectives
    # (the halo exchange and the result gather) move data.

    def _mapped(self, fn, *args):
        """Run ``fn`` in the manual region (entering one if needed).

        Inputs and outputs are replicated (``P()``); ``fn`` slices its own
        shard out of each flat ``(d * per_shard,)`` array via
        ``axis_index``.  ``check_rep=False`` because the final
        ``all_gather`` makes the output replicated by construction, which
        jax's replication checker cannot infer.
        """
        if getattr(_MANUAL_REGION, "active", False):
            return fn(*args)
        return shard_map(fn, mesh=self.mesh,
                         in_specs=(PartitionSpec(),) * len(args),
                         out_specs=PartitionSpec(), check_rep=False)(*args)

    def run_loop(self, loop, *args):
        """Run a whole fixpoint loop as one shard_map manual region.

        The loop carry, the convergence tests, and every dense op in the
        body stay full-shape and replicated on each device; the pull/push
        primitives inside notice the active region (``_MANUAL_REGION``)
        and emit their collectives directly instead of nesting another
        shard_map.
        """

        def fn(ex, args_):
            _MANUAL_REGION.active = True
            try:
                return loop(ex, *args_)
            finally:
                _MANUAL_REGION.active = False

        return shard_map(fn, mesh=self.mesh,
                         in_specs=(PartitionSpec(), PartitionSpec()),
                         out_specs=PartitionSpec(),
                         check_rep=False)(self, args)

    def _rekey(self, edge_vals: jax.Array, slot: jax.Array,
               es: int) -> jax.Array:
        """Scatter global-edge-order values into the flat padded layout."""
        return jnp.zeros((self.d * es,), edge_vals.dtype).at[slot] \
            .set(edge_vals)

    def _exchange_reduce(self, x, combine, gidx, seg, bnd, es, halo,
                         ev_sh, edge_op):
        """One boundary-exchange round: halo gather + local segment reduce."""
        reducer = _REDUCERS[combine]
        d, ns, ax = self.d, self.ns, self.axis

        def run(xp, gidx_f, seg_f, bnd_f, *ev_rest):
            i = jax.lax.axis_index(ax)
            x_loc = jax.lax.dynamic_slice(xp, (i * ns,), (ns,))
            bnd_loc = jax.lax.dynamic_slice(bnd_f, (i * halo,), (halo,))
            halo_vals = jax.lax.all_gather(x_loc[bnd_loc], ax, tiled=True)
            ev = jnp.concatenate([x_loc, halo_vals])[
                jax.lax.dynamic_slice(gidx_f, (i * es,), (es,))]
            if ev_rest:
                e = ev_rest[0]
                if e.ndim:
                    e = jax.lax.dynamic_slice(e, (i * es,), (es,))
                ev = ev * e if edge_op == "mul" else ev + e
            loc = reducer(ev, jax.lax.dynamic_slice(seg_f, (i * es,), (es,)),
                          num_segments=ns + 1, indices_are_sorted=True)[:ns]
            return jax.lax.all_gather(loc, ax, tiled=True)

        args = [jnp.pad(x, (0, d * ns - self.n_nodes)), gidx, seg, bnd]
        if ev_sh is not None:
            args.append(ev_sh)
        return self._mapped(run, *args)[: self.n_nodes]

    def _segment_reduce(self, ev_sh, seg, es, combine):
        """Halo-free shard-local segment reduction (values already placed)."""
        reducer = _REDUCERS[combine]
        ns, ax = self.ns, self.axis

        def run(ev_f, seg_f):
            i = jax.lax.axis_index(ax)
            loc = reducer(jax.lax.dynamic_slice(ev_f, (i * es,), (es,)),
                          jax.lax.dynamic_slice(seg_f, (i * es,), (es,)),
                          num_segments=ns + 1, indices_are_sorted=True)[:ns]
            return jax.lax.all_gather(loc, ax, tiled=True)

        return self._mapped(run, ev_sh, seg)[: self.n_nodes]

    # -- primitives --------------------------------------------------------------
    def reduce_in(self, edge_vals, combine="sum"):
        if edge_vals.ndim != 1:
            return super().reduce_in(edge_vals, combine)
        return self._segment_reduce(
            self._rekey(edge_vals, self.p_slot, self.p_es),
            self.p_seg, self.p_es, combine)

    def reduce_out(self, edge_vals, combine="sum"):
        if edge_vals.ndim != 1:
            return super().reduce_out(edge_vals, combine)
        return self._segment_reduce(
            self._rekey(edge_vals, self.q_slot, self.q_es),
            self.q_seg, self.q_es, combine)

    def pull(self, x, combine="sum", edge_values=None, edge_op="mul"):
        if x.ndim != 1:
            return super().pull(x, combine, edge_values, edge_op)
        ev_sh = None
        if edge_values is not None:
            ev = jnp.asarray(edge_values)
            if ev.ndim > 1:
                return super().pull(x, combine, edge_values, edge_op)
            ev_sh = ev if ev.ndim == 0 \
                else self._rekey(ev, self.p_slot, self.p_es)
        return self._exchange_reduce(x, combine, self.p_gidx, self.p_seg,
                                     self.p_bnd, self.p_es, self.p_halo,
                                     ev_sh, edge_op)

    def push(self, x, combine="sum", edge_values=None, edge_op="mul"):
        if x.ndim != 1:
            return super().push(x, combine, edge_values, edge_op)
        ev_sh = None
        if edge_values is not None:
            ev = jnp.asarray(edge_values)
            if ev.ndim > 1:
                return super().push(x, combine, edge_values, edge_op)
            ev_sh = ev if ev.ndim == 0 \
                else self._rekey(ev, self.q_slot, self.q_es)
        return self._exchange_reduce(x, combine, self.q_gidx, self.q_seg,
                                     self.q_bnd, self.q_es, self.q_halo,
                                     ev_sh, edge_op)


# ---------------------------------------------------------------------------
# exec construction (cached on the plan)
# ---------------------------------------------------------------------------


def shard_count(n_shards: Optional[int] = None) -> int:
    """Resolve the shard count: explicit > REPRO_SHARD_COUNT > all devices."""
    if n_shards is not None:
        return int(n_shards)
    env = os.environ.get("REPRO_SHARD_COUNT")
    if env:
        return int(env)
    return len(jax.devices())


def get_exec(plan, backend: Optional[str] = None, *,
             interpret: Optional[bool] = None,
             block: int = DEFAULT_BLOCK,
             chunk: int = DEFAULT_CHUNK,
             n_shards: Optional[int] = None) -> XlaExec:
    """Backend Exec for a :class:`GraphPlan`, memoized on the plan."""
    backend = select_backend(plan, backend)
    if plan.n_nodes == 0:
        backend = "xla"   # degenerate: the re-blocked kernels have no rows
    interp = auto_interpret(interpret)
    shards = shard_count(n_shards) if backend == "sharded" else 0
    key = (backend, interp, block, chunk, shards)
    ex = plan.execs.get(key)
    if ex is not None:
        _C_EXEC_HIT.inc()
        return ex
    _C_EXEC_MISS.inc()
    base = (plan.n_nodes, plan.n_edges, plan.in_src, plan.in_dst,
            plan.out_src, plan.out_dst)
    if backend == "xla":
        ex = XlaExec(*base)
    elif backend == "sharded":
        sp = plan.sharded(shards)
        ex = ShardedExec(*base, d=sp.d, ns=sp.ns, axis=sp.axis, mesh=sp.mesh,
                         p_es=sp.pull.es, p_halo=sp.pull.halo,
                         q_es=sp.push.es, q_halo=sp.push.halo,
                         p_gidx=sp.pull.gather_idx, p_seg=sp.pull.seg_local,
                         p_slot=sp.pull.edge_slot, p_bnd=sp.pull.boundary,
                         q_gidx=sp.push.gather_idx, q_seg=sp.push.seg_local,
                         q_slot=sp.push.edge_slot, q_bnd=sp.push.boundary)
    elif backend == "frontier":
        ptr, idx, deg_pad = plan.csr_out()
        ex = FrontierExec(*base, ptr, idx, deg_pad, plan.in_perm_out())
    elif backend == "pallas":
        p_chunk, p_slot, p_lids, p_blk, nb_in, _ = plan.chunk_layout_in(chunk)
        q_chunk, q_slot, q_lids, q_blk, nb_out, _ = plan.chunk_layout_out(chunk)
        ex = PallasExec(*base, p_chunk, p_slot, p_lids, p_blk,
                        q_chunk, q_slot, q_lids, q_blk,
                        nb_in=nb_in, nb_out=nb_out, interpret=interp)
    else:
        tiles, rows, cols, nb = plan.bsr(block)
        tiles_t, rows_t, cols_t, _ = plan.bsr_t(block)
        ex = BsrExec(*base, tiles, rows, cols, tiles_t, rows_t, cols_t,
                     nb=nb, block=block, interpret=interp)
    plan.execs[key] = ex
    return ex


def pull(plan, values: jax.Array, combine: str = "sum", *,
         backend: Optional[str] = None,
         edge_values: Optional[jax.Array] = None, edge_op: str = "mul",
         **exec_kw) -> jax.Array:
    """Module-level convenience: ``get_exec(plan, backend).pull(...)``."""
    return get_exec(plan, backend, **exec_kw).pull(values, combine,
                                                   edge_values, edge_op)


def push(plan, values: jax.Array, combine: str = "sum", *,
         backend: Optional[str] = None,
         edge_values: Optional[jax.Array] = None, edge_op: str = "mul",
         **exec_kw) -> jax.Array:
    """Module-level convenience: ``get_exec(plan, backend).push(...)``."""
    return get_exec(plan, backend, **exec_kw).push(values, combine,
                                                   edge_values, edge_op)


# ---------------------------------------------------------------------------
# fixpoint driver
# ---------------------------------------------------------------------------

_RUNNERS = {}

# (runner key, exec type, leaf shapes/dtypes) signatures already run through
# a jitted fixpoint runner: a fresh signature means the call pays a
# trace+lower+compile, which the profiler attributes to
# ``engine.profile.<backend>.compile_ms`` (retrace bracketing) instead of
# ``execute_ms``
_PROFILED_SIGS: set = set()

_BACKEND_OF = {XlaExec: "xla", PallasExec: "pallas", BsrExec: "bsr",
               FrontierExec: "frontier", ShardedExec: "sharded"}


def _profile_sig(key, ex, init, args) -> bool:
    """True when this (runner, exec, shapes) signature is new — i.e. the
    call that just ran traced and compiled."""
    leaves = jax.tree_util.tree_leaves((ex, init, args))
    sig = (key, type(ex),
           tuple((tuple(getattr(leaf, "shape", ())),
                  str(getattr(leaf, "dtype", type(leaf).__name__)))
                 for leaf in leaves))
    if sig in _PROFILED_SIGS:
        return False
    _PROFILED_SIGS.add(sig)
    return True


def _profile_fixpoint(key, ex, init, args, t0: float,
                      rounds: Optional[int] = None) -> None:
    """Record one fixpoint runner call in ``engine.profile.*`` (only
    called when obs is enabled and outside manual regions)."""
    dt_ms = (time.perf_counter() - t0) * 1e3
    backend = _BACKEND_OF.get(type(ex), "xla")
    obs.profile.record_runner(backend, _profile_sig(key, ex, init, args),
                              dt_ms)
    if isinstance(ex, ShardedExec):
        # per-round halo bytes are static layout facts (matches
        # ShardPlan.halo_bytes_per_round); per-round halo *time* is not
        # attributable from the host — the whole loop runs inside one
        # shard_map manual region — so loop wall time is what's recorded
        obs.profile.record_sharded(ex.d, ex.d * ex.p_halo * 4, dt_ms,
                                   rounds=rounds)


def _leaf_changed(o: jax.Array, n: jax.Array) -> jax.Array:
    neq = o != n
    if jnp.issubdtype(jnp.asarray(o).dtype, jnp.inexact):
        # NaN != NaN would spin the loop forever; a NaN that stays NaN is
        # converged (the deleted strict-decrease conditions terminated too)
        neq = neq & ~(jnp.isnan(o) & jnp.isnan(n))
    return jnp.any(neq)


def _changed(old, new) -> jax.Array:
    flags = [_leaf_changed(o, n) for o, n in
             zip(jax.tree_util.tree_leaves(old), jax.tree_util.tree_leaves(new))]
    return functools.reduce(jnp.logical_or, flags, jnp.bool_(False))


def _residual(old, new) -> jax.Array:
    """L1 residual between two state pytrees (f32 accumulation)."""
    tot = jnp.float32(0.0)
    for o, n in zip(jax.tree_util.tree_leaves(old),
                    jax.tree_util.tree_leaves(new)):
        tot = tot + jnp.sum(jnp.abs(n.astype(jnp.float32)
                                    - o.astype(jnp.float32)))
    return tot


def _runner(body: Callable, fixed, manual: bool = False):
    # ``manual`` = this fixpoint is being traced inside an enclosing
    # ShardedExec.run_loop region (nested fixpoints: SCC's color/reach
    # solves inside _scc_round).  Those must NOT wrap another shard_map —
    # manual regions cannot nest — so they run the bare loop; keying the
    # jit cache on the flag keeps the two tracings from sharing a jaxpr.
    key = (body, fixed, manual)
    run = _RUNNERS.get(key)
    if run is None:
        if fixed == "tol":
            def loop_py(ex, init, max_iter, tol, *args):
                def cond(carry):
                    _, i, res = carry
                    return (res > tol) & (i < max_iter)

                def step(carry):
                    s, i, _ = carry
                    ns = body(ex, s, *args)
                    return ns, i + 1, _residual(s, ns)

                final, iters, _ = jax.lax.while_loop(
                    cond, step, (init, jnp.int32(0), jnp.float32(jnp.inf)))
                # the iteration counter rides along so the caller can expose
                # warm-vs-cold convergence as a metric (one scalar, fetched
                # only when obs is enabled and the call is not being traced)
                return final, iters
        elif fixed:
            def loop_py(ex, init, n_iter, *args):
                return jax.lax.fori_loop(
                    0, n_iter, lambda _, s: body(ex, s, *args), init)
        else:
            def loop_py(ex, init, max_iter, *args):
                def cond(carry):
                    _, i, changed = carry
                    return changed & (i < max_iter)

                def step(carry):
                    s, i, _ = carry
                    ns = body(ex, s, *args)
                    return ns, i + 1, _changed(s, ns)

                final, _, _ = jax.lax.while_loop(
                    cond, step, (init, jnp.int32(0), jnp.bool_(True)))
                return final

        if manual:
            def run_py(ex, *a):
                return loop_py(ex, *a)
        else:
            def run_py(ex, *a):
                return ex.run_loop(loop_py, *a)

        run = _RUNNERS[key] = jax.jit(run_py)
    return run


def fixpoint(plan_or_exec, body: Callable, init, *,
             n_iter: Optional[int] = None, max_iter: Optional[int] = None,
             tol: Optional[float] = None,
             backend: Optional[str] = None, args: Tuple = (),
             obs_tag: Optional[str] = None):
    """Iterate ``body(exec, state, *args) -> state`` on the engine.

    With ``n_iter``: exactly that many rounds (fori_loop).  With ``tol``:
    until the L1 residual between consecutive states drops to ``tol``,
    capped at ``max_iter`` — the convergence stopping rule that makes
    warm-started contractions (PageRank from a parent vector after a small
    delta) finish in a handful of rounds.  Otherwise: until the state stops
    changing, capped at ``max_iter`` (while_loop).  ``body`` must be a
    module-level function — the jitted runner is cached per body identity;
    pass per-call parameters via ``args`` (traced).  ``obs_tag`` names the
    call in the tol-mode iteration-count metric
    (``engine.fixpoint.tol_iters[.<tag>]``) — how warm-started solves show
    their shortened convergence.
    """
    ex = (plan_or_exec if isinstance(plan_or_exec, XlaExec)
          else get_exec(plan_or_exec, backend))
    manual = getattr(_MANUAL_REGION, "active", False)
    # profiling brackets only make sense for real host-side calls: inside a
    # manual region this function runs at trace time, where wall clocks
    # measure tracing of the enclosing jit, not execution
    prof = obs.REGISTRY.enabled and not manual
    if tol is not None:
        cap = np.iinfo(np.int32).max if max_iter is None else int(max_iter)
        t0 = time.perf_counter() if prof else 0.0
        out, iters = _runner(body, "tol", manual)(ex, init, jnp.int32(cap),
                                                  jnp.float32(tol), *args)
        # skip the scalar fetch when disabled; under a jax trace (vmapped
        # tol solves) the counter is abstract and cannot be observed
        if obs.REGISTRY.enabled:
            try:
                n = int(iters)
            except Exception:        # tracer-stage call: no concrete count
                n = None
            if n is not None:
                _H_TOL_ITERS.observe(n)
                if obs_tag:
                    obs.histogram(f"engine.fixpoint.tol_iters.{obs_tag}",
                                  buckets=obs.COUNT_BUCKETS).observe(n)
            if prof:
                _profile_fixpoint(("tol", body), ex, init, args, t0,
                                  rounds=n)
        return out
    if n_iter is not None:
        t0 = time.perf_counter() if prof else 0.0
        out = _runner(body, True, manual)(ex, init, jnp.int32(n_iter), *args)
        if prof:
            _profile_fixpoint(("fori", body), ex, init, args, t0,
                              rounds=int(n_iter))
        return out
    cap = np.iinfo(np.int32).max if max_iter is None else int(max_iter)
    t0 = time.perf_counter() if prof else 0.0
    out = _runner(body, False, manual)(ex, init, jnp.int32(cap), *args)
    if prof:
        _profile_fixpoint(("while", body), ex, init, args, t0)
    return out


# ---------------------------------------------------------------------------
# frontier fixpoint driver — sparse monotone min-relaxation
# ---------------------------------------------------------------------------

# direction-optimization switch: dense pull once the frontier's out-edges
# exceed |E| / _DENSE_EDGE_DIV (Beamer-style; the dense round costs ~|E|,
# the sparse round costs ~frontier edges plus compaction)
_DENSE_EDGE_DIV = 4
_MIN_BUCKET = 16


def _stats_of(mask, deg):
    """(frontier size, frontier out-edge count) — the host's planning pair."""
    return jnp.stack([jnp.sum(mask.astype(jnp.int32)),
                      jnp.sum(jnp.where(mask, deg, 0)).astype(jnp.int32)])


def _frontier_round_out(ex, state, new, caps, t):
    """Shared step epilogue: freeze capped rows, next mask + its stats.

    The (frontier size, frontier out-edge count) pair the host needs to
    plan the next round is computed inside the same jitted step, so each
    round costs one dispatch and one scalar fetch.
    """
    new = jnp.where((t < caps)[:, None], new, state)
    mask = jnp.any(new < state, axis=0)
    return new, mask, _stats_of(mask, ex.deg_pad[: ex.n_nodes])


@functools.partial(jax.jit, static_argnames=("e_budget",))
def _frontier_push_step(ex, state, f_idx, w_out, caps, t, *, e_budget):
    """One sparse push round over the compacted frontier.

    ``f_idx`` is the frontier padded with the sentinel vertex ``n`` (degree
    0 in ``deg_pad``, so pad slots own no edge lanes); ``e_budget`` is the
    static edge-lane count (bucketed power of two >= frontier out-edges).
    Each lane finds its owning frontier slot by prefix-sum search, gathers
    the neighbor from the plan CSR, and scatter-mins ``state[u] (+ w)``
    into the neighbor's column.  Rows with ``t >= caps`` are frozen (the
    per-request depth limits of fused service batches).
    """
    n = ex.n_nodes
    deg = ex.deg_pad[f_idx]
    off = ex.out_ptr[f_idx]
    cum = jnp.cumsum(deg) - deg                           # exclusive prefix
    total = jnp.sum(deg)
    j = jnp.arange(e_budget, dtype=deg.dtype)
    owner = jnp.clip(jnp.searchsorted(cum, j, side="right") - 1,
                     0, f_idx.shape[0] - 1)
    valid = j < total
    pos = jnp.clip(off[owner] + (j - cum[owner]), 0, ex.adj.shape[0] - 1)
    v = jnp.where(valid, ex.adj[pos], n)                  # pad -> sentinel col
    u = jnp.minimum(f_idx[owner], n - 1)
    cand = state[:, u]
    if w_out is not None:
        # scalar = uniform edge weight (BFS hops); array = per-edge, already
        # re-keyed to out order
        cand = cand + (w_out if w_out.ndim == 0 else w_out[pos])
    new = jnp.pad(state, ((0, 0), (0, 1))).at[:, v].min(cand)[:, :n]
    return _frontier_round_out(ex, state, new, caps, t)


@jax.jit
def _frontier_dense_step(ex, state, w_in, caps, t):
    """One dense pull round (the direction-optimized big-frontier path).

    Round-for-round identical to the sparse push: for a monotone min
    relaxation, re-relaxing an edge whose source did not change last round
    is a no-op (its contribution is already in the state).
    """
    def one(s):
        ev = s[ex.in_src]
        if w_in is not None:
            ev = ev + w_in          # scalar hop or per-edge (in-order) array
        return jax.ops.segment_min(ev, ex.in_dst, num_segments=ex.n_nodes,
                                   indices_are_sorted=True)

    # single-row runs skip vmap batching overhead (the common service case)
    relaxed = one(state[0])[None] if state.shape[0] == 1 \
        else jax.vmap(one)(state)
    new = jnp.minimum(state, relaxed)
    return _frontier_round_out(ex, state, new, caps, t)


_frontier_stats = jax.jit(_stats_of)   # round-0 entry; later rounds get
                                       # stats fused into their step


def frontier_fixpoint(plan_or_exec, init, frontier, *,
                      weights: Optional[jax.Array] = None,
                      caps=None, max_rounds: Optional[int] = None):
    """Sparse monotone min-relaxation to fixpoint (BFS/SSSP/min-label).

    Iterates ``state[v] <- min(state[v], min over frontier in-neighbors u of
    state[u] (+ w(u, v)))`` where the frontier is the set of vertices whose
    value changed last round, until the frontier empties (or a round bound).
    The frontier is kept *compacted* — an index array padded to a bucketed
    power of two, so jit re-traces are bounded by log2 n — and each round
    relaxes only the outgoing edges of frontier vertices, switching to a
    dense pull over all edges when the frontier exceeds ``|E| / 4``.

    ``init`` is ``(n,)`` or batched ``(k, n)``; ``frontier`` a ``(n,)`` bool
    mask seeding round 0 (for batched runs: the union over rows).
    ``weights`` is per-edge in in-edge order (the sssp convention) and is
    re-keyed to CSR push order via the plan's cached permutation.  ``caps``
    (scalar or ``(k,)``) freezes row ``i`` after ``caps[i]`` rounds — the
    exact equivalent of running that row alone for ``caps[i]`` iterations.

    The host drives the loop (frontier sizes are data-dependent); state and
    mask stay on device, with one scalar fetch per round.
    """
    ex = (plan_or_exec if isinstance(plan_or_exec, FrontierExec)
          else get_exec(plan_or_exec, "frontier"))
    state = jnp.asarray(init)
    batched = state.ndim == 2
    if not batched:
        state = state[None, :]
    k, n = state.shape
    if n == 0 or k == 0 or ex.n_edges == 0:
        return jnp.asarray(init)   # no edges: nothing can relax
    w_in = w_out = None
    if weights is not None:
        w_in = jnp.asarray(weights)
        # scalars broadcast (no per-edge gather); arrays re-key to out order
        w_out = w_in if w_in.ndim == 0 else w_in[ex.w_perm]
    big = np.iinfo(np.int32).max
    if caps is None:
        caps_np = np.full((k,), big, np.int64)
    else:
        caps_np = np.broadcast_to(
            np.atleast_1d(np.asarray(caps, dtype=np.int64)), (k,))
    caps_arr = jnp.asarray(np.minimum(caps_np, big).astype(np.int32))
    bound = int(min(caps_np.max(), big if max_rounds is None else max_rounds))

    mask = jnp.asarray(frontier, bool)
    stats = _frontier_stats(mask, ex.deg_pad[:-1])
    t = 0
    reg_on = obs.REGISTRY.enabled
    prev_dense: Optional[bool] = None
    # per-round profile timing: a round's kernel completes at the *next*
    # iteration's stats fetch (the one host sync per round), so each round
    # is timed from just before its step dispatch to just after that fetch
    prof_mode: Optional[str] = None
    prof_t0 = 0.0
    with obs.TRACER.span("engine.frontier_fixpoint", rows=k, nodes=n,
                         edges=int(ex.n_edges),
                         weighted=weights is not None) as fspan:
        while t < bound:
            cnt, fe = (int(x) for x in np.asarray(stats))  # one fetch/round
            if prof_mode is not None:
                obs.profile.record_frontier_round(
                    prof_mode, (time.perf_counter() - prof_t0) * 1e3)
                prof_mode = None
            if cnt == 0:
                break
            tj = jnp.int32(t)
            dense = fe * _DENSE_EDGE_DIV >= ex.n_edges
            if reg_on:
                _H_FRONTIER.observe(cnt)
                _C_ROUNDS.inc()
                _C_RELAX.inc(fe)
                if dense:
                    _C_DENSE.inc()
                if prev_dense is not None and dense != prev_dense:
                    _C_SWITCH.inc()
                prof_mode = "dense" if dense else "sparse"
                prof_t0 = time.perf_counter()
            if dense:
                rspan = obs.TRACER.span("engine.frontier.round", round=t,
                                        frontier=cnt, edges=fe, mode="dense")
                state, mask, stats = _frontier_dense_step(ex, state, w_in,
                                                          caps_arr, tj)
            else:
                b = min(next_capacity(cnt, minimum=_MIN_BUCKET),
                        next_capacity(max(n, 1)))
                f_idx = jnp.nonzero(mask, size=b,
                                    fill_value=n)[0].astype(jnp.int32)
                eb = next_capacity(max(fe, 1), minimum=_MIN_BUCKET)
                shape_sig = (k, b, eb, w_out is None, str(state.dtype))
                if shape_sig not in _TRACED_SHAPES:
                    _TRACED_SHAPES.add(shape_sig)
                    if reg_on:
                        _C_RETRACE.inc()
                rspan = obs.TRACER.span("engine.frontier.round", round=t,
                                        frontier=cnt, edges=fe,
                                        mode="sparse", bucket=b, e_budget=eb)
                state, mask, stats = _frontier_push_step(ex, state, f_idx,
                                                         w_out, caps_arr, tj,
                                                         e_budget=eb)
            rspan.finish()
            prev_dense = dense
            t += 1
        fspan.set(rounds=t)
    return state if batched else state[0]
