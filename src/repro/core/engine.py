"""Layer 2 of the unified traversal engine: backend-dispatched push/pull.

Architecture map (Ringo §2.2: one shared in-memory representation serving a
whole algorithm library):

    core/graph.py       Graph         static-shape dual-CSR storage
        |  .plan()  (identity-memoized; functional updates -> fresh Graph)
        v
    core/plan.py        GraphPlan     cached derived arrays: dst-/src-sorted
        |                             edges, degrees, oriented adjacency,
        |                             BSR tiles, Pallas chunk layouts
        v
    core/engine.py      Exec          gather + segment-reduce primitives
        |   push / pull / fixpoint    with *backend dispatch*:
        |                               "xla"    jax.ops.segment_{sum,min,max}
        |                               "pallas" kernels/segment_sum one-hot
        |                                        matmul (sum reductions)
        |                               "bsr"    kernels/bsr_spmv MXU SpMV
        v                                        (fused gather+sum pulls and
                                                 pushes via transpose tiles)
    core/algorithms.py  pagerank, hits, eigenvector_centrality, CC, SCC,
                        sssp/bfs (batched multi-source), k-core, label
                        propagation, triangles — thin compositions over the
                        engine, so a backend speedup applies to all of them.

Primitives (all methods of an ``Exec`` pytree, usable inside jit):

    pull(x, combine)        per-node reduce over in-edges of x[src]
    push(x, combine)        per-node reduce over out-edges of x[dst]
    in_src_vals / in_dst_vals / out_src_vals / out_dst_vals
                            edge-order gathers (pull order / push order)
    reduce_in / reduce_out  the bare segmented reductions

``fixpoint`` drives iteration: a fixed number of rounds (``n_iter``) or
until the state stops changing.  Bodies must be module-level functions
(the jitted runner is cached per body); per-call parameters go through
``args`` so they are traced, not baked into the compile cache.

Backends that cannot serve a request (min/max or integer sums on "pallas",
weighted, batched or integer pulls/pushes on "bsr") transparently fall back
to the XLA primitives, so backend choice never changes semantics — only
speed.
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.bsr_spmv import bsr_spmv
from ..kernels.ops import auto_interpret
from ..kernels.segment_sum import (DEFAULT_BLOCK, DEFAULT_CHUNK,
                                   segment_sum_chunked)

__all__ = ["BACKENDS", "select_backend", "get_exec", "push", "pull",
           "fixpoint", "XlaExec", "PallasExec", "BsrExec"]

BACKENDS = ("xla", "pallas", "bsr")

# Auto-selection thresholds: below them the re-blocked kernels cannot beat
# plain segment reductions (tile/chunk padding dominates).
_PALLAS_MIN_EDGES = 1 << 16
_BSR_MAX_NODES = 1 << 14  # tiles are dense 128x128: only small/dense graphs

_REDUCERS = {
    "sum": jax.ops.segment_sum,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
}


def select_backend(plan, backend: Optional[str] = None) -> str:
    """Resolve the backend: per-call override > env var > device/size auto."""
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
        return backend
    env = os.environ.get("REPRO_ENGINE_BACKEND")
    if env:
        return select_backend(plan, env)
    if jax.default_backend() == "tpu":
        if plan.n_nodes <= _BSR_MAX_NODES and plan.n_edges >= _PALLAS_MIN_EDGES:
            return "bsr"
        if plan.n_edges >= _PALLAS_MIN_EDGES:
            return "pallas"
    return "xla"


# ---------------------------------------------------------------------------
# Exec pytrees — one per backend
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class XlaExec:
    """Traversal primitives over plan arrays; XLA segment reductions."""

    n_nodes: int
    n_edges: int
    in_src: jax.Array    # in-edge order = sorted by dst (pull order)
    in_dst: jax.Array
    out_src: jax.Array   # out-edge order = sorted by src (push order)
    out_dst: jax.Array

    def tree_flatten(self):
        return ((self.in_src, self.in_dst, self.out_src, self.out_dst),
                (self.n_nodes, self.n_edges))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*aux, *leaves)

    # -- edge-order gathers -----------------------------------------------------
    def in_src_vals(self, x: jax.Array) -> jax.Array:
        return x[self.in_src]

    def in_dst_vals(self, x: jax.Array) -> jax.Array:
        return x[self.in_dst]

    def out_src_vals(self, x: jax.Array) -> jax.Array:
        return x[self.out_src]

    def out_dst_vals(self, x: jax.Array) -> jax.Array:
        return x[self.out_dst]

    # -- segmented reductions ---------------------------------------------------
    def reduce_in(self, edge_vals: jax.Array, combine: str = "sum") -> jax.Array:
        """Per-destination reduction of in-edge-order values (sorted ids)."""
        return _REDUCERS[combine](edge_vals, self.in_dst,
                                  num_segments=self.n_nodes,
                                  indices_are_sorted=True)

    def reduce_out(self, edge_vals: jax.Array, combine: str = "sum") -> jax.Array:
        """Per-source reduction of out-edge-order values (sorted ids)."""
        return _REDUCERS[combine](edge_vals, self.out_src,
                                  num_segments=self.n_nodes,
                                  indices_are_sorted=True)

    # -- fused traversal primitives ---------------------------------------------
    def pull(self, x: jax.Array, combine: str = "sum",
             edge_values: Optional[jax.Array] = None,
             edge_op: str = "mul") -> jax.Array:
        """out[v] = combine over in-edges (u -> v) of x[u] (o edge_values)."""
        ev = self.in_src_vals(x)
        if edge_values is not None:
            ev = ev * edge_values if edge_op == "mul" else ev + edge_values
        return self.reduce_in(ev, combine)

    def push(self, x: jax.Array, combine: str = "sum",
             edge_values: Optional[jax.Array] = None,
             edge_op: str = "mul") -> jax.Array:
        """out[u] = combine over out-edges (u -> v) of x[v] (o edge_values)."""
        ev = self.out_dst_vals(x)
        if edge_values is not None:
            ev = ev * edge_values if edge_op == "mul" else ev + edge_values
        return self.reduce_out(ev, combine)


@jax.tree_util.register_pytree_node_class
@dataclass
class PallasExec(XlaExec):
    """Sum reductions via the one-hot-matmul Pallas kernel.

    The chunk *structure* (which edge lands in which chunk/slot) is static
    per graph and comes precomputed from the plan; each reduction only
    scatters fresh values into the (C, L) chunk buffer on device.  min/max
    and batched reductions fall back to the XLA primitives.
    """

    p_chunk: jax.Array = None   # pull layout: (E,) chunk of edge
    p_slot: jax.Array = None    # (E,) slot within chunk
    p_lids: jax.Array = None    # (C, L) local ids, pad = 128
    p_blk: jax.Array = None     # (C,) owning output block
    q_chunk: jax.Array = None   # push layout (over out_src)
    q_slot: jax.Array = None
    q_lids: jax.Array = None
    q_blk: jax.Array = None
    nb_in: int = 0
    nb_out: int = 0
    interpret: bool = True

    def tree_flatten(self):
        return ((self.in_src, self.in_dst, self.out_src, self.out_dst,
                 self.p_chunk, self.p_slot, self.p_lids, self.p_blk,
                 self.q_chunk, self.q_slot, self.q_lids, self.q_blk),
                (self.n_nodes, self.n_edges, self.nb_in, self.nb_out,
                 self.interpret))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_nodes, n_edges, nb_in, nb_out, interpret = aux
        return cls(n_nodes, n_edges, *leaves, nb_in=nb_in, nb_out=nb_out,
                   interpret=interpret)

    def _chunked_sum(self, edge_vals, chunk_of, slot_of, lids, blk, nb):
        c, l = lids.shape
        cvals = jnp.zeros((c, l), jnp.float32)
        cvals = cvals.at[chunk_of, slot_of].set(edge_vals.astype(jnp.float32))
        out = segment_sum_chunked(cvals, lids, blk, nb,
                                  interpret=self.interpret)
        return out.reshape(-1)[: self.n_nodes]

    def reduce_in(self, edge_vals, combine="sum"):
        # non-sum, batched, and integer reductions fall back: the f32 matmul
        # path would change exactness/dtype, violating backend neutrality
        if (combine != "sum" or edge_vals.ndim != 1
                or not jnp.issubdtype(edge_vals.dtype, jnp.floating)):
            return super().reduce_in(edge_vals, combine)
        return self._chunked_sum(edge_vals, self.p_chunk, self.p_slot,
                                 self.p_lids, self.p_blk, self.nb_in)

    def reduce_out(self, edge_vals, combine="sum"):
        if (combine != "sum" or edge_vals.ndim != 1
                or not jnp.issubdtype(edge_vals.dtype, jnp.floating)):
            return super().reduce_out(edge_vals, combine)
        return self._chunked_sum(edge_vals, self.q_chunk, self.q_slot,
                                 self.q_lids, self.q_blk, self.nb_out)


@jax.tree_util.register_pytree_node_class
@dataclass
class BsrExec(XlaExec):
    """Fused gather+sum pulls AND pushes as MXU SpMV over 128x128 BSR tiles.

    ``pull(x, "sum")`` is ``M @ x`` with M[dst, src] = 1; ``push(x, "sum")``
    is ``Mᵀ @ x`` over a separately-blocked transpose tile stream
    (``plan.bsr_t``), so the HITS hub step takes the same MXU path as the
    authority step.  Everything else — min/max, weighted or batched
    reductions — falls back to XLA.
    """

    tiles: jax.Array = None
    rows: jax.Array = None
    cols: jax.Array = None
    tiles_t: jax.Array = None   # transpose stream: M[src, dst] (push layout)
    rows_t: jax.Array = None
    cols_t: jax.Array = None
    nb: int = 0
    block: int = DEFAULT_BLOCK
    interpret: bool = True

    def tree_flatten(self):
        return ((self.in_src, self.in_dst, self.out_src, self.out_dst,
                 self.tiles, self.rows, self.cols,
                 self.tiles_t, self.rows_t, self.cols_t),
                (self.n_nodes, self.n_edges, self.nb, self.block,
                 self.interpret))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_nodes, n_edges, nb, block, interpret = aux
        return cls(n_nodes, n_edges, *leaves, nb=nb, block=block,
                   interpret=interpret)

    def _spmv(self, tiles, rows, cols, x):
        nb, b = self.nb, self.block
        xp = jnp.zeros((nb * b,), jnp.float32)
        xp = xp.at[: self.n_nodes].set(x.astype(jnp.float32))
        y = bsr_spmv(tiles, rows, cols, xp.reshape(nb, b), nb,
                     interpret=self.interpret)
        return y.reshape(-1)[: self.n_nodes]

    def pull(self, x, combine="sum", edge_values=None, edge_op="mul"):
        if (combine != "sum" or edge_values is not None or x.ndim != 1
                or not jnp.issubdtype(x.dtype, jnp.floating)):
            return super().pull(x, combine, edge_values, edge_op)
        return self._spmv(self.tiles, self.rows, self.cols, x)

    def push(self, x, combine="sum", edge_values=None, edge_op="mul"):
        if (combine != "sum" or edge_values is not None or x.ndim != 1
                or not jnp.issubdtype(x.dtype, jnp.floating)):
            return super().push(x, combine, edge_values, edge_op)
        return self._spmv(self.tiles_t, self.rows_t, self.cols_t, x)


# ---------------------------------------------------------------------------
# exec construction (cached on the plan)
# ---------------------------------------------------------------------------


def get_exec(plan, backend: Optional[str] = None, *,
             interpret: Optional[bool] = None,
             block: int = DEFAULT_BLOCK,
             chunk: int = DEFAULT_CHUNK) -> XlaExec:
    """Backend Exec for a :class:`GraphPlan`, memoized on the plan."""
    backend = select_backend(plan, backend)
    interp = auto_interpret(interpret)
    key = (backend, interp, block, chunk)
    ex = plan.execs.get(key)
    if ex is not None:
        return ex
    base = (plan.n_nodes, plan.n_edges, plan.in_src, plan.in_dst,
            plan.out_src, plan.out_dst)
    if backend == "xla":
        ex = XlaExec(*base)
    elif backend == "pallas":
        p_chunk, p_slot, p_lids, p_blk, nb_in, _ = plan.chunk_layout_in(chunk)
        q_chunk, q_slot, q_lids, q_blk, nb_out, _ = plan.chunk_layout_out(chunk)
        ex = PallasExec(*base, p_chunk, p_slot, p_lids, p_blk,
                        q_chunk, q_slot, q_lids, q_blk,
                        nb_in=nb_in, nb_out=nb_out, interpret=interp)
    else:
        tiles, rows, cols, nb = plan.bsr(block)
        tiles_t, rows_t, cols_t, _ = plan.bsr_t(block)
        ex = BsrExec(*base, tiles, rows, cols, tiles_t, rows_t, cols_t,
                     nb=nb, block=block, interpret=interp)
    plan.execs[key] = ex
    return ex


def pull(plan, values: jax.Array, combine: str = "sum", *,
         backend: Optional[str] = None,
         edge_values: Optional[jax.Array] = None, edge_op: str = "mul",
         **exec_kw) -> jax.Array:
    """Module-level convenience: ``get_exec(plan, backend).pull(...)``."""
    return get_exec(plan, backend, **exec_kw).pull(values, combine,
                                                   edge_values, edge_op)


def push(plan, values: jax.Array, combine: str = "sum", *,
         backend: Optional[str] = None,
         edge_values: Optional[jax.Array] = None, edge_op: str = "mul",
         **exec_kw) -> jax.Array:
    """Module-level convenience: ``get_exec(plan, backend).push(...)``."""
    return get_exec(plan, backend, **exec_kw).push(values, combine,
                                                   edge_values, edge_op)


# ---------------------------------------------------------------------------
# fixpoint driver
# ---------------------------------------------------------------------------

_RUNNERS = {}


def _leaf_changed(o: jax.Array, n: jax.Array) -> jax.Array:
    neq = o != n
    if jnp.issubdtype(jnp.asarray(o).dtype, jnp.inexact):
        # NaN != NaN would spin the loop forever; a NaN that stays NaN is
        # converged (the deleted strict-decrease conditions terminated too)
        neq = neq & ~(jnp.isnan(o) & jnp.isnan(n))
    return jnp.any(neq)


def _changed(old, new) -> jax.Array:
    flags = [_leaf_changed(o, n) for o, n in
             zip(jax.tree_util.tree_leaves(old), jax.tree_util.tree_leaves(new))]
    return functools.reduce(jnp.logical_or, flags, jnp.bool_(False))


def _runner(body: Callable, fixed: bool):
    key = (body, fixed)
    run = _RUNNERS.get(key)
    if run is None:
        if fixed:
            def run_py(ex, init, n_iter, *args):
                return jax.lax.fori_loop(
                    0, n_iter, lambda _, s: body(ex, s, *args), init)
        else:
            def run_py(ex, init, max_iter, *args):
                def cond(carry):
                    _, i, changed = carry
                    return changed & (i < max_iter)

                def step(carry):
                    s, i, _ = carry
                    ns = body(ex, s, *args)
                    return ns, i + 1, _changed(s, ns)

                final, _, _ = jax.lax.while_loop(
                    cond, step, (init, jnp.int32(0), jnp.bool_(True)))
                return final
        run = _RUNNERS[key] = jax.jit(run_py)
    return run


def fixpoint(plan_or_exec, body: Callable, init, *,
             n_iter: Optional[int] = None, max_iter: Optional[int] = None,
             backend: Optional[str] = None, args: Tuple = ()):
    """Iterate ``body(exec, state, *args) -> state`` on the engine.

    With ``n_iter``: exactly that many rounds (fori_loop).  Without: until
    the state pytree stops changing, capped at ``max_iter`` (while_loop).
    ``body`` must be a module-level function — the jitted runner is cached
    per body identity; pass per-call parameters via ``args`` (traced).
    """
    ex = (plan_or_exec if isinstance(plan_or_exec, XlaExec)
          else get_exec(plan_or_exec, backend))
    if n_iter is not None:
        return _runner(body, True)(ex, init, jnp.int32(n_iter), *args)
    cap = np.iinfo(np.int32).max if max_iter is None else int(max_iter)
    return _runner(body, False)(ex, init, jnp.int32(cap), *args)
