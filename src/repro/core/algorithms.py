"""Graph algorithms (Ringo §2.2/§3, paper Tables 3 & 6).

The paper benchmarks PageRank and triangle counting (parallel, Table 3) and
3-core / SSSP / SCC (sequential, Table 6), drawn from SNAP's 200+ algorithm
library.  We implement the full set named in the paper plus the common
supporting measures, as **vectorized fixed-point iterations**:

    OpenMP parallel-for over nodes/edges  →  segment_sum/min/max over
    CSR-sorted edge arrays + lax.while_loop until fixpoint.

Every algorithm works on dense node ids of a :class:`repro.core.graph.Graph`
and returns per-node arrays (convertible back to tables via
``convert.graph_to_node_table`` — the paper's results-to-tables loop).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph

__all__ = [
    "pagerank",
    "triangle_count",
    "per_node_triangles",
    "clustering_coefficient",
    "connected_components",
    "strongly_connected_components",
    "sssp",
    "bfs",
    "k_core",
    "core_numbers",
    "hits",
    "degree_histogram",
]

_INF = jnp.float32(jnp.inf)


# ---------------------------------------------------------------------------
# PageRank (paper Table 3: 2.76 s LiveJournal / 60.5 s Twitter2010, 10 iters)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(4, 5))
def _pagerank_kernel(src_by_dst, dst_of_edge, out_deg, dangling_mask,
                     n_nodes: int, n_iter: int, damping: float = 0.85):
    n = n_nodes
    pr0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    inv_deg = jnp.where(out_deg > 0, 1.0 / jnp.maximum(out_deg, 1), 0.0)

    def body(_, pr):
        contrib = pr * inv_deg                       # mass per out-edge
        gathered = contrib[src_by_dst]               # sorted by dst => fast
        summed = jax.ops.segment_sum(gathered, dst_of_edge, num_segments=n,
                                     indices_are_sorted=True)
        dangling = jnp.sum(jnp.where(dangling_mask, pr, 0.0))
        return (1.0 - damping) / n + damping * (summed + dangling / n)

    return jax.lax.fori_loop(0, n_iter, body, pr0)


def pagerank(g: Graph, n_iter: int = 10, damping: float = 0.85) -> jax.Array:
    """Power-iteration PageRank with dangling-mass redistribution.

    The SpMV inner loop gathers rank along in-edges **sorted by destination**
    (the sort-first layout), turning the paper's per-edge scatter into a
    contiguous segmented reduction.  `kernels/bsr_spmv` provides the
    MXU-tiled Pallas version of the same contraction.
    """
    src, dst = g.in_edges()
    out_deg = g.out_degrees().astype(jnp.float32)
    dangling = out_deg == 0
    return _pagerank_kernel(src, dst, out_deg, dangling, g.n_nodes, n_iter,
                            damping)


# ---------------------------------------------------------------------------
# Triangle counting (paper Table 3: 6.13 s / 263.6 s)
# ---------------------------------------------------------------------------


def _oriented_neighbor_matrix(g: Graph) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Degeneracy-oriented padded adjacency.

    Orient each undirected edge from its lower-(degree, id) endpoint to the
    higher one; every triangle then has exactly one "apex" and is counted
    once.  Max oriented out-degree is O(sqrt(E)) — this bounds the padded
    matrix width, the TPU dual of the paper's per-node adjacency vectors.
    """
    src, dst = g.out_edges()  # undirected graph stores both directions
    deg = g.out_degrees()
    # orient by (degree, id) lexicographic rank
    keep = (deg[src] < deg[dst]) | ((deg[src] == deg[dst]) & (src < dst))
    n_keep = int(jnp.sum(keep))
    perm = jnp.argsort(~keep, stable=True)[: max(n_keep, 1)]
    osrc, odst = src[perm][:n_keep], dst[perm][:n_keep]
    odeg = jnp.bincount(osrc, length=g.n_nodes)
    max_deg = int(jnp.max(odeg)) if n_keep else 0
    order_ = jnp.lexsort((odst, osrc))
    s_sorted, d_sorted = osrc[order_], odst[order_]
    ptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(odeg).astype(jnp.int32)])
    # scatter into (n, max_deg) padded matrix; pad with n (sorts to the end)
    slot = jnp.arange(n_keep, dtype=jnp.int32) - ptr[s_sorted]
    nbr = jnp.full((g.n_nodes, max(max_deg, 1)), g.n_nodes, dtype=jnp.int32)
    nbr = nbr.at[s_sorted, slot].set(d_sorted)
    return osrc, odst, nbr, odeg.astype(jnp.int32)


def triangle_count(g: Graph, edge_chunk: int = 1 << 16) -> int:
    """Exact triangle count of the undirected simple graph ``g``.

    Degeneracy orientation + per-edge sorted-adjacency intersection
    (binary search), chunked over edges to bound memory.  The Pallas
    `bsr_tricount` kernel computes the same quantity as Σ A∘(A·A)/6 on
    128×128 MXU tiles (see kernels/).
    """
    if g.n_edges == 0 or g.n_nodes == 0:
        return 0
    osrc, odst, nbr, odeg = _oriented_neighbor_matrix(g)
    e = int(osrc.shape[0])
    n = g.n_nodes
    total = 0
    pad_val = n  # padding neighbor id
    for lo in range(0, e, edge_chunk):
        hi = min(lo + edge_chunk, e)
        u, v = osrc[lo:hi], odst[lo:hi]
        cand = nbr[u]                                  # (c, w)
        rows = nbr[v]                                  # (c, w)
        pos = jnp.clip(jax.vmap(jnp.searchsorted)(rows, cand), 0, rows.shape[1] - 1)
        hit = (jnp.take_along_axis(rows, pos, axis=1) == cand) & (cand != pad_val)
        total += int(jnp.sum(hit))
    return total


def per_node_triangles(g: Graph, edge_chunk: int = 1 << 16) -> jax.Array:
    """Triangles incident to each node (undirected simple graph)."""
    if g.n_edges == 0 or g.n_nodes == 0:
        return jnp.zeros((max(g.n_nodes, 1),), jnp.int32)[: g.n_nodes]
    osrc, odst, nbr, _ = _oriented_neighbor_matrix(g)
    e = int(osrc.shape[0])
    n = g.n_nodes
    pad_val = n
    counts = jnp.zeros((n,), jnp.int32)
    for lo in range(0, e, edge_chunk):
        hi = min(lo + edge_chunk, e)
        u, v = osrc[lo:hi], odst[lo:hi]
        cand = nbr[u]
        rows = nbr[v]
        pos = jnp.clip(jax.vmap(jnp.searchsorted)(rows, cand), 0, rows.shape[1] - 1)
        hit = (jnp.take_along_axis(rows, pos, axis=1) == cand) & (cand != pad_val)
        per_edge = jnp.sum(hit, axis=1).astype(jnp.int32)        # apex count
        counts = counts.at[u].add(per_edge)
        counts = counts.at[v].add(per_edge)
        # the third vertex w of each triangle:
        w_hits = jnp.where(hit, cand, n)
        counts = counts + jnp.bincount(w_hits.reshape(-1), length=n + 1)[:n].astype(jnp.int32)
    return counts


def clustering_coefficient(g: Graph) -> jax.Array:
    """Local clustering coefficient per node (undirected simple graph)."""
    tri = per_node_triangles(g).astype(jnp.float32)
    deg = g.out_degrees().astype(jnp.float32)
    wedges = deg * (deg - 1.0) / 2.0
    return jnp.where(wedges > 0, tri / jnp.maximum(wedges, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Connected components (WCC) — hash-min label propagation + pointer jumping
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(2,))
def _cc_kernel(src, dst, n_nodes: int):
    labels0 = jnp.arange(n_nodes, dtype=jnp.int32)

    def cond(state):
        labels, changed = state
        return changed

    def body(state):
        labels, _ = state
        # min label over in-neighbors (graph is symmetrized by caller)
        m = jax.ops.segment_min(labels[src], dst, num_segments=n_nodes,
                                indices_are_sorted=True)
        new = jnp.minimum(labels, m)
        # pointer jumping: label <- label[label] until stable this round
        new = new[new]
        new = new[new]
        return new, jnp.any(new != labels)

    labels, _ = jax.lax.while_loop(cond, body, (labels0, jnp.bool_(True)))
    return labels


def connected_components(g: Graph) -> jax.Array:
    """Weakly-connected component labels (min node id in component)."""
    u = g.to_undirected()
    src, dst = u.in_edges()
    labels = _cc_kernel(src, dst, u.n_nodes)
    # map back to g's dense id space (same original ids, maybe different order)
    return labels[u.dense_of(g.node_ids[: g.n_nodes])]


# ---------------------------------------------------------------------------
# SSSP / BFS (paper Table 6: SSSP 7.4 s sequential on LiveJournal)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(3,))
def _bellman_ford(src, dst, w, n_nodes: int, source):
    dist0 = jnp.full((n_nodes,), _INF).at[source].set(0.0)

    def cond(state):
        dist, changed = state
        return changed

    def body(state):
        dist, _ = state
        relaxed = jax.ops.segment_min(dist[src] + w, dst, num_segments=n_nodes,
                                      indices_are_sorted=True)
        new = jnp.minimum(dist, relaxed)
        return new, jnp.any(new < dist)

    dist, _ = jax.lax.while_loop(cond, body, (dist0, jnp.bool_(True)))
    return dist


def sssp(g: Graph, source: int, weights: Optional[jax.Array] = None) -> jax.Array:
    """Single-source shortest paths (Bellman-Ford over in-edge segments).

    ``weights`` is per-edge in in-edge order (sorted by dst); defaults to 1.
    Vectorized frontier relaxation — the data-parallel dual of SNAP's
    sequential Dijkstra benchmarked in Table 6.
    """
    src, dst = g.in_edges()
    w = jnp.ones((src.shape[0],), jnp.float32) if weights is None \
        else weights.astype(jnp.float32)
    return _bellman_ford(src, dst, w, g.n_nodes, jnp.int32(source))


def bfs(g: Graph, source: int) -> jax.Array:
    """BFS levels (unweighted SSSP); -1 for unreachable."""
    dist = sssp(g, source)
    return jnp.where(jnp.isinf(dist), -1, dist.astype(jnp.int32))


# ---------------------------------------------------------------------------
# k-core (paper Table 6: 3-core 31 s sequential)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(2, 3))
def _k_core_kernel(src, dst, n_nodes: int, k: int):
    alive0 = jnp.ones((n_nodes,), bool)

    def cond(state):
        alive, changed = state
        return changed

    def body(state):
        alive, _ = state
        # degree counting only edges between alive nodes
        live_edge = alive[src] & alive[dst]
        deg = jax.ops.segment_sum(live_edge.astype(jnp.int32), dst,
                                  num_segments=n_nodes, indices_are_sorted=True)
        new = alive & (deg >= k)
        return new, jnp.any(new != alive)

    alive, _ = jax.lax.while_loop(cond, body, (alive0, jnp.bool_(True)))
    return alive


def k_core(g: Graph, k: int) -> jax.Array:
    """Boolean mask of nodes in the k-core (iterative parallel peeling)."""
    u = g.to_undirected()
    src, dst = u.in_edges()
    alive = _k_core_kernel(src, dst, u.n_nodes, int(k))
    return alive[u.dense_of(g.node_ids[: g.n_nodes])]


def core_numbers(g: Graph, k_max: Optional[int] = None) -> jax.Array:
    """Core number per node by sweeping k (exact; O(k_max) peels)."""
    u = g.to_undirected()
    src, dst = u.in_edges()
    if k_max is None:
        k_max = int(jnp.max(u.out_degrees())) if u.n_nodes else 0
    core = jnp.zeros((u.n_nodes,), jnp.int32)
    for k in range(1, k_max + 1):
        alive = _k_core_kernel(src, dst, u.n_nodes, k)
        if not bool(jnp.any(alive)):
            break
        core = jnp.where(alive, k, core)
    return core[u.dense_of(g.node_ids[: g.n_nodes])]


# ---------------------------------------------------------------------------
# SCC (paper Table 6: 18 s sequential) — parallel coloring (Orzan) algorithm
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(4,))
def _scc_kernel(fsrc, fdst, bsrc, bdst, n_nodes: int):
    """Forward-max coloring + backward containment, vectorized.

    repeat until every node assigned:
      1. color = max node id, propagated along *forward* edges among
         unassigned nodes, to fixpoint.
      2. nodes with color == own id are SCC roots.
      3. propagate "reached" backward from each root, restricted to nodes of
         the same color: those reached form the root's SCC.
    """
    NOT_ASSIGNED = jnp.int32(-1)
    scc0 = jnp.full((n_nodes,), NOT_ASSIGNED)

    def any_unassigned(state):
        scc, = state
        return jnp.any(scc == NOT_ASSIGNED)

    def round_(state):
        scc, = state
        un = scc == NOT_ASSIGNED

        # --- forward max-coloring to fixpoint
        color0 = jnp.where(un, jnp.arange(n_nodes, dtype=jnp.int32), NOT_ASSIGNED)

        def c_cond(cs):
            color, changed = cs
            return changed

        def c_body(cs):
            color, _ = cs
            # propagate color along forward edges: dst takes max(src color)
            src_col = jnp.where(un[fsrc] & un[fdst], color[fsrc], NOT_ASSIGNED)
            m = jax.ops.segment_max(src_col, fdst, num_segments=n_nodes,
                                    indices_are_sorted=True)
            new = jnp.where(un, jnp.maximum(color, m), color)
            return new, jnp.any(new != color)

        color, _ = jax.lax.while_loop(c_cond, c_body, (color0, jnp.bool_(True)))

        # --- backward reachability within color
        is_root = un & (color == jnp.arange(n_nodes, dtype=jnp.int32))
        reach0 = is_root

        def r_cond(rs):
            reach, changed = rs
            return changed

        def r_body(rs):
            reach, _ = rs
            # backward edge (u->v in G) becomes v->u; propagate reach from dst to src
            ok = un[bsrc] & un[bdst] & (color[bsrc] == color[bdst])
            src_reach = jnp.where(ok, reach[bsrc], False)
            m = jax.ops.segment_max(src_reach.astype(jnp.int32), bdst,
                                    num_segments=n_nodes, indices_are_sorted=True)
            new = reach | (m > 0)
            return new, jnp.any(new != reach)

        reach, _ = jax.lax.while_loop(r_cond, r_body, (reach0, jnp.bool_(True)))
        scc_new = jnp.where(un & reach, color, scc)
        return (scc_new,)

    (scc,) = jax.lax.while_loop(any_unassigned, round_, (scc0,))
    return scc


def strongly_connected_components(g: Graph) -> jax.Array:
    """SCC id per node (id = max dense node id in the component)."""
    fsrc, fdst = g.in_edges()          # forward edges grouped by dst
    bdst_src, bdst_dst = g.out_edges()  # src->dst sorted by src
    # backward propagation goes dst->src: treat (dst as source of reach, src as target)
    # regroup by "target" = src: out_edges is sorted by src already.
    bsrc, bdst = bdst_dst, bdst_src
    return _scc_kernel(fsrc, fdst, bsrc, bdst, g.n_nodes)


# ---------------------------------------------------------------------------
# HITS
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(4, 5))
def _hits_kernel(isrc, idst, osrc, odst, n_nodes: int, n_iter: int):
    hub = jnp.ones((n_nodes,), jnp.float32)
    auth = jnp.ones((n_nodes,), jnp.float32)

    def body(_, ha):
        hub, auth = ha
        auth = jax.ops.segment_sum(hub[isrc], idst, num_segments=n_nodes,
                                   indices_are_sorted=True)
        auth = auth / jnp.maximum(jnp.linalg.norm(auth), 1e-30)
        hub = jax.ops.segment_sum(auth[odst], osrc, num_segments=n_nodes,
                                  indices_are_sorted=True)
        hub = hub / jnp.maximum(jnp.linalg.norm(hub), 1e-30)
        return hub, auth

    return jax.lax.fori_loop(0, n_iter, body, (hub, auth))


def hits(g: Graph, n_iter: int = 20) -> Tuple[jax.Array, jax.Array]:
    """HITS hub/authority scores (paper §4.1 mentions Hits for experts)."""
    isrc, idst = g.in_edges()
    osrc, odst = g.out_edges()
    return _hits_kernel(isrc, idst, osrc, odst, g.n_nodes, n_iter)


# ---------------------------------------------------------------------------
# misc measures
# ---------------------------------------------------------------------------


def degree_histogram(g: Graph, direction: str = "out") -> jax.Array:
    deg = g.out_degrees() if direction == "out" else g.in_degrees()
    mx = int(jnp.max(deg)) if g.n_nodes else 0
    return jnp.bincount(deg, length=mx + 1)


# ---------------------------------------------------------------------------
# additional centrality / community measures (SNAP-style extensions)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(2, 3))
def _eigen_kernel(src, dst, n_nodes: int, n_iter: int):
    x = jnp.full((n_nodes,), 1.0 / jnp.sqrt(n_nodes), jnp.float32)

    def body(_, v):
        nv = jax.ops.segment_sum(v[src], dst, num_segments=n_nodes,
                                 indices_are_sorted=True)
        nv = nv + 0.01 * v   # regularizer: convergence on DAG-like graphs
        return nv / jnp.maximum(jnp.linalg.norm(nv), 1e-30)

    return jax.lax.fori_loop(0, n_iter, body, x)


def eigenvector_centrality(g: Graph, n_iter: int = 50) -> jax.Array:
    """Power-iteration eigenvector centrality over in-edges."""
    src, dst = g.in_edges()
    return _eigen_kernel(src, dst, g.n_nodes, n_iter)


def degree_centrality(g: Graph, direction: str = "out") -> jax.Array:
    deg = g.out_degrees() if direction == "out" else g.in_degrees()
    return deg.astype(jnp.float32) / jnp.maximum(g.n_nodes - 1, 1)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _lp_kernel(src, dst, n_nodes: int, n_iter: int):
    """Synchronous label propagation: adopt the min label among the
    most-frequent neighbor labels (deterministic tie-break)."""
    labels = jnp.arange(n_nodes, dtype=jnp.int32)

    def body(_, lab):
        # score a label by (count via weighted vote, tie-break by min id):
        # approximate the count with a sum of 1/(1+label) perturbations is
        # unstable; use two passes — count votes per (dst, label) via sort
        # is data-dependent.  We use the common min-of-mode relaxation:
        # propagate min label among neighbors with the current max count
        # approximated by a hash-min sweep (converges to communities on
        # modular graphs; exact CC on disconnected ones).
        m = jax.ops.segment_min(lab[src], dst, num_segments=n_nodes,
                                indices_are_sorted=True)
        return jnp.minimum(lab, m)

    return jax.lax.fori_loop(0, n_iter, body, labels)


def label_propagation(g: Graph, n_iter: int = 20) -> jax.Array:
    """Community labels by (min-)label propagation on the undirected view."""
    u = g.to_undirected()
    src, dst = u.in_edges()
    lab = _lp_kernel(src, dst, u.n_nodes, n_iter)
    return lab[u.dense_of(g.node_ids[: g.n_nodes])]


def closeness_centrality(g: Graph, sources: Optional[jax.Array] = None,
                         n_samples: int = 16) -> jax.Array:
    """Sampled closeness: average reciprocal distance over sampled sources
    (exact if sources covers all nodes).  Batched Bellman-Ford."""
    n = g.n_nodes
    if sources is None:
        step = max(n // max(n_samples, 1), 1)
        sources = jnp.arange(0, n, step, dtype=jnp.int32)[: n_samples]
    src, dst = g.in_edges()
    w = jnp.ones((src.shape[0],), jnp.float32)

    def one(s):
        return _bellman_ford(src, dst, w, n, s)

    dists = jax.vmap(one)(sources)                      # (k, n)
    finite = jnp.isfinite(dists)
    recip = jnp.where(finite & (dists > 0), 1.0 / jnp.maximum(dists, 1e-9), 0.0)
    return jnp.sum(recip, axis=0) / jnp.maximum(jnp.sum(finite, axis=0), 1)
