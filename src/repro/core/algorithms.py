"""Graph algorithms (Ringo §2.2/§3, paper Tables 3 & 6) on the shared engine.

The paper benchmarks PageRank and triangle counting (parallel, Table 3) and
3-core / SSSP / SCC (sequential, Table 6), drawn from SNAP's 200+ algorithm
library.  We implement the full set named in the paper plus the common
supporting measures, as **vectorized fixed-point iterations** — but every
one of them is now a thin composition over the two-layer execution
substrate:

    Graph.plan()      (core/plan.py)   cached derived arrays, paid once
    engine primitives (core/engine.py) pull/push/fixpoint with backend
                                       dispatch: "xla" | "pallas" | "bsr"

so repeated interactive calls on the same graph reuse the sorted edge
arrays, and a backend speedup applies to the whole library at once.  Every
algorithm accepts ``backend=`` (None = auto by device/size) and
``interpret=`` (Pallas interpret-mode override) kwargs.

Every algorithm works on dense node ids of a :class:`repro.core.graph.Graph`
and returns per-node arrays (convertible back to tables via
``convert.graph_to_node_table`` — the paper's results-to-tables loop).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine
from .. import obs
from .graph import Graph
from .provenance import track

__all__ = [
    "pagerank",
    "personalized_pagerank",
    "triangle_count",
    "per_node_triangles",
    "clustering_coefficient",
    "connected_components",
    "strongly_connected_components",
    "sssp",
    "bfs",
    "k_core",
    "core_numbers",
    "hits",
    "degree_histogram",
    "incremental_sssp",
    "incremental_bfs",
    "incremental_connected_components",
    "incremental_label_propagation",
]

_log = obs.get_logger(__name__)

_INF = jnp.float32(jnp.inf)


def _exec_for(g: Graph, backend: Optional[str], interpret: Optional[bool]):
    plan = g.plan()
    return plan, engine.get_exec(plan, backend, interpret=interpret)


def _undirected_presence(g: Graph, u: Graph):
    """(pos, present): where each g-node lands in the undirected view.

    ``to_undirected`` rebuilds the node set from edge endpoints, so vertices
    of ``g`` with no non-loop edges are absent from ``u`` — indexing ``u``
    results by ``u.dense_of`` alone would read a neighbor's slot for them.
    """
    orig = g.node_ids[: g.n_nodes]
    if u.n_nodes == 0:
        return (jnp.zeros((g.n_nodes,), jnp.int32),
                jnp.zeros((g.n_nodes,), bool))
    pos = jnp.clip(u.dense_of(orig), 0, u.n_nodes - 1)
    return pos, u.node_ids[pos] == orig


def _undirected_values_to_g(g: Graph, u: Graph, vals: jax.Array, missing
                            ) -> jax.Array:
    """Per-node values on the undirected view -> g's id space."""
    if g.n_nodes == 0:
        return vals[:0]
    pos, present = _undirected_presence(g, u)
    if u.n_nodes == 0:
        return jnp.broadcast_to(missing, (g.n_nodes,)).astype(vals.dtype)
    return jnp.where(present, vals[pos], missing)


def _undirected_ids_to_g(g: Graph, u: Graph, labels: jax.Array) -> jax.Array:
    """Id-valued results (CC/LP labels are u-dense ids) -> g-dense ids.

    Both dense numberings ascend with original id, so the translation is
    order-preserving and min-id semantics survive; absent vertices (no
    non-loop edges) label themselves.
    """
    own = jnp.arange(g.n_nodes, dtype=jnp.int32)
    if g.n_nodes == 0 or u.n_nodes == 0:
        return own
    pos, present = _undirected_presence(g, u)
    lab_g = g.dense_of(u.original_of(labels)).astype(jnp.int32)
    return jnp.where(present, lab_g[pos], own)


# ---------------------------------------------------------------------------
# PageRank (paper Table 3: 2.76 s LiveJournal / 60.5 s Twitter2010, 10 iters)
# ---------------------------------------------------------------------------


def _pagerank_body(ex, pr, damping, inv_deg, dangling):
    n = ex.n_nodes
    summed = ex.pull(pr * inv_deg, "sum")        # rank mass along in-edges
    dang = jnp.sum(jnp.where(dangling, pr, 0.0))
    return (1.0 - damping) / n + damping * (summed + dang / n)


@track("algorithms.pagerank", "A.pagerank")
def pagerank(g: Graph, n_iter: int = 10, damping: float = 0.85, *,
             tol: Optional[float] = None,
             init: Optional[jax.Array] = None,
             backend: Optional[str] = None,
             interpret: Optional[bool] = None) -> jax.Array:
    """Power-iteration PageRank with dangling-mass redistribution.

    The SpMV inner loop is ``engine.pull(pr * inv_deg, "sum")`` — on the
    "bsr" backend that is the MXU-tiled BSR SpMV, on "pallas" the one-hot
    matmul segment sum, on "xla" a sorted segmented reduction.

    With ``tol`` set, ``n_iter`` is ignored and the iteration runs until
    the L1 residual between rounds drops to ``tol``.  ``init`` seeds the
    iterate (default: uniform); PageRank is a contraction, so any seed
    converges to the same vector under the ``tol`` rule — passing a parent
    graph's vector after a small :class:`~repro.core.graph.EdgeDelta` is
    the warm-start path, converging in a handful of rounds.
    """
    if g.n_nodes == 0:
        return jnp.zeros((0,), jnp.float32)
    plan, ex = _exec_for(g, backend, interpret)
    pr0 = (jnp.asarray(init, jnp.float32) if init is not None
           else jnp.full((g.n_nodes,), 1.0 / g.n_nodes, dtype=jnp.float32))
    args = (jnp.float32(damping), plan.inv_out_deg, plan.dangling)
    if tol is not None:
        return engine.fixpoint(
            ex, _pagerank_body, pr0, tol=float(tol), max_iter=10_000,
            args=args,
            obs_tag="pagerank_warm" if init is not None else "pagerank")
    return engine.fixpoint(ex, _pagerank_body, pr0, n_iter=n_iter, args=args)


def _ppr_body(ex, pr, damping, inv_deg, dangling, restart):
    summed = ex.pull(pr * inv_deg, "sum")
    dang = jnp.sum(jnp.where(dangling, pr, 0.0))
    return (1.0 - damping) * restart + damping * (summed + dang * restart)


def _ppr_capped_body(ex, st, damping, inv_deg, dangling, restart, cap):
    """PPR iterate frozen past a per-run round cap (cross-n_iter fusion)."""
    pr, t = st
    new = _ppr_body(ex, pr, damping, inv_deg, dangling, restart)
    return jnp.where(t < cap, new, pr), t + 1


@track("algorithms.personalized_pagerank", "A.personalized_pagerank")
def personalized_pagerank(g: Graph, source, n_iter=10,
                          damping: float = 0.85, *,
                          tol: Optional[float] = None,
                          init: Optional[jax.Array] = None,
                          backend: Optional[str] = None,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Random-walk-with-restart PageRank personalized to ``source``.

    Teleport and dangling mass both return to the restart distribution
    (a one-hot at the source).  Like :func:`sssp`, ``source`` may be a
    scalar (returns ``(n,)``) or an array of k sources (returns ``(k, n)``,
    batched via ``vmap`` over the engine fixpoint) — the fusion target for
    the interactive service's scheduler.  ``n_iter`` may likewise be a
    ``(k,)`` array of per-source iteration counts: the batch runs to the
    max and every row freezes at its own count, exactly matching a
    standalone run.

    ``tol``/``init`` mirror :func:`pagerank`: run to L1-residual
    convergence from ``init`` (default: the restart distribution) instead
    of a fixed round count — the warm-start path after an edge delta.
    """
    if g.n_nodes == 0:
        return jnp.zeros((0,), jnp.float32)
    plan, ex = _exec_for(g, backend, interpret)
    scalar = np.ndim(source) == 0
    sources = jnp.atleast_1d(jnp.asarray(source, dtype=jnp.int32))
    args = (jnp.float32(damping), plan.inv_out_deg, plan.dangling)

    if tol is not None:
        init_rows = None if init is None else jnp.atleast_2d(
            jnp.asarray(init, jnp.float32))

        def one_tol(s, i):
            restart = jnp.zeros((g.n_nodes,), jnp.float32).at[s].set(1.0)
            pr0 = restart if init_rows is None else init_rows[i]
            return engine.fixpoint(ex, _ppr_body, pr0, tol=float(tol),
                                   max_iter=10_000, args=(*args, restart))

        prs = jax.vmap(one_tol)(sources, jnp.arange(sources.shape[0]))
        return prs[0] if scalar else prs

    if np.ndim(n_iter) == 0:
        def one(s):
            restart = jnp.zeros((g.n_nodes,), jnp.float32).at[s].set(1.0)
            return engine.fixpoint(ex, _ppr_body, restart, n_iter=int(n_iter),
                                   args=(*args, restart))

        prs = jax.vmap(one)(sources)
    else:
        caps = _source_caps(sources, n_iter)
        rounds = int(caps.max()) if caps.size else 0

        def one_capped(s, cap):
            restart = jnp.zeros((g.n_nodes,), jnp.float32).at[s].set(1.0)
            out, _ = engine.fixpoint(ex, _ppr_capped_body,
                                     (restart, jnp.int32(0)), n_iter=rounds,
                                     args=(*args, restart, cap))
            return out

        prs = jax.vmap(one_capped)(sources, jnp.asarray(caps))
    return prs[0] if scalar else prs


# ---------------------------------------------------------------------------
# Triangle counting (paper Table 3: 6.13 s / 263.6 s)
# ---------------------------------------------------------------------------


def _triangle_hits(plan, lo: int, hi: int):
    """Per-edge sorted-adjacency intersection over one oriented-edge chunk."""
    osrc, odst, nbr, _ = plan.oriented()
    pad_val = plan.n_nodes
    u, v = osrc[lo:hi], odst[lo:hi]
    cand = nbr[u]                                  # (c, w)
    rows = nbr[v]                                  # (c, w)
    pos = jnp.clip(jax.vmap(jnp.searchsorted)(rows, cand), 0, rows.shape[1] - 1)
    return u, v, cand, (jnp.take_along_axis(rows, pos, axis=1) == cand) \
        & (cand != pad_val)


def triangle_count(g: Graph, edge_chunk: int = 1 << 16, *,
                   backend: Optional[str] = None,
                   interpret: Optional[bool] = None) -> int:
    """Exact triangle count of the undirected simple graph ``g``.

    Default path: degeneracy orientation (cached in the plan) + per-edge
    sorted-adjacency intersection, chunked over edges to bound memory.
    ``backend="bsr"`` dispatches to the A∘(A·A) MXU kernel over the plan's
    cached 128×128 tiles and block triples (kernels/bsr_tricount.py);
    ``backend="sharded"`` partitions the oriented edges over the graph
    mesh (core/distributed.py) and ``psum``s the per-device counts.
    """
    if backend not in (None, "xla", "bsr", "sharded"):
        raise ValueError(f"triangle_count backends are None/'xla' (oriented "
                         f"intersection), 'bsr' (MXU kernel) or 'sharded' "
                         f"(mesh-partitioned); got {backend!r}")
    if g.n_edges == 0 or g.n_nodes == 0:
        return 0
    if backend == "sharded":
        from ..launch.mesh import graph_mesh
        from .distributed import triangle_count_distributed
        return triangle_count_distributed(g, graph_mesh(engine.shard_count()))
    plan = g.plan()
    if backend == "bsr":
        from ..kernels.bsr_tricount import bsr_tricount
        from ..kernels.ops import auto_interpret
        tiles, _, _, _ = plan.bsr()
        t_ij, t_ik, t_kj = plan.tri_triples()
        six_t = bsr_tricount(jnp.minimum(tiles, 1.0), t_ij, t_ik, t_kj,
                             interpret=auto_interpret(interpret))
        return int(round(float(six_t) / 6.0))
    osrc, _, _, _ = plan.oriented()
    e = int(osrc.shape[0])
    total = 0
    for lo in range(0, e, edge_chunk):
        hi = min(lo + edge_chunk, e)
        _, _, _, hit = _triangle_hits(plan, lo, hi)
        total += int(jnp.sum(hit))
    return total


@track("algorithms.per_node_triangles", "A.per_node_triangles")
def per_node_triangles(g: Graph, edge_chunk: int = 1 << 16) -> jax.Array:
    """Triangles incident to each node (undirected simple graph)."""
    if g.n_edges == 0 or g.n_nodes == 0:
        return jnp.zeros((max(g.n_nodes, 1),), jnp.int32)[: g.n_nodes]
    plan = g.plan()
    osrc, _, _, _ = plan.oriented()
    e = int(osrc.shape[0])
    n = g.n_nodes
    counts = jnp.zeros((n,), jnp.int32)
    for lo in range(0, e, edge_chunk):
        hi = min(lo + edge_chunk, e)
        u, v, cand, hit = _triangle_hits(plan, lo, hi)
        per_edge = jnp.sum(hit, axis=1).astype(jnp.int32)        # apex count
        counts = counts.at[u].add(per_edge)
        counts = counts.at[v].add(per_edge)
        # the third vertex w of each triangle:
        w_hits = jnp.where(hit, cand, n)
        counts = counts + jnp.bincount(w_hits.reshape(-1),
                                       length=n + 1)[:n].astype(jnp.int32)
    return counts


@track("algorithms.clustering_coefficient", "A.clustering_coefficient")
def clustering_coefficient(g: Graph) -> jax.Array:
    """Local clustering coefficient per node (undirected simple graph)."""
    tri = per_node_triangles(g).astype(jnp.float32)
    deg = g.plan().out_deg.astype(jnp.float32)
    wedges = deg * (deg - 1.0) / 2.0
    return jnp.where(wedges > 0, tri / jnp.maximum(wedges, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Connected components (WCC) — hash-min label propagation + pointer jumping
# ---------------------------------------------------------------------------


def _cc_body(ex, labels):
    # min label over in-neighbors (undirected view is symmetrized)
    m = ex.pull(labels, "min")
    new = jnp.minimum(labels, m)
    # pointer jumping: label <- label[label] until stable this round
    new = new[new]
    new = new[new]
    return new


@track("algorithms.connected_components", "A.connected_components")
def connected_components(g: Graph, *, backend: Optional[str] = None,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Weakly-connected component labels (min node id in component).

    The ``"frontier"`` backend propagates min labels only from vertices
    whose label changed last round (no pointer jumping, more rounds, far
    less work per round on sparse graphs); both paths converge to the same
    unique fixpoint — min dense id per component.
    """
    u = g.plan().undirected()
    uplan = u.plan()
    be = engine.select_backend(uplan, backend, op="connected_components")
    labels0 = jnp.arange(u.n_nodes, dtype=jnp.int32)
    if be == "frontier" and u.n_nodes > 0:
        labels = engine.frontier_fixpoint(uplan, labels0,
                                          jnp.ones((u.n_nodes,), bool))
    else:
        ex = engine.get_exec(uplan, be, interpret=interpret)
        labels = engine.fixpoint(ex, _cc_body, labels0)
    # map back to g's dense id space; isolated vertices label themselves
    return _undirected_ids_to_g(g, u, labels)


# ---------------------------------------------------------------------------
# SSSP / BFS (paper Table 6: SSSP 7.4 s sequential on LiveJournal)
# ---------------------------------------------------------------------------


def _sssp_body(ex, dist, w):
    relaxed = ex.pull(dist, "min", edge_values=w, edge_op="add")
    return jnp.minimum(dist, relaxed)


def _sssp_capped_body(ex, st, w, cap):
    """Relaxation with a per-run round cap threaded through the state.

    Freezing at ``t >= cap`` makes a vmapped batch of runs with *different*
    caps exact: each row equals a standalone run of ``cap`` rounds — the
    mechanism behind the service's cross-``n_iter`` fusion.  The round
    counter itself freezes once the distances converge (a monotone
    relaxation that didn't change is at its fixpoint), so the
    until-unchanged driver exits early instead of grinding a
    convergence-bound cap (|V| for an uncapped fused request) to the end.
    """
    dist, t = st
    relaxed = ex.pull(dist, "min", edge_values=w, edge_op="add")
    new = jnp.where(t < cap, jnp.minimum(dist, relaxed), dist)
    return new, jnp.where(engine._changed(dist, new), t + 1, t)


def _source_caps(sources, n_iter):
    """Broadcast a scalar/array round limit to one cap per source."""
    if n_iter is None:
        return None
    return np.broadcast_to(np.atleast_1d(np.asarray(n_iter, np.int32)),
                           (int(sources.shape[0]),))


@track("algorithms.sssp", "A.sssp")
def sssp(g: Graph, source, weights: Optional[jax.Array] = None,
         n_iter=None, *, backend: Optional[str] = None,
         interpret: Optional[bool] = None) -> jax.Array:
    """Single- or multi-source shortest paths (relaxation to fixpoint).

    ``weights`` is per-edge in in-edge order (sorted by dst); defaults to 1.
    ``source`` may be a scalar (returns ``(n,)``) or an array of k sources
    (returns ``(k, n)`` — batched via ``vmap`` over the engine fixpoint, the
    data-parallel dual of SNAP's sequential Dijkstra from Table 6).
    ``n_iter`` caps relaxation rounds (None = run to convergence); it may be
    per-source — a ``(k,)`` array of caps — and each row then equals a
    standalone run with that cap (the service fuses mixed-depth requests
    this way).

    On the ``"frontier"`` backend the relaxation is frontier-sparse: only
    out-edges of vertices whose distance changed last round are relaxed,
    direction-optimizing to a dense pull when the frontier grows large.
    Results are identical to the dense backends round for round.
    """
    plan = g.plan()
    scalar = np.ndim(source) == 0
    sources = jnp.atleast_1d(jnp.asarray(source, dtype=jnp.int32))
    caps = _source_caps(sources, n_iter)
    # auto-selection routes only *single-source* runs to the frontier path:
    # a batch's union frontier densifies fast, and the vmapped dense
    # fixpoint wins there (explicit backend="frontier" batches still work)
    auto_op = "sssp" if int(sources.shape[0]) == 1 else None
    be = engine.select_backend(plan, backend,
                               op="sssp" if backend is not None else auto_op)
    w = jnp.ones((g.n_edges,), jnp.float32) if weights is None \
        else weights.astype(jnp.float32)

    if be == "frontier" and g.n_nodes > 0:
        k = int(sources.shape[0])
        dist0 = jnp.full((k, g.n_nodes), _INF) \
            .at[jnp.arange(k), sources].set(0.0)
        mask0 = jnp.zeros((g.n_nodes,), bool).at[sources].set(True)
        # unweighted runs relax with a broadcast scalar hop (no edge gather)
        fw = jnp.float32(1.0) if weights is None else w
        dists = engine.frontier_fixpoint(plan, dist0, mask0, weights=fw,
                                         caps=caps)
        return dists[0] if scalar else dists

    ex = engine.get_exec(plan, be, interpret=interpret)
    if caps is None:
        def one(s):
            dist0 = jnp.full((g.n_nodes,), _INF).at[s].set(0.0)
            return engine.fixpoint(ex, _sssp_body, dist0, args=(w,))

        dists = jax.vmap(one)(sources)
    else:
        rounds = int(caps.max()) if caps.size else 0

        def one_capped(s, cap):
            dist0 = jnp.full((g.n_nodes,), _INF).at[s].set(0.0)
            out, _ = engine.fixpoint(ex, _sssp_capped_body,
                                     (dist0, jnp.int32(0)), max_iter=rounds,
                                     args=(w, cap))
            return out

        dists = jax.vmap(one_capped)(sources, jnp.asarray(caps))
    return dists[0] if scalar else dists


@track("algorithms.bfs", "A.bfs")
def bfs(g: Graph, source, n_iter=None, *, backend: Optional[str] = None,
        interpret: Optional[bool] = None) -> jax.Array:
    """BFS levels (unweighted SSSP); -1 for unreachable.  Batched like sssp.

    ``n_iter`` is the depth limit: vertices deeper than ``n_iter`` hops
    report unreachable, exactly as if the traversal stopped there.
    """
    dist = sssp(g, source, n_iter=n_iter, backend=backend,
                interpret=interpret)
    return jnp.where(jnp.isinf(dist), -1, dist.astype(jnp.int32))


# ---------------------------------------------------------------------------
# k-core (paper Table 6: 3-core 31 s sequential)
# ---------------------------------------------------------------------------


def _k_core_body(ex, alive, k):
    # degree over alive neighbors; edges into dead nodes only affect rows
    # that the alive & ... mask kills anyway, so no dst-side mask is needed
    deg = ex.pull(alive.astype(jnp.float32), "sum")
    return alive & (deg >= k)


@track("algorithms.k_core", "A.k_core")
def k_core(g: Graph, k: int, *, backend: Optional[str] = None,
           interpret: Optional[bool] = None) -> jax.Array:
    """Boolean mask of nodes in the k-core (iterative parallel peeling)."""
    u = g.plan().undirected()
    _, ex = _exec_for(u, backend, interpret)
    alive = engine.fixpoint(ex, _k_core_body, jnp.ones((u.n_nodes,), bool),
                            args=(jnp.float32(k),))
    # vertices with no non-loop edges have undirected degree 0: in-core iff k<=0
    return _undirected_values_to_g(g, u, alive, jnp.bool_(k <= 0))


@track("algorithms.core_numbers", "A.core_numbers")
def core_numbers(g: Graph, k_max: Optional[int] = None, *,
                 backend: Optional[str] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Core number per node by sweeping k (exact; O(k_max) peels).

    All peels share one plan/exec — the sweep reuses the cached undirected
    view and sorted edge arrays across every k.
    """
    u = g.plan().undirected()
    _, ex = _exec_for(u, backend, interpret)
    if k_max is None:
        k_max = int(jnp.max(u.plan().out_deg)) if u.n_nodes else 0
    core = jnp.zeros((u.n_nodes,), jnp.int32)
    for k in range(1, k_max + 1):
        alive = engine.fixpoint(ex, _k_core_body,
                                jnp.ones((u.n_nodes,), bool),
                                args=(jnp.float32(k),))
        if not bool(jnp.any(alive)):
            break
        core = jnp.where(alive, k, core)
    return _undirected_values_to_g(g, u, core, jnp.int32(0))


# ---------------------------------------------------------------------------
# SCC (paper Table 6: 18 s sequential) — parallel coloring (Orzan) algorithm
# ---------------------------------------------------------------------------

_NOT_ASSIGNED = jnp.int32(-1)


def _scc_color_body(ex, color, un):
    # propagate color along forward edges: dst takes max(src color)
    m = ex.pull(jnp.where(un, color, _NOT_ASSIGNED), "max")
    return jnp.where(un, jnp.maximum(color, m), color)


def _scc_reach_body(ex, reach, un, color):
    # backward edge (u->v in G) propagates reach v->u, restricted to
    # unassigned endpoints of equal color: reduce out-edges to their source
    ok = (ex.out_src_vals(un) & ex.out_dst_vals(un)
          & (ex.out_src_vals(color) == ex.out_dst_vals(color)))
    ev = jnp.where(ok, ex.out_dst_vals(reach), False)
    m = ex.reduce_out(ev.astype(jnp.int32), "max")
    return reach | (m > 0)


def _scc_round(ex, scc):
    """Forward-max coloring + backward containment, one assignment round.

    1. color = max node id, propagated along *forward* edges among
       unassigned nodes, to fixpoint.
    2. nodes with color == own id are SCC roots.
    3. propagate "reached" backward from each root, restricted to nodes of
       the same color: those reached form the root's SCC.
    """
    n = ex.n_nodes
    un = scc == _NOT_ASSIGNED
    color0 = jnp.where(un, jnp.arange(n, dtype=jnp.int32), _NOT_ASSIGNED)
    color = engine.fixpoint(ex, _scc_color_body, color0, args=(un,))
    is_root = un & (color == jnp.arange(n, dtype=jnp.int32))
    reach = engine.fixpoint(ex, _scc_reach_body, is_root, args=(un, color))
    return jnp.where(un & reach, color, scc)


@track("algorithms.strongly_connected_components", "A.strongly_connected_components")
def strongly_connected_components(g: Graph, *,
                                  backend: Optional[str] = None,
                                  interpret: Optional[bool] = None
                                  ) -> jax.Array:
    """SCC id per node (id = max dense node id in the component)."""
    _, ex = _exec_for(g, backend, interpret)
    scc0 = jnp.full((g.n_nodes,), _NOT_ASSIGNED)
    # each round assigns at least the max unassigned id's component, so the
    # state strictly changes until everything is assigned — the generic
    # until-unchanged driver terminates one round after full assignment
    return engine.fixpoint(ex, _scc_round, scc0)


# ---------------------------------------------------------------------------
# HITS
# ---------------------------------------------------------------------------


def _hits_body(ex, ha):
    hub, auth = ha
    auth = ex.pull(hub, "sum")
    auth = auth / jnp.maximum(jnp.linalg.norm(auth), 1e-30)
    hub = ex.push(auth, "sum")
    hub = hub / jnp.maximum(jnp.linalg.norm(hub), 1e-30)
    return hub, auth


@track("algorithms.hits", "A.hits")
def hits(g: Graph, n_iter: int = 20, *, backend: Optional[str] = None,
         interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """HITS hub/authority scores (paper §4.1 mentions Hits for experts)."""
    _, ex = _exec_for(g, backend, interpret)
    ones = jnp.ones((g.n_nodes,), jnp.float32)
    return engine.fixpoint(ex, _hits_body, (ones, ones), n_iter=n_iter)


# ---------------------------------------------------------------------------
# misc measures
# ---------------------------------------------------------------------------


def degree_histogram(g: Graph, direction: str = "out") -> jax.Array:
    plan = g.plan()
    deg = plan.out_deg if direction == "out" else plan.in_deg
    mx = int(jnp.max(deg)) if g.n_nodes else 0
    return jnp.bincount(deg, length=mx + 1)


# ---------------------------------------------------------------------------
# additional centrality / community measures (SNAP-style extensions)
# ---------------------------------------------------------------------------


def _eigen_body(ex, v):
    nv = ex.pull(v, "sum")
    nv = nv + 0.01 * v   # regularizer: convergence on DAG-like graphs
    return nv / jnp.maximum(jnp.linalg.norm(nv), 1e-30)


@track("algorithms.eigenvector_centrality", "A.eigenvector_centrality")
def eigenvector_centrality(g: Graph, n_iter: int = 50, *,
                           backend: Optional[str] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Power-iteration eigenvector centrality over in-edges."""
    _, ex = _exec_for(g, backend, interpret)
    x0 = jnp.full((g.n_nodes,), 1.0 / jnp.sqrt(g.n_nodes), jnp.float32)
    return engine.fixpoint(ex, _eigen_body, x0, n_iter=n_iter)


def degree_centrality(g: Graph, direction: str = "out") -> jax.Array:
    plan = g.plan()
    deg = plan.out_deg if direction == "out" else plan.in_deg
    return deg.astype(jnp.float32) / jnp.maximum(g.n_nodes - 1, 1)


def _lp_body(ex, lab):
    """Hash-min label propagation step (min-of-mode relaxation).

    Converges to communities on modular graphs; exact CC on disconnected
    ones — the deterministic tie-break variant of synchronous LP.
    """
    m = ex.pull(lab, "min")
    return jnp.minimum(lab, m)


@track("algorithms.label_propagation", "A.label_propagation")
def label_propagation(g: Graph, n_iter: int = 20, *,
                      backend: Optional[str] = None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Community labels by (min-)label propagation on the undirected view.

    Min-label propagation is a monotone relaxation, so the ``"frontier"``
    backend path is round-for-round identical to the dense iterate: a
    vertex whose label did not change has nothing new to propagate.
    """
    u = g.plan().undirected()
    uplan = u.plan()
    be = engine.select_backend(uplan, backend, op="label_propagation")
    labels0 = jnp.arange(u.n_nodes, dtype=jnp.int32)
    if be == "frontier" and u.n_nodes > 0:
        lab = engine.frontier_fixpoint(uplan, labels0,
                                       jnp.ones((u.n_nodes,), bool),
                                       caps=n_iter)
    else:
        ex = engine.get_exec(uplan, be, interpret=interpret)
        lab = engine.fixpoint(ex, _lp_body, labels0, n_iter=n_iter)
    return _undirected_ids_to_g(g, u, lab)


# ---------------------------------------------------------------------------
# incremental recomputation (delta-update path; see core/graph.EdgeDelta)
#
# Each helper answers "can the parent's result be reused?" and returns None
# with a logged reason when it cannot — callers fall back to a cold run.
# Soundness rests on monotonicity: for an *insert-only* delta the parent
# fixpoint is a valid upper bound of the child fixpoint under a min
# relaxation, so re-seeding the frontier with the inserted edges' endpoints
# converges to exactly the from-scratch result.  Deletions can raise values,
# which breaks the bound — they always fall back.
# ---------------------------------------------------------------------------


def _insert_only_info(g: Graph, op: str):
    info = getattr(g, "_delta", None)
    if info is None:
        _log.info("incremental.cold_fallback", op=op,
                  reason="no delta lineage")
        return None
    if not info.insert_only:
        _log.info("incremental.cold_fallback", op=op,
                  reason="delta deletes edges; parent result is no longer "
                         "an upper bound")
        return None
    return info


def incremental_sssp(g: Graph, source, parent_dist, *,
                     weights: Optional[jax.Array] = None,
                     n_iter=None) -> Optional[jax.Array]:
    """Warm single-source shortest paths after an insert-only delta.

    Re-seeds :func:`engine.frontier_fixpoint` from the parent's (fixpoint)
    distance vector with the inserted edges' sources as the frontier: only
    regions whose distance actually improves are re-relaxed.  Returns None
    (caller runs cold) when unsound: deletions, weighted edges (the parent
    vector's weight keying cannot be verified), a round cap (a capped run
    is not a fixpoint), or a batched source.
    """
    info = _insert_only_info(g, "sssp")
    if info is None:
        return None
    if weights is not None:
        _log.info("incremental.cold_fallback", op="sssp",
                  reason="weighted run")
        return None
    if n_iter is not None:
        _log.info("incremental.cold_fallback", op="sssp",
                  reason="capped run is not a fixpoint")
        return None
    if np.ndim(source) != 0:
        _log.info("incremental.cold_fallback", op="sssp",
                  reason="batched sources")
        return None
    if g.n_nodes == 0:
        return jnp.zeros((0,), jnp.float32)
    dist0 = jnp.asarray(parent_dist, jnp.float32)
    mask = np.zeros((g.n_nodes,), bool)
    mask[info.add_src] = True
    return engine.frontier_fixpoint(g.plan(), dist0, jnp.asarray(mask),
                                    weights=jnp.float32(1.0))


def incremental_bfs(g: Graph, source, parent_levels, *,
                    n_iter=None) -> Optional[jax.Array]:
    """Warm BFS levels (unweighted :func:`incremental_sssp`); -1 unreachable."""
    pd = jnp.asarray(parent_levels)
    dist = incremental_sssp(
        g, source, jnp.where(pd < 0, _INF, pd.astype(jnp.float32)),
        n_iter=n_iter)
    if dist is None:
        return None
    return jnp.where(jnp.isinf(dist), -1, dist.astype(jnp.int32))


def incremental_connected_components(g: Graph, parent_labels
                                     ) -> Optional[jax.Array]:
    """Warm WCC labels after an insert-only delta.

    Works in the undirected view's id space: the parent labels translate to
    a valid upper bound (each vertex's label is the u-id of a member of its
    own component), and the inserted edges' endpoints seed the frontier, so
    only merging components are re-labeled.  Requires the plan's undirected
    view to be a *patched* one (it carries its own delta lineage); when the
    patch fell back to a rebuild there is no per-edge delta to seed from.
    """
    info = _insert_only_info(g, "connected_components")
    if info is None:
        return None
    if g.n_nodes == 0:
        return jnp.zeros((0,), jnp.int32)
    u = g.plan().undirected()
    uinfo = getattr(u, "_delta", None)
    if uinfo is None:
        _log.info("incremental.cold_fallback", op="connected_components",
                  reason="undirected view was rebuilt (no delta lineage)")
        return None
    if u.n_nodes == 0:
        return _undirected_ids_to_g(g, u, jnp.zeros((0,), jnp.int32))
    # translate parent g-space labels to u-space: label -> original id ->
    # u-dense id; the min-id member of every component is present in u
    # (defensively: fall back to own id, still an upper bound)
    orig_u = u.node_ids[: u.n_nodes]
    gx = g.dense_of(orig_u)
    lab_orig = g.original_of(jnp.asarray(parent_labels, jnp.int32)[gx])
    pos = jnp.clip(u.dense_of(lab_orig), 0, u.n_nodes - 1)
    own = jnp.arange(u.n_nodes, dtype=jnp.int32)
    init_u = jnp.where(u.node_ids[pos] == lab_orig, pos, own).astype(jnp.int32)
    mask = np.zeros((u.n_nodes,), bool)
    mask[uinfo.add_src] = True
    mask[uinfo.add_dst] = True
    labels = engine.frontier_fixpoint(u.plan(), init_u, jnp.asarray(mask))
    return _undirected_ids_to_g(g, u, labels)


def incremental_label_propagation(g: Graph, parent_labels, n_iter: int = 20
                                  ) -> Optional[jax.Array]:
    """Warm min-label propagation after an insert-only delta.

    Only sound when the round cap cannot bind: a capped LP result is not a
    fixpoint (a label may travel further through an inserted edge than the
    parent run's cap allowed).  With ``n_iter >= |V|`` the run is the
    min-label fixpoint — component min-labels — which is exactly what
    :func:`incremental_connected_components` computes.
    """
    info = _insert_only_info(g, "label_propagation")
    if info is None:
        return None
    u = g.plan().undirected()
    if int(n_iter) < u.n_nodes:
        _log.info("incremental.cold_fallback", op="label_propagation",
                  reason="n_iter < |V| may cap the propagation",
                  n_iter=int(n_iter), n_nodes=u.n_nodes)
        return None
    return incremental_connected_components(g, parent_labels)


@track("algorithms.closeness_centrality", "A.closeness_centrality")
def closeness_centrality(g: Graph, sources: Optional[jax.Array] = None,
                         n_samples: int = 16, *,
                         backend: Optional[str] = None,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Sampled closeness: average reciprocal distance over sampled sources
    (exact if sources covers all nodes).  Batched multi-source sssp."""
    n = g.n_nodes
    if sources is None:
        step = max(n // max(n_samples, 1), 1)
        sources = jnp.arange(0, n, step, dtype=jnp.int32)[: n_samples]
    dists = sssp(g, sources, backend=backend, interpret=interpret)    # (k, n)
    finite = jnp.isfinite(dists)
    recip = jnp.where(finite & (dists > 0), 1.0 / jnp.maximum(dists, 1e-9), 0.0)
    return jnp.sum(recip, axis=0) / jnp.maximum(jnp.sum(finite, axis=0), 1)
