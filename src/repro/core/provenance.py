"""Provenance layer — op recording, script export, replay (Ringo §2.1/§4).

Ringo's front end is *interactive*: an analyst iterates trial-and-error over
named tables and graphs, and every derived object silently accumulates
metadata about how it was built, so a finished exploration can be exported as
a runnable script (the paper's §4 demo: "Ringo can export the sequence of
commands as a standalone Python program").  This module is that layer for the
repro stack:

* every tracked operation (relational ops, table↔graph conversions, graph
  functional updates, algorithms) appends a :class:`ProvRecord` to the
  objects it produces — op name, named inputs (as *version tokens*), literal
  params, output version token(s);
* :func:`version_of` hands out a stable per-object version token (``t3`` /
  ``g7`` / ``a12``).  Objects are immutable and functional updates return
  fresh objects, so a version token also keys result caching — the same
  contract as the identity-memoized ``Graph.plan()`` cache;
* :func:`export_script` emits a runnable Python script reproducing an object
  (roots embedded as literals, or taken as function arguments);
* :func:`replay` re-executes a record chain in-process against fresh root
  inputs.

Implementation notes.  Tracking is *reentrancy-guarded*: while a tracked op
runs, nested tracked calls (``bfs`` → ``sssp``, ``unique`` → ``group_by``)
record nothing, so chains stay at user-call granularity.  Records ride on the
objects themselves (``Table``/``Graph`` take a dynamic attribute; ``jax.Array``
outputs go through a weakref side table).  Provenance is attached eagerly but
never crosses a ``jit`` boundary: a pytree-reconstructed object is a fresh
root, exactly like its plan cache.
"""

from __future__ import annotations

import functools
import inspect
import secrets
import threading
import weakref
from collections import OrderedDict
from dataclasses import dataclass, replace as _dc_replace
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)

import numpy as np

__all__ = [
    "ProvRecord",
    "ProvenanceError",
    "Opaque",
    "track",
    "record_call",
    "annotate_last",
    "records_of",
    "version_of",
    "peek_version",
    "bind_version",
    "adopt_records",
    "records_to_wire",
    "records_from_wire",
    "roots_of",
    "object_for_version",
    "canonical_value",
    "canonical_params",
    "contains_opaque",
    "export_script",
    "replay",
    "register_op",
    "set_pin_capacity",
    "pin_stats",
]


class ProvenanceError(RuntimeError):
    """Raised when a chain cannot be exported or replayed."""


class Opaque:
    """Placeholder for a parameter that has no literal form (big arrays,
    callables...).  Hashable by identity, so a cache key containing one
    simply never hits; export/replay refuse it with a clear error."""

    __slots__ = ("desc",)

    def __init__(self, desc: str):
        self.desc = desc

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"<opaque {self.desc}>"


@dataclass(frozen=True)
class ProvRecord:
    """One executed operation: how some object(s) came to be.

    ``inputs`` are (param_name, version_token) pairs in signature order;
    ``params`` are (param_name, canonical_literal) pairs; ``outputs`` are the
    version token(s) of the produced value(s) (len > 1 for tuple-returning
    ops like ``hits``).
    """

    op: str
    inputs: Tuple[Tuple[str, str], ...]
    params: Tuple[Tuple[str, Any], ...]
    outputs: Tuple[str, ...]
    #: execution metadata that is *not* part of the computation — e.g. the
    #: service scheduler's queueing/coalescing annotations (queued_ms,
    #: batch size, scheduling mode).  Ignored by export_script and replay:
    #: two runs of the same analysis are the same program regardless of how
    #: the scheduler happened to batch them.
    meta: Tuple[Tuple[str, Any], ...] = ()


# ---------------------------------------------------------------------------
# version tokens + record attachment (attribute first, weakref side table
# for objects that refuse attributes, e.g. jax.Array)
# ---------------------------------------------------------------------------

_LOCK = threading.RLock()
_NEXT_VERSION = 1
# Tokens are minted as "<kind><n>x<nonce>" with a per-process random nonce:
# two processes can never mint the same token, so a client-side token
# shipped over the wire (pack_object peeks, never mints — but locally
# tracked ops may have minted one) cannot collide with a server-side one.
# Adopted foreign tokens keep their exact string (bind_version); tokens
# stay valid Python identifiers for export_script.
_PROC_NONCE = secrets.token_hex(4)
_SIDE_VERSIONS: Dict[int, str] = {}
_SIDE_RECORDS: Dict[int, Tuple[ProvRecord, ...]] = {}
# version token -> weakref (or pinned object), for export_script root
# embedding; a small strong ring pins attr-less objects without weakref
# support (prevents id-reuse aliasing).
_BY_VERSION: Dict[str, Any] = {}
_PINNED = object()  # marker: object lives in _STRONG_RING
_STRONG_RING: "OrderedDict[int, Any]" = OrderedDict()
_STRONG_CAP = 4096
# pinned-object id -> version tokens bound to it: ring eviction must also
# drop the _BY_VERSION entries, which hold the object strongly (a pinned
# binding has no weakref death callback — without this reverse map every
# evicted pin leaked its object through _BY_VERSION forever)
_PIN_TOKENS: Dict[int, List[str]] = {}


def _try_setattr(obj: Any, name: str, value: Any) -> bool:
    try:
        object.__setattr__(obj, name, value)
        return True
    except (AttributeError, TypeError):
        return False


def _evict_pin_locked(key: int) -> None:
    """Drop every side-table and registry entry of an evicted pinned id."""
    _SIDE_VERSIONS.pop(key, None)
    _SIDE_RECORDS.pop(key, None)
    obj = _STRONG_RING.pop(key, None)
    for tok in _PIN_TOKENS.pop(key, ()):
        cur = _BY_VERSION.get(tok)
        if isinstance(cur, tuple) and cur[0] is _PINNED and cur[1] is obj:
            del _BY_VERSION[tok]


def _side_put(store: Dict[int, Any], obj: Any, value: Any) -> None:
    key = id(obj)
    with _LOCK:
        store[key] = value
        try:
            weakref.finalize(obj, store.pop, key, None)
        except TypeError:
            # no weakref support: pin the object so its id cannot be reused
            _STRONG_RING[key] = obj
            _STRONG_RING.move_to_end(key)
            while len(_STRONG_RING) > _STRONG_CAP:
                old_key = next(iter(_STRONG_RING))
                if old_key == key:
                    break              # never evict the entry being added
                _evict_pin_locked(old_key)


def set_pin_capacity(n: int) -> None:
    """Bound the strong-pin ring (weakref-less provenance subjects) to ``n``.

    Shrinking evicts oldest pins immediately — their versions/records are
    forgotten, exactly as if the objects had been garbage collected.
    """
    global _STRONG_CAP
    if n < 1:
        raise ValueError(f"pin capacity must be >= 1, got {n}")
    with _LOCK:
        _STRONG_CAP = int(n)
        while len(_STRONG_RING) > _STRONG_CAP:
            _evict_pin_locked(next(iter(_STRONG_RING)))


def pin_stats() -> Dict[str, int]:
    """Accounting for the strong-pin ring: count, capacity and bytes held
    (array-typed pins charge ``size * itemsize``; others charge 0)."""
    with _LOCK:
        nbytes = 0
        for obj in _STRONG_RING.values():
            if hasattr(obj, "dtype") and hasattr(obj, "size"):
                nbytes += int(obj.size) * int(np.dtype(obj.dtype).itemsize)
        return {"pinned": len(_STRONG_RING), "capacity": _STRONG_CAP,
                "bytes": nbytes}


def _kind_prefix(obj: Any) -> str:
    from .graph import Graph
    from .table import Table
    if isinstance(obj, Table):
        return "t"
    if isinstance(obj, Graph):
        return "g"
    if isinstance(obj, (np.ndarray,)) or hasattr(obj, "dtype"):
        return "a"
    return "v"


def version_of(obj: Any) -> str:
    """Stable version token for ``obj``, assigned on first use.

    Objects are immutable and updates are functional, so identity == version;
    a fresh object (e.g. from ``Graph.add_edges``) gets a fresh token — the
    provenance dual of the plan-cache invalidation-by-construction contract.
    """
    global _NEXT_VERSION
    with _LOCK:
        v = getattr(obj, "_prov_version", None)
        if v is None:
            v = _SIDE_VERSIONS.get(id(obj))
        if v is not None:
            return v
        v = f"{_kind_prefix(obj)}{_NEXT_VERSION}x{_PROC_NONCE}"
        _NEXT_VERSION += 1
        _register_locked(obj, v)
        return v


def _pop_version_if(v: str, ref: Any) -> None:
    """Weakref death callback: drop the registry entry only if it is still
    *this* reference — a token can be re-bound to a fresh object (wire
    adoption re-binding a decoded copy), and the old object's death must
    not evict the new binding."""
    with _LOCK:
        if _BY_VERSION.get(v) is ref:
            del _BY_VERSION[v]


def _register_locked(obj: Any, v: str) -> None:
    if not _try_setattr(obj, "_prov_version", v):
        _side_put(_SIDE_VERSIONS, obj, v)
    cur = _BY_VERSION.get(v)
    if cur is not None:
        alive = cur[1] if isinstance(cur, tuple) and cur[0] is _PINNED \
            else cur()
        if alive is not None:
            # first live binding wins: re-binding a token to a transient
            # decoded copy (wire adoption) must not evict the original —
            # both are the same value, and export roots need the one that
            # stays alive (e.g. in a workspace mirror)
            return
    try:
        _BY_VERSION[v] = weakref.ref(obj,
                                     lambda r, v=v: _pop_version_if(v, r))
    except TypeError:
        # no weakref support: the object is either attr-carrying (rare)
        # or already pinned in the strong ring by _side_put; remember the
        # token so ring eviction can drop this strong binding too
        _BY_VERSION[v] = (_PINNED, obj)
        _PIN_TOKENS.setdefault(id(obj), []).append(v)


def peek_version(obj: Any) -> Optional[str]:
    """``obj``'s version token if one was ever assigned, else None.

    Unlike :func:`version_of` this never mints: the wire layer uses it so a
    *client-side* root ships without a token (the server assigns one and the
    client binds to it) — a client-minted token could collide with tokens
    the server already handed out.
    """
    with _LOCK:
        v = getattr(obj, "_prov_version", None)
        if v is None:
            v = _SIDE_VERSIONS.get(id(obj))
        return v


def _token_num(token: str) -> Optional[int]:
    digits = token.lstrip("tgav")
    return int(digits) if digits.isdigit() else None


def bind_version(obj: Any, token: str) -> str:
    """Register ``obj`` under a version token minted in *another* process.

    The wire protocol (:mod:`repro.serve.wire`) ships objects together with
    their server-assigned version tokens; the receiving process binds its
    deserialized copy to the same token so the provenance chain stays
    self-consistent — ``object_for_version`` resolves chain roots to the
    local copies and :func:`export_script` works on remotely computed
    objects.  Minted tokens carry a per-process nonce so a foreign token
    can never collide with a local one; for legacy nonce-less tokens the
    counter is additionally advanced past the foreign token's number.
    """
    global _NEXT_VERSION
    with _LOCK:
        num = _token_num(token)
        if num is not None and num >= _NEXT_VERSION:
            _NEXT_VERSION = num + 1
        _register_locked(obj, token)
        return token


def adopt_records(obj: Any, records: Sequence["ProvRecord"],
                  token: Optional[str] = None) -> None:
    """Attach a provenance chain deserialized from another process.

    ``token`` is the producing process's version token for ``obj`` (defaults
    to the final record's last output); it is bound via :func:`bind_version`
    so downstream records referencing it keep resolving.  With no records
    and no token this is a no-op.
    """
    recs = tuple(records)
    if token is None and recs:
        token = recs[-1].outputs[-1]
    if token is not None:
        bind_version(obj, token)
    if recs:
        _attach_records(obj, recs)


def object_for_version(version: str) -> Optional[Any]:
    """Live object for a version token, if it is still alive."""
    ref = _BY_VERSION.get(version)
    if ref is None:
        return None
    if isinstance(ref, tuple) and ref[0] is _PINNED:
        return ref[1]
    return ref()


def records_of(obj: Any) -> Tuple[ProvRecord, ...]:
    """Full provenance chain of ``obj`` (empty tuple for root objects)."""
    recs = getattr(obj, "_prov_records", None)
    if recs is None:
        recs = _SIDE_RECORDS.get(id(obj), ())
    return recs


def _attach_records(obj: Any, records: Tuple[ProvRecord, ...]) -> None:
    if not _try_setattr(obj, "_prov_records", records):
        _side_put(_SIDE_RECORDS, obj, records)


def _is_tracked(obj: Any) -> bool:
    from .graph import Graph
    from .table import Table
    return isinstance(obj, (Table, Graph)) or bool(records_of(obj))


# ---------------------------------------------------------------------------
# parameter canonicalization (hashable literals -> cache keys + script text)
# ---------------------------------------------------------------------------

_MAX_EMBED = 256  # arrays up to this many elements become literals


def canonical_value(v: Any) -> Any:
    """Hashable canonical form of a parameter value.

    Scalars pass through; sequences/mappings become tagged tuples; small
    arrays become ``("array", dtype, shape, values)`` literals; everything
    else collapses to an :class:`Opaque` sentinel.
    """
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    from .graph import EdgeDelta  # lazy: graph imports this module at load
    if isinstance(v, EdgeDelta):
        # small deltas embed as literals (replayable/exportable update
        # chains); oversized ones carry Opaque components and stay
        # uncacheable, like any big array param
        return ("edge_delta",
                canonical_value(v.add_src), canonical_value(v.add_dst),
                canonical_value(v.del_src), canonical_value(v.del_dst))
    if isinstance(v, np.ndarray) or (hasattr(v, "dtype") and hasattr(v, "shape")):
        arr = np.asarray(v)
        if arr.ndim == 0:
            return arr.item()
        if arr.size <= _MAX_EMBED:
            return ("array", str(arr.dtype), tuple(arr.shape),
                    tuple(arr.reshape(-1).tolist()))
        return Opaque(f"array{tuple(arr.shape)}:{arr.dtype}")
    if isinstance(v, (list, tuple)):
        return ("tuple", tuple(canonical_value(x) for x in v))
    if isinstance(v, Mapping):
        return ("dict", tuple((str(k), canonical_value(x)) for k, x in v.items()))
    return Opaque(type(v).__name__)


def canonical_params(params: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple((k, canonical_value(v)) for k, v in params.items())


def contains_opaque(canon: Any) -> bool:
    if isinstance(canon, Opaque):
        return True
    if isinstance(canon, tuple):
        return any(contains_opaque(x) for x in canon)
    return False


# -- wire form (cross-process serving) --------------------------------------
# Canonical params are already plain data except Opaque, which has no literal
# form by definition; on the wire it becomes a tagged tuple and comes back as
# a fresh Opaque (identity lost — exactly the semantics Opaque promises).

_OPAQUE_TAG = "__opaque__"


def _wire_val(v: Any) -> Any:
    if isinstance(v, Opaque):
        return (_OPAQUE_TAG, v.desc)
    if isinstance(v, tuple):
        return tuple(_wire_val(x) for x in v)
    return v


def _unwire_val(v: Any) -> Any:
    if isinstance(v, tuple):
        if len(v) == 2 and v[0] == _OPAQUE_TAG:
            return Opaque(v[1])
        return tuple(_unwire_val(x) for x in v)
    return v


def records_to_wire(records: Sequence[ProvRecord]) -> list:
    """Provenance chain -> plain data (tuples/lists/scalars) for the codec."""
    return [{"op": r.op, "inputs": tuple(r.inputs),
             "params": _wire_val(r.params), "outputs": tuple(r.outputs),
             "meta": _wire_val(r.meta)} for r in records]


def records_from_wire(data: Iterable[Mapping[str, Any]]
                      ) -> Tuple[ProvRecord, ...]:
    return tuple(
        ProvRecord(op=d["op"],
                   inputs=tuple((n, v) for n, v in d["inputs"]),
                   params=_unwire_val(tuple(d["params"])),
                   outputs=tuple(d["outputs"]),
                   meta=_unwire_val(tuple(d["meta"]))) for d in data)


def _uncanonical(v: Any) -> Any:
    """Canonical literal -> live value (for replay)."""
    if isinstance(v, Opaque):
        raise ProvenanceError(f"cannot replay opaque parameter {v!r}")
    if isinstance(v, tuple) and v and v[0] == "array":
        import jax.numpy as jnp
        _, dtype, shape, vals = v
        return jnp.asarray(np.asarray(vals, dtype=dtype).reshape(shape))
    if isinstance(v, tuple) and v and v[0] == "edge_delta":
        from .graph import EdgeDelta
        return EdgeDelta(*(np.asarray(_uncanonical(x)) for x in v[1:]))
    if isinstance(v, tuple) and v and v[0] == "tuple":
        return tuple(_uncanonical(x) for x in v[1])
    if isinstance(v, tuple) and v and v[0] == "dict":
        return {k: _uncanonical(x) for k, x in v[1]}
    return v


def _literal(v: Any) -> str:
    """Canonical literal -> python source text (for export_script)."""
    if isinstance(v, Opaque):
        raise ProvenanceError(f"cannot export opaque parameter {v!r}")
    if isinstance(v, tuple) and v and v[0] == "array":
        _, dtype, shape, vals = v
        return (f"jnp.asarray(np.asarray({list(vals)!r}, "
                f"dtype={dtype!r}).reshape({tuple(shape)!r}))")
    if isinstance(v, tuple) and v and v[0] == "edge_delta":
        a_s, a_d, d_s, d_d = (_literal(x) for x in v[1:])
        return (f"EdgeDelta(add_src={a_s}, add_dst={a_d}, "
                f"del_src={d_s}, del_dst={d_d})")
    if isinstance(v, tuple) and v and v[0] == "tuple":
        inner = ", ".join(_literal(x) for x in v[1])
        comma = "," if len(v[1]) == 1 else ""
        return f"({inner}{comma})"
    if isinstance(v, tuple) and v and v[0] == "dict":
        inner = ", ".join(f"{k!r}: {_literal(x)}" for k, x in v[1])
        return "{" + inner + "}"
    return repr(v)


# ---------------------------------------------------------------------------
# op registry + tracking decorator
# ---------------------------------------------------------------------------

# op name -> (callable, script expression path e.g. "R.select")
_OPS: Dict[str, Tuple[Callable, str]] = {}
_LOCAL = threading.local()


def register_op(op: str, fn: Callable, script: str) -> None:
    _OPS[op] = (fn, script)


def record_call(op: str, tracked: Sequence[Tuple[str, Any]],
                params: Mapping[str, Any] | Tuple[Tuple[str, Any], ...],
                out: Any, multi_output: Optional[bool] = None,
                meta: Optional[Mapping[str, Any]] = None) -> ProvRecord:
    """Manually append a :class:`ProvRecord` for an executed op.

    ``tracked`` is (param_name, input_object) in call order; ``params`` holds
    the remaining literal parameters.  Input chains merge (deduplicated by
    output token, order-preserving) and the new record is appended to the
    chain attached to ``out`` (each element, if the op returns a tuple).
    ``meta`` attaches execution metadata (scheduler queueing/coalescing
    facts) that export/replay ignore.

    Used directly by the service's fusion scheduler, which executes one
    batched engine call but must give every per-request slice the provenance
    of the equivalent single-source call.
    """
    if multi_output is None:
        multi_output = isinstance(out, tuple)
    canon = params if isinstance(params, tuple) else canonical_params(params)
    inputs = tuple((name, version_of(objx)) for name, objx in tracked)
    outs = tuple(out) if multi_output else (out,)
    outputs = tuple(version_of(o) for o in outs)
    mcanon = () if meta is None else canonical_params(meta)
    rec = ProvRecord(op=op, inputs=inputs, params=canon, outputs=outputs,
                     meta=mcanon)
    chain: List[ProvRecord] = []
    seen: set = set()
    for _, objx in tracked:
        for r in records_of(objx):
            if r.outputs not in seen:
                seen.add(r.outputs)
                chain.append(r)
    chain.append(rec)
    for o in outs:
        _attach_records(o, tuple(chain))
    return rec


def annotate_last(obj: Any, meta: Mapping[str, Any]) -> bool:
    """Merge ``meta`` into the newest provenance record attached to ``obj``.

    The service scheduler uses this to stamp queueing/coalescing facts
    (queued_ms, batch size, scheduling mode) onto a result produced through
    a ``@track``-ed op — the record already exists by the time the
    scheduler knows what it cost.  Returns False (no-op) for objects
    without provenance, e.g. tuple-returning ops or roots.  Only call this
    on a freshly produced object: chains are shared by reference with
    cached copies of the same value.
    """
    recs = records_of(obj)
    if not recs:
        return False
    last = _dc_replace(recs[-1],
                       meta=recs[-1].meta + canonical_params(meta))
    _attach_records(obj, recs[:-1] + (last,))
    return True


def track(op: str, script: str) -> Callable:
    """Decorator: register ``fn`` as op ``op`` and record each top-level call.

    Nested tracked calls (one tracked op implemented via another) record
    nothing — the reentrancy guard keeps chains at user-call granularity.
    ``script`` is the expression path used by :func:`export_script`
    (e.g. ``"R.select"``); it must resolve under the standard script header.
    """
    def deco(fn: Callable) -> Callable:
        sig = inspect.signature(fn)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if getattr(_LOCAL, "depth", 0):
                return fn(*args, **kwargs)
            _LOCAL.depth = 1
            try:
                out = fn(*args, **kwargs)
            finally:
                _LOCAL.depth = 0
            try:
                bound = sig.bind(*args, **kwargs)
                bound.apply_defaults()
            except TypeError:  # pragma: no cover - fn would have raised too
                return out
            tracked_in: List[Tuple[str, Any]] = []
            params: List[Tuple[str, Any]] = []
            for name, val in bound.arguments.items():
                if _is_tracked(val):
                    tracked_in.append((name, val))
                else:
                    params.append((name, canonical_value(val)))
            record_call(op, tracked_in, tuple(params), out)
            return out

        register_op(op, wrapper, script)
        return wrapper

    return deco


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------


def roots_of(records: Sequence[ProvRecord]) -> Tuple[str, ...]:
    """Version tokens consumed but never produced by ``records`` (in order)."""
    produced = {v for r in records for v in r.outputs}
    roots: List[str] = []
    for r in records:
        for _, ver in r.inputs:
            if ver not in produced and ver not in roots:
                roots.append(ver)
    return tuple(roots)


def replay(records: Sequence[ProvRecord], inputs: Mapping[str, Any]):
    """Re-execute a record chain against fresh root inputs.

    ``inputs`` maps root version tokens (see :func:`roots_of`) to objects.
    Returns the value of the final record (a tuple if it had multiple
    outputs).  Replayed objects get fresh provenance of their own.
    """
    env: Dict[str, Any] = dict(inputs)
    out: Any = None
    for r in records:
        if r.op not in _OPS:
            raise ProvenanceError(f"unknown op {r.op!r} in record chain")
        fn, _ = _OPS[r.op]
        kwargs: Dict[str, Any] = {}
        for name, ver in r.inputs:
            if ver not in env:
                raise ProvenanceError(
                    f"replay missing input {ver!r} for op {r.op!r}; "
                    f"provide it via inputs= (roots: {roots_of(records)})")
            kwargs[name] = env[ver]
        for name, val in r.params:
            kwargs[name] = _uncanonical(val)
        out = fn(**kwargs)
        if len(r.outputs) > 1:
            for ver, o in zip(r.outputs, out):
                env[ver] = o
        else:
            env[r.outputs[0]] = out
    return out


# ---------------------------------------------------------------------------
# script export (the paper's §4 "export the analysis as a program")
# ---------------------------------------------------------------------------

_SCRIPT_HEADER = '''\
"""Auto-exported provenance script (Ringo §4: an interactive analysis,
replayable as a standalone program).  Run with PYTHONPATH=<repo>/src."""

import numpy as np
import jax.numpy as jnp

from repro.core.table import Table
from repro.core.graph import EdgeDelta, Graph
from repro.core import relational as R
from repro.core import algorithms as A
from repro.core import convert as C
'''


def _embed_root(ver: str, obj: Any) -> str:
    """Literal construction code for a root object (Table/Graph/array)."""
    from .graph import Graph
    from .table import Table
    if isinstance(obj, Table):
        schema = {n: t for n, t in obj.schema.fields}
        data = obj.to_pydict()
        return f"{ver} = Table.from_columns({schema!r}, {data!r})"
    if isinstance(obj, Graph):
        s, d = obj.out_edges()
        src = np.asarray(obj.original_of(s)).tolist()
        dst = np.asarray(obj.original_of(d)).tolist()
        return (f"{ver} = Graph.from_edges(np.asarray({src!r}, np.int32), "
                f"np.asarray({dst!r}, np.int32), dedupe=False)")
    canon = canonical_value(obj)
    if contains_opaque(canon):
        raise ProvenanceError(
            f"root {ver!r} ({type(obj).__name__}) is too large to embed; "
            f"use embed_roots=False and pass it to the emitted function")
    return f"{ver} = {_literal(canon)}"


def export_script(obj: Any, *, embed_roots: bool = True,
                  func_name: str = "rebuild") -> str:
    """Emit a runnable Python script that rebuilds ``obj`` from its chain.

    With ``embed_roots=True`` root tables/graphs are embedded as literal
    constructors and the emitted ``rebuild()`` takes no arguments — a fully
    standalone program.  With ``embed_roots=False`` the roots become the
    function's parameters (named by version token), for re-running the same
    analysis against fresh data.
    """
    records = records_of(obj)
    if not records:
        raise ProvenanceError(
            "object has no provenance records (is it a root, or did it "
            "cross a jit boundary?)")
    target = version_of(obj)
    roots = roots_of(records)
    lines: List[str] = [_SCRIPT_HEADER, ""]

    if embed_roots:
        arg_list = ""
        body_roots: List[str] = []
        for ver in roots:
            root_obj = object_for_version(ver)
            if root_obj is None:
                raise ProvenanceError(
                    f"root object {ver!r} has been garbage-collected; "
                    f"keep roots alive (e.g. in a Workspace) or use "
                    f"embed_roots=False")
            body_roots.append("    " + _embed_root(ver, root_obj))
    else:
        arg_list = ", ".join(roots)
        body_roots = []

    lines.append(f"def {func_name}({arg_list}):")
    lines.extend(body_roots)
    for r in records:
        if r.op not in _OPS:
            raise ProvenanceError(f"unknown op {r.op!r} in record chain")
        _, path = _OPS[r.op]
        kwargs = [f"{name}={ver}" for name, ver in r.inputs]
        kwargs += [f"{name}={_literal(val)}" for name, val in r.params]
        targets = ", ".join(r.outputs)
        lines.append(f"    {targets} = {path}({', '.join(kwargs)})")
    lines.append(f"    return {target}")
    lines.append("")
    lines.append("")
    lines.append('if __name__ == "__main__":')
    if embed_roots:
        lines.append(f"    print({func_name}())")
    else:
        msg = f"pass roots {', '.join(roots)} to {func_name}()"
        lines.append(f"    raise SystemExit({msg!r})")
    lines.append("")
    return "\n".join(lines)
