"""Columnar in-memory tables (Ringo §2.3) in JAX.

Ringo implements native relational tables as a *column-based store* with a
schema (int / float / string columns) and a **persistent unique row id** per
row, which enables fast in-place grouping/filtering/selection and fine-grained
data tracking through complex pipelines.

TPU/JAX adaptation
------------------
XLA wants static shapes, but an interactive system produces data-dependent
sizes (a select's output size is known only after it runs).  We therefore give
every table a *capacity* (padded, bucketed to powers of two so recompiles are
logarithmic in growth) and an explicit ``n_valid``.  Rows beyond ``n_valid``
are padding.  "Select in place" (paper Table 4) compacts valid rows to the
front of the same capacity bucket — the static-shape dual of Ringo's
persistent-row-id filtering.

Strings are dictionary-encoded: a column holds int32 codes plus a host-side
list of unique strings (Ringo's C++ backend does the same via string pools).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Schema",
    "Table",
    "ColumnType",
    "next_capacity",
    "INT",
    "FLOAT",
    "STR",
]

# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

INT = "int"
FLOAT = "float"
STR = "str"

# INT columns are explicitly int32: we run with x64 disabled, and an int64
# entry here would be a lie — jnp.asarray(..., dtype=int64) silently
# truncates to int32 under the default config (with a warning in some JAX
# versions).  Declaring int32 makes the on-device dtype the declared dtype;
# int round-trip safety is asserted in tests/test_table.py.
_DTYPE_FOR = {INT: jnp.int32, FLOAT: jnp.float32, STR: jnp.int32}
_DTYPE_FOR_32 = _DTYPE_FOR  # alias retained for older call sites

ColumnType = str


@dataclass(frozen=True)
class Schema:
    """Ordered mapping of column name -> type (int | float | str)."""

    fields: Tuple[Tuple[str, ColumnType], ...]

    def __post_init__(self):
        names = [n for n, _ in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names in schema: {names}")
        for _, t in self.fields:
            if t not in (INT, FLOAT, STR):
                raise ValueError(f"unknown column type {t!r}")

    @classmethod
    def of(cls, spec: Mapping[str, ColumnType] | Sequence[Tuple[str, ColumnType]]) -> "Schema":
        if isinstance(spec, Mapping):
            return cls(tuple(spec.items()))
        return cls(tuple(spec))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.fields)

    def type_of(self, name: str) -> ColumnType:
        for n, t in self.fields:
            if n == name:
                return t
        raise KeyError(f"no column {name!r}; have {self.names}")

    def __contains__(self, name: str) -> bool:
        return any(n == name for n, _ in self.fields)

    def with_column(self, name: str, typ: ColumnType) -> "Schema":
        if name in self:
            raise ValueError(f"column {name!r} already exists")
        return Schema(self.fields + ((name, typ),))

    def project(self, names: Sequence[str]) -> "Schema":
        return Schema(tuple((n, self.type_of(n)) for n in names))


def next_capacity(n: int, minimum: int = 8) -> int:
    """Bucket a length to the next power of two (recompile control)."""
    cap = max(int(minimum), 1)
    while cap < n:
        cap *= 2
    return cap


# ---------------------------------------------------------------------------
# Table
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class Table:
    """Columnar table with padded capacity and persistent row ids.

    Attributes
    ----------
    schema:   column names and types (static / aux data).
    columns:  dict name -> jnp array of shape (capacity,).
    row_ids:  (capacity,) int32 persistent unique row identifiers.
    n_valid:  number of valid rows (python int — host-side, like Ringo's
              table length; ops that change it run eagerly).
    dicts:    for STR columns, name -> list of unique strings (host side).
    next_row_id: next fresh row id (host side).
    """

    schema: Schema
    columns: Dict[str, jax.Array]
    row_ids: jax.Array
    n_valid: int
    dicts: Dict[str, List[str]] = field(default_factory=dict)
    next_row_id: int = 0

    # -- pytree protocol (leaves: columns + row_ids) ------------------------
    def tree_flatten(self):
        names = self.schema.names
        leaves = tuple(self.columns[n] for n in names) + (self.row_ids,)
        aux = (self.schema, self.n_valid, tuple(sorted(self.dicts.items())), self.next_row_id)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        schema, n_valid, dict_items, next_row_id = aux
        names = schema.names
        columns = {n: leaves[i] for i, n in enumerate(names)}
        return cls(
            schema=schema,
            columns=columns,
            row_ids=leaves[len(names)],
            n_valid=n_valid,
            dicts={k: list(v) for k, v in dict_items},
            next_row_id=next_row_id,
        )

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_columns(
        cls,
        schema: Schema | Mapping[str, ColumnType],
        data: Mapping[str, Any],
        capacity: Optional[int] = None,
    ) -> "Table":
        if not isinstance(schema, Schema):
            schema = Schema.of(schema)
        names = schema.names
        if set(data.keys()) != set(names):
            raise ValueError(f"data columns {sorted(data)} != schema columns {sorted(names)}")

        n = None
        dicts: Dict[str, List[str]] = {}
        cols: Dict[str, jax.Array] = {}
        for name in names:
            typ = schema.type_of(name)
            raw = data[name]
            if typ == STR:
                codes, uniq = _encode_strings(raw)
                dicts[name] = uniq
                arr = jnp.asarray(codes, dtype=jnp.int32)
            else:
                arr = jnp.asarray(np.asarray(raw), dtype=_DTYPE_FOR_32[typ])
            if n is None:
                n = int(arr.shape[0])
            elif int(arr.shape[0]) != n:
                raise ValueError("ragged columns")
            cols[name] = arr
        n = n or 0
        cap = next_capacity(n) if capacity is None else capacity
        if cap < n:
            raise ValueError(f"capacity {cap} < n rows {n}")
        for name in names:
            cols[name] = _pad_to(cols[name], cap)
        row_ids = _pad_to(jnp.arange(n, dtype=jnp.int32), cap, fill=-1)
        return cls(schema=schema, columns=cols, row_ids=row_ids, n_valid=n,
                   dicts=dicts, next_row_id=n)

    @classmethod
    def empty(cls, schema: Schema | Mapping[str, ColumnType], capacity: int = 8) -> "Table":
        if not isinstance(schema, Schema):
            schema = Schema.of(schema)
        data = {n: np.zeros((0,), dtype=np.float32 if schema.type_of(n) == FLOAT else np.int32)
                if schema.type_of(n) != STR else []
                for n in schema.names}
        return cls.from_columns(schema, data, capacity=capacity)

    # -- basic accessors ------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.row_ids.shape[0])

    @property
    def version(self) -> str:
        """Provenance version token (see :mod:`repro.core.provenance`).

        Tables are value-immutable (ops return new tables), so the token is
        a stable cache key for any result derived from this table.
        """
        from .provenance import version_of
        return version_of(self)

    def __len__(self) -> int:
        return self.n_valid

    def column(self, name: str) -> jax.Array:
        """Valid prefix of a column (device array, length n_valid)."""
        return self.columns[name][: self.n_valid]

    def column_np(self, name: str) -> np.ndarray:
        return np.asarray(self.column(name))

    def strings(self, name: str) -> List[str]:
        """Decode a STR column back to python strings (host side)."""
        if self.schema.type_of(name) != STR:
            raise TypeError(f"{name} is not a string column")
        codes = self.column_np(name)
        uniq = self.dicts[name]
        return [uniq[c] for c in codes]

    def to_pydict(self) -> Dict[str, list]:
        out: Dict[str, list] = {}
        for name in self.schema.names:
            if self.schema.type_of(name) == STR:
                out[name] = self.strings(name)
            else:
                out[name] = self.column_np(name).tolist()
        return out

    # -- structural ops -------------------------------------------------------
    def with_valid(self, columns: Dict[str, jax.Array], row_ids: jax.Array,
                   n_valid: int) -> "Table":
        """Rebuild with same schema/dicts but new storage (bucketed)."""
        return Table(schema=self.schema, columns=columns, row_ids=row_ids,
                     n_valid=n_valid, dicts=dict(self.dicts), next_row_id=self.next_row_id)

    def compacted(self, keep_mask: jax.Array) -> "Table":
        """Keep rows where mask (length n_valid) is True; compact to front.

        This is Ringo's "select in place": same object shape, fewer valid rows.
        """
        mask = keep_mask[: self.n_valid]
        n_keep = int(jnp.sum(mask))
        cap = self.capacity
        # stable compaction permutation: valid keeps first, in order
        perm = _compact_perm(mask, cap)
        cols = {n: jnp.take(self.columns[n], perm, axis=0) for n in self.schema.names}
        rid = jnp.take(self.row_ids, perm, axis=0)
        return self.with_valid(cols, rid, n_keep)

    def gathered(self, idx: jax.Array, n_valid: int,
                 fresh_row_ids: bool = False) -> "Table":
        """New table whose rows are self[idx] (idx may exceed n_valid into pad)."""
        cap = next_capacity(int(idx.shape[0]))
        idx = _pad_to(idx.astype(jnp.int32), cap)
        cols = {n: jnp.take(self.columns[n], idx, axis=0) for n in self.schema.names}
        if fresh_row_ids:
            rid = _pad_to(jnp.arange(n_valid, dtype=jnp.int32), cap, fill=-1)
            t = self.with_valid(cols, rid, n_valid)
            t.next_row_id = n_valid
            return t
        rid = jnp.take(self.row_ids, idx, axis=0)
        return self.with_valid(cols, rid, n_valid)

    def with_column_added(self, name: str, typ: ColumnType, values: Any,
                          strings: Optional[List[str]] = None) -> "Table":
        """Add a column (length n_valid or capacity); pads to capacity."""
        arr = jnp.asarray(values)
        if typ == STR:
            if strings is None:
                codes, strings = _encode_strings(values)
                arr = jnp.asarray(codes, dtype=jnp.int32)
            else:
                arr = arr.astype(jnp.int32)
        else:
            arr = arr.astype(_DTYPE_FOR_32[typ])
        if int(arr.shape[0]) == self.n_valid:
            arr = _pad_to(arr, self.capacity)
        elif int(arr.shape[0]) != self.capacity:
            raise ValueError("column length must be n_valid or capacity")
        new_schema = self.schema.with_column(name, typ)
        cols = dict(self.columns)
        cols[name] = arr
        dicts = dict(self.dicts)
        if typ == STR:
            dicts[name] = list(strings or [])
        return Table(schema=new_schema, columns=cols, row_ids=self.row_ids,
                     n_valid=self.n_valid, dicts=dicts, next_row_id=self.next_row_id)

    def renamed(self, mapping: Mapping[str, str]) -> "Table":
        fields = tuple((mapping.get(n, n), t) for n, t in self.schema.fields)
        cols = {mapping.get(n, n): a for n, a in self.columns.items()}
        dicts = {mapping.get(n, n): v for n, v in self.dicts.items()}
        return Table(schema=Schema(fields), columns=cols, row_ids=self.row_ids,
                     n_valid=self.n_valid, dicts=dicts, next_row_id=self.next_row_id)

    def nbytes(self) -> int:
        """In-memory size (paper Table 2 analogue)."""
        total = self.row_ids.size * self.row_ids.dtype.itemsize
        for a in self.columns.values():
            total += a.size * a.dtype.itemsize
        return int(total)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"Table({self.n_valid} rows / cap {self.capacity}, "
                f"cols={list(self.schema.names)})")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _pad_to(a: jax.Array, cap: int, fill: int | float = 0) -> jax.Array:
    n = int(a.shape[0])
    if n == cap:
        return a
    if n > cap:
        raise ValueError(f"array of {n} rows > capacity {cap}")
    pad = jnp.full((cap - n,) + a.shape[1:], fill, dtype=a.dtype)
    return jnp.concatenate([a, pad], axis=0)


def _encode_strings(raw: Iterable[str]) -> Tuple[np.ndarray, List[str]]:
    """Dictionary-encode strings -> (codes, uniques). Stable first-seen order."""
    uniq: Dict[str, int] = {}
    codes = []
    for s in raw:
        code = uniq.setdefault(s, len(uniq))
        codes.append(code)
    return np.asarray(codes, dtype=np.int32), list(uniq.keys())


@functools.partial(jax.jit, static_argnums=(1,))
def _compact_perm(mask: jax.Array, cap: int) -> jax.Array:
    """Permutation putting True rows (in order) first, padded with cap-1 dups.

    mask has length n_valid <= cap; result has length cap.
    """
    n = mask.shape[0]
    full = jnp.zeros((cap,), dtype=bool).at[:n].set(mask)
    # stable argsort of (not mask): True rows keep order at the front
    order = jnp.argsort(~full, stable=True)
    return order.astype(jnp.int32)
