"""Graph data structure (Ringo §2.2) — static-shape dual CSR in JAX.

Ringo represents a directed graph as a hash table of nodes, each node holding
two *sorted adjacency vectors* (in- and out-neighbors).  The representation
targets (a) fast neighborhood access for traversal and (b) dynamism.

TPU/JAX adaptation (DESIGN.md §2): XLA has no pointer-stable hash tables, so
we keep the *logical* structure — per-node sorted neighbor lists, both
directions — in **padded CSR** form with densely renumbered node ids:

    node_ids : (node_cap,)   original ids, ascending (padding = INT32_MAX)
    out_ptr  : (node_cap+1,) CSR row pointers (out-adjacency)
    out_idx  : (edge_cap,)   dense dst ids, sorted within each row
    in_ptr   : (node_cap+1,)
    in_idx   : (edge_cap,)   dense src ids, sorted within each row

The hash-table lookup ``id -> node`` becomes ``searchsorted(node_ids, id)``
(log n, vectorized over queries); updates are functional rebuilds via sorted
merge (O(E log E), fully parallel) instead of O(deg) in-place edits.
Capacities are power-of-two bucketed like tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from .provenance import track, version_of
from .table import next_capacity

__all__ = ["Graph", "EdgeDelta", "INVALID_ID"]

INVALID_ID = np.iinfo(np.int32).max

_log = obs.get_logger(__name__)
_C_PLAN_HIT = obs.counter("engine.plan_cache.hits")
_C_PLAN_MISS = obs.counter("engine.plan_cache.misses")
_C_PLAN_PATCH = obs.counter("engine.plan_cache.patched")


@dataclass(frozen=True)
class EdgeDelta:
    """Batch of edge inserts/deletes in **original** node ids.

    The unit of incremental maintenance (Ringo's dynamism story): applying a
    delta via :meth:`Graph.apply_delta` yields a new graph whose traversal
    plan can be *patched* from the parent's instead of re-derived, and whose
    analytics can warm-start from the parent's results.  Deleting an edge
    that does not exist is a no-op; inserted duplicates are deduped.
    """

    add_src: np.ndarray
    add_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray

    def __post_init__(self):
        for name in ("add_src", "add_dst", "del_src", "del_dst"):
            a = np.asarray(getattr(self, name), dtype=np.int32).reshape(-1)
            object.__setattr__(self, name, a)
        if self.add_src.shape != self.add_dst.shape:
            raise ValueError("EdgeDelta add_src/add_dst length mismatch")
        if self.del_src.shape != self.del_dst.shape:
            raise ValueError("EdgeDelta del_src/del_dst length mismatch")

    @classmethod
    def inserts(cls, src, dst) -> "EdgeDelta":
        empty = np.empty((0,), np.int32)
        return cls(src, dst, empty, empty)

    @classmethod
    def deletes(cls, src, dst) -> "EdgeDelta":
        empty = np.empty((0,), np.int32)
        return cls(empty, empty, src, dst)

    @property
    def n_adds(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def n_dels(self) -> int:
        return int(self.del_src.shape[0])

    @property
    def insert_only(self) -> bool:
        return self.n_dels == 0

    def __repr__(self) -> str:  # pragma: no cover
        return f"EdgeDelta(+{self.n_adds} edges, -{self.n_dels} edges)"


@dataclass
class _DeltaInfo:
    """How a Graph was derived from its parent — fuel for plan patching.

    Dense-id arrays in the **child** numbering (== parent numbering on the
    fast path, which is the only path that records one of these).  The merged
    edge lists are host-side copies of both CSR orders so the plan patch
    never re-sorts on device.
    """

    parent: "Graph"
    add_src: np.ndarray      # applied (deduped) inserts, out-order sorted
    add_dst: np.ndarray
    del_src: np.ndarray      # distinct deleted pairs
    del_dst: np.ndarray
    insert_only: bool        # no edge was actually removed
    dirty: np.ndarray        # dense vertex ids touched by the delta
    out_src: np.ndarray      # merged edges sorted by (src, dst)
    out_dst: np.ndarray
    in_src: np.ndarray       # merged edges sorted by (dst, src)
    in_dst: np.ndarray


@jax.tree_util.register_pytree_node_class
@dataclass
class Graph:
    """Directed graph with dense node ids [0, n_nodes) and dual CSR."""

    n_nodes: int
    n_edges: int
    node_ids: jax.Array
    out_ptr: jax.Array
    out_idx: jax.Array
    in_ptr: jax.Array
    in_idx: jax.Array
    # Identity-keyed traversal-plan cache (core/plan.py).  Not a pytree leaf:
    # a Graph reconstructed inside jit starts with a cold cache, and the
    # functional update methods return fresh Graph objects, so a stale plan
    # can never be observed.
    _plan: Optional[object] = field(default=None, repr=False, compare=False)
    # Delta lineage (set by apply_delta's fast path).  Also not a pytree
    # leaf: a Graph rebuilt inside jit loses its lineage and simply rebuilds
    # its plan from scratch — correct, just not incremental.
    _delta: Optional[_DeltaInfo] = field(default=None, repr=False, compare=False)

    # -- pytree ---------------------------------------------------------------
    def tree_flatten(self):
        leaves = (self.node_ids, self.out_ptr, self.out_idx, self.in_ptr, self.in_idx)
        return leaves, (self.n_nodes, self.n_edges)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        n_nodes, n_edges = aux
        return cls(n_nodes, n_edges, *leaves)

    # -- constructors ----------------------------------------------------------
    @classmethod
    def from_dense_edges(cls, src: jax.Array, dst: jax.Array, n_nodes: int,
                         node_ids: Optional[jax.Array] = None) -> "Graph":
        """Build from dense-id edge arrays (valid length = full length).

        This is the core of the paper's **sort-first** algorithm (§2.4):
        (1) copy the columns, (2) sort them, (3) compute neighbor counts
        explicitly, (4) bulk-write adjacency — no contention, no estimates.
        """
        src = src.astype(jnp.int32)
        dst = dst.astype(jnp.int32)
        e = int(src.shape[0])
        node_cap = next_capacity(max(n_nodes, 1))
        edge_cap = next_capacity(max(e, 1))

        if node_ids is None:
            ids = jnp.where(jnp.arange(node_cap) < n_nodes,
                            jnp.arange(node_cap, dtype=jnp.int32), INVALID_ID)
        else:
            ids = _pad_ids(node_ids, node_cap)

        out_ptr, out_idx = _csr_from_pairs(src, dst, n_nodes, node_cap, edge_cap)
        in_ptr, in_idx = _csr_from_pairs(dst, src, n_nodes, node_cap, edge_cap)
        return cls(n_nodes=n_nodes, n_edges=e, node_ids=ids,
                   out_ptr=out_ptr, out_idx=out_idx, in_ptr=in_ptr, in_idx=in_idx)

    @classmethod
    def from_edges(cls, src, dst, dedupe: bool = True,
                   drop_self_loops: bool = False) -> "Graph":
        """Build from raw (original-id) edge arrays; renumbers densely.

        Node set = union of endpoint ids (paper §2.4: "Nodes V are defined by
        unique values in columns S and D").
        """
        src = jnp.asarray(src, dtype=jnp.int32)
        dst = jnp.asarray(dst, dtype=jnp.int32)
        if drop_self_loops:
            keep = src != dst
            n_keep = int(jnp.sum(keep))
            perm = jnp.argsort(~keep, stable=True)[:max(n_keep, 1)]
            src, dst = src[perm][:n_keep], dst[perm][:n_keep]

        # dense renumbering: the sort-based dual of Ringo's node hash table
        all_ids = jnp.sort(jnp.concatenate([src, dst]))
        if all_ids.shape[0] == 0:
            return cls.from_dense_edges(src, dst, 0)
        firsts = jnp.concatenate([jnp.ones((1,), bool), all_ids[1:] != all_ids[:-1]])
        n_nodes = int(jnp.sum(firsts))
        node_cap = next_capacity(max(n_nodes, 1))
        uniq_pos = jnp.nonzero(firsts, size=node_cap, fill_value=all_ids.shape[0] - 1)[0]
        node_ids = jnp.where(jnp.arange(node_cap) < n_nodes, all_ids[uniq_pos],
                             INVALID_ID)
        valid_ids = node_ids[:n_nodes]
        src_d = jnp.searchsorted(valid_ids, src).astype(jnp.int32)
        dst_d = jnp.searchsorted(valid_ids, dst).astype(jnp.int32)

        if dedupe:
            src_d, dst_d = _dedupe_pairs(src_d, dst_d, n_nodes)
        return cls.from_dense_edges(src_d, dst_d, n_nodes, node_ids=node_ids)

    # -- accessors ---------------------------------------------------------------
    @property
    def node_capacity(self) -> int:
        return int(self.node_ids.shape[0])

    @property
    def edge_capacity(self) -> int:
        return int(self.out_idx.shape[0])

    def out_degrees(self) -> jax.Array:
        return (self.out_ptr[1:] - self.out_ptr[:-1])[: self.n_nodes]

    def in_degrees(self) -> jax.Array:
        return (self.in_ptr[1:] - self.in_ptr[:-1])[: self.n_nodes]

    def out_edges(self) -> Tuple[jax.Array, jax.Array]:
        """(src, dst) with edges sorted by src (dense ids, valid prefix)."""
        e = self.n_edges
        src = _row_of_edge(self.out_ptr, self.edge_capacity)[:e]
        return src, self.out_idx[:e]

    def in_edges(self) -> Tuple[jax.Array, jax.Array]:
        """(src, dst) with edges sorted by dst (dense ids, valid prefix)."""
        e = self.n_edges
        dst = _row_of_edge(self.in_ptr, self.edge_capacity)[:e]
        return self.in_idx[:e], dst

    def neighbors_out(self, dense_id: int) -> jax.Array:
        lo, hi = int(self.out_ptr[dense_id]), int(self.out_ptr[dense_id + 1])
        return self.out_idx[lo:hi]

    # -- traversal plan (the shared-substrate hook; Ringo §2.2) -----------------
    def plan(self):
        """Memoized :class:`repro.core.plan.GraphPlan` for this graph.

        Built on first use and cached by graph identity, so the paper's
        trial-and-error loop — many algorithm calls against one graph —
        pays the edge-sort / re-blocking cost exactly once.  The functional
        update methods (:meth:`add_edges`, :meth:`delete_edges`) return new
        ``Graph`` objects whose plan cache starts empty (invalidation by
        construction); call :meth:`invalidate_plan` only if the underlying
        buffers are mutated out-of-band (donated buffers etc.).
        """
        if self._plan is None:
            from .plan import GraphPlan  # local import: plan -> kernels -> graph
            _C_PLAN_MISS.inc()
            if self._delta is not None:
                _C_PLAN_PATCH.inc()
                self._plan = GraphPlan.patch(self, self._delta)
            else:
                self._plan = GraphPlan.build(self)
        else:
            _C_PLAN_HIT.inc()
        return self._plan

    def invalidate_plan(self) -> None:
        self._plan = None

    @property
    def version(self) -> str:
        """Provenance version token (Ringo §2.1 object metadata).

        Graphs are immutable and the update methods return fresh objects, so
        the token doubles as a cache key: any result computed against it stays
        valid forever — a functional update yields a new token, which is the
        service-layer mirror of the plan cache's invalidation-by-construction.
        """
        return version_of(self)

    def dense_of(self, original_ids) -> jax.Array:
        """Vectorized id lookup (the hash-probe dual)."""
        q = jnp.asarray(original_ids, dtype=jnp.int32)
        return jnp.searchsorted(self.node_ids[: self.n_nodes], q).astype(jnp.int32)

    def original_of(self, dense_ids) -> jax.Array:
        return self.node_ids[jnp.asarray(dense_ids, dtype=jnp.int32)]

    # -- functional updates (the dynamism story) -----------------------------------
    @track("graph.add_edges", "Graph.add_edges")
    def add_edges(self, src, dst, dedupe: bool = True) -> "Graph":
        """Merge new edges (original ids) — functional rebuild via sorted merge."""
        osrc = self.original_of(self.out_edges()[0])
        odst = self.original_of(self.out_edges()[1])
        src = jnp.concatenate([osrc, jnp.asarray(src, jnp.int32)])
        dst = jnp.concatenate([odst, jnp.asarray(dst, jnp.int32)])
        return Graph.from_edges(src, dst, dedupe=dedupe)

    @track("graph.delete_edges", "Graph.delete_edges")
    def delete_edges(self, src, dst) -> "Graph":
        """Remove the given (original-id) edges; sort-based anti-join.

        Host-side op (interactive path): exact 64-bit pair keys via numpy,
        since device int64 is disabled in 32-bit mode.
        """
        s, d = self.out_edges()
        os = np.asarray(self.original_of(s), dtype=np.int64)
        od = np.asarray(self.original_of(d), dtype=np.int64)
        keys = (os << np.int64(32)) | (od & np.int64(0xFFFFFFFF))
        dk = (np.asarray(src, dtype=np.int64) << np.int64(32)) | \
             (np.asarray(dst, dtype=np.int64) & np.int64(0xFFFFFFFF))
        keep = ~np.isin(keys, dk)
        return Graph.from_edges(os[keep].astype(np.int32),
                                od[keep].astype(np.int32), dedupe=False)

    @track("graph.apply_delta", "Graph.apply_delta")
    def apply_delta(self, delta: EdgeDelta) -> "Graph":
        """Batch edge inserts/deletes (original ids) -> new Graph.

        Fast path — every insert endpoint is already a node — performs a
        host-side sorted merge of both CSR orders (O(E + Δ log Δ) numpy
        passes, no device re-sort) and records a ``_DeltaInfo`` so
        :meth:`plan` can *patch* the parent's plan instead of re-deriving
        it.  Inserts are deduped against the kept edges and themselves;
        deleting a non-existent edge is a no-op (all duplicates of a
        matched pair are removed, like :meth:`delete_edges`).

        When an insert endpoint is a brand-new node the dense numbering
        shifts, so we fall back to a full rebuild with a logged reason; the
        child then carries no delta lineage and its plan is built cold.
        """
        n = self.n_nodes
        valid = np.asarray(self.node_ids[:n]) if n else np.empty((0,), np.int32)
        new_eps = np.concatenate([delta.add_src, delta.add_dst])
        _, known = _dense_lookup(valid, new_eps)
        if new_eps.size and not bool(np.all(known)):
            n_new = int(np.unique(new_eps[~known]).size)
            _log.info("apply_delta.full_rebuild", new_nodes=n_new,
                      reason="dense numbering shifts")
            return self._apply_delta_rebuild(delta)

        s, d = self.out_edges()
        s64 = np.asarray(s).astype(np.int64)
        d64 = np.asarray(d).astype(np.int64)
        keys = (s64 << 32) | d64  # dense ids are non-negative: sorted, exact

        # -- deletes: anti-join on dense pair keys (absent endpoints no-op) --
        if delta.n_dels:
            dp, ok_s = _dense_lookup(valid, delta.del_src)
            dq, ok_d = _dense_lookup(valid, delta.del_dst)
            ok = ok_s & ok_d
            dkeys = np.unique((dp[ok] << 32) | dq[ok])
            keep = ~_in_sorted(dkeys, keys)
        else:
            keep = np.ones(keys.shape, bool)
        kept = keys[keep]
        dropped = np.unique(keys[~keep])
        n_deleted = int(keys.size - kept.size)

        # -- inserts: dedupe, then merge into the sorted out-order list --
        if delta.n_adds:
            ai, _ = _dense_lookup(valid, delta.add_src)
            aj, _ = _dense_lookup(valid, delta.add_dst)
            akeys = np.unique((ai << 32) | aj)
            akeys = akeys[~_in_sorted(kept, akeys)]
        else:
            akeys = np.empty((0,), np.int64)
        merged = (np.insert(kept, np.searchsorted(kept, akeys), akeys)
                  if akeys.size else kept)

        # -- same merge in in-order (sorted by dst, then src) --
        si, di = self.in_edges()
        keys_in = (np.asarray(di).astype(np.int64) << 32) | \
                  np.asarray(si).astype(np.int64)
        if n_deleted:
            dkeys_in = np.sort(((dropped & 0xFFFFFFFF) << 32) | (dropped >> 32))
            kept_in = keys_in[~_in_sorted(dkeys_in, keys_in)]
        else:
            kept_in = keys_in
        if akeys.size:
            akeys_in = np.sort(((akeys & 0xFFFFFFFF) << 32) | (akeys >> 32))
            merged_in = np.insert(kept_in, np.searchsorted(kept_in, akeys_in),
                                  akeys_in)
        else:
            merged_in = kept_in

        # -- rebuild the padded CSR arrays from the merged host lists --
        e2 = int(merged.size)
        node_cap = self.node_capacity
        edge_cap = next_capacity(max(e2, 1))
        m_src = (merged >> 32).astype(np.int32)
        m_dst = (merged & 0xFFFFFFFF).astype(np.int32)
        mi_dst = (merged_in >> 32).astype(np.int32)
        mi_src = (merged_in & 0xFFFFFFFF).astype(np.int32)
        out_idx = np.zeros((edge_cap,), np.int32)
        out_idx[:e2] = m_dst
        in_idx = np.zeros((edge_cap,), np.int32)
        in_idx[:e2] = mi_src

        child = Graph(n_nodes=n, n_edges=e2, node_ids=self.node_ids,
                      out_ptr=jnp.asarray(_host_ptr(m_src, node_cap)),
                      out_idx=jnp.asarray(out_idx),
                      in_ptr=jnp.asarray(_host_ptr(mi_dst, node_cap)),
                      in_idx=jnp.asarray(in_idx))
        dirty = np.unique(np.concatenate([
            akeys >> 32, akeys & 0xFFFFFFFF,
            dropped >> 32, dropped & 0xFFFFFFFF])).astype(np.int32)
        child._delta = _DeltaInfo(
            parent=self,
            add_src=(akeys >> 32).astype(np.int32),
            add_dst=(akeys & 0xFFFFFFFF).astype(np.int32),
            del_src=(dropped >> 32).astype(np.int32),
            del_dst=(dropped & 0xFFFFFFFF).astype(np.int32),
            insert_only=(n_deleted == 0),
            dirty=dirty,
            out_src=m_src, out_dst=m_dst, in_src=mi_src, in_dst=mi_dst)
        return child

    def _apply_delta_rebuild(self, delta: EdgeDelta) -> "Graph":
        """Slow path: node set grows -> renumber and rebuild from scratch.

        Node set = parent nodes (isolated ones included) + new insert
        endpoints; delete/dedupe semantics match the fast path.
        """
        s, d = self.out_edges()
        os = np.asarray(self.original_of(s)).astype(np.int64)
        od = np.asarray(self.original_of(d)).astype(np.int64)
        # original ids may be any int32, so mask the low word (injective on
        # int32 pairs; only used for set membership, never for ordering)
        keys = (os << 32) | (od & 0xFFFFFFFF)
        if delta.n_dels:
            dk = (delta.del_src.astype(np.int64) << 32) | \
                 (delta.del_dst.astype(np.int64) & 0xFFFFFFFF)
            keep = ~np.isin(keys, dk)
        else:
            keep = np.ones(keys.shape, bool)

        valid = np.asarray(self.node_ids[: self.n_nodes]) \
            if self.n_nodes else np.empty((0,), np.int32)
        new_ids = np.union1d(valid, np.concatenate([delta.add_src,
                                                    delta.add_dst]))
        # orig -> dense is monotone, so the kept out-order list stays sorted
        ks = np.searchsorted(new_ids, os[keep].astype(np.int32)).astype(np.int64)
        kd = np.searchsorted(new_ids, od[keep].astype(np.int32)).astype(np.int64)
        kept_keys = (ks << 32) | kd
        ai = np.searchsorted(new_ids, delta.add_src).astype(np.int64)
        aj = np.searchsorted(new_ids, delta.add_dst).astype(np.int64)
        akeys = np.unique((ai << 32) | aj)
        akeys = akeys[~_in_sorted(kept_keys, akeys)]
        all_s = np.concatenate([ks, akeys >> 32]).astype(np.int32)
        all_d = np.concatenate([kd, akeys & 0xFFFFFFFF]).astype(np.int32)
        return Graph.from_dense_edges(
            jnp.asarray(all_s), jnp.asarray(all_d), int(new_ids.size),
            node_ids=jnp.asarray(new_ids.astype(np.int32)))

    @track("graph.to_undirected", "Graph.to_undirected")
    def to_undirected(self) -> "Graph":
        """Symmetrized simple graph (for triangles / k-core / WCC)."""
        s, d = self.out_edges()
        os, od = self.original_of(s), self.original_of(d)
        src = jnp.concatenate([os, od])
        dst = jnp.concatenate([od, os])
        return Graph.from_edges(src, dst, dedupe=True, drop_self_loops=True)

    def nbytes(self) -> int:
        total = 0
        for a in (self.node_ids, self.out_ptr, self.out_idx, self.in_ptr, self.in_idx):
            total += a.size * a.dtype.itemsize
        return int(total)

    def plan_nbytes(self) -> int:
        """Derived bytes held by this graph's plan (0 when the plan is cold)."""
        return 0 if self._plan is None else int(self._plan.nbytes())

    def lineage_depth(self) -> int:
        """Length of the ``apply_delta`` ancestry chain hanging off this graph."""
        depth, g = 0, self
        while g._delta is not None:
            depth += 1
            g = g._delta.parent
        return depth

    def prune_lineage(self, max_depth: int) -> int:
        """Cut the delta-ancestry chain ``max_depth`` links up; returns cuts.

        Every ``apply_delta`` child strongly references its parent graph (and,
        once its plan is built, the parent's plan) through ``_delta`` — a
        long-lived delta stream would otherwise pin every ancestor forever.
        Cutting clears the ancestor's ``_delta`` and its plan's
        ``_parent``/``_info`` back-references, releasing everything deeper.
        The cut ancestor (and anything that still reaches it) simply loses
        delta-aware retention/warm-starts for *future* deltas and falls back
        to cold recomputation — results are unaffected.
        """
        depth, g = 0, self
        while g._delta is not None and depth < max_depth:
            depth += 1
            g = g._delta.parent
        cuts = 0
        if g._delta is not None:
            g._delta = None
            cuts += 1
        if g._plan is not None and getattr(g._plan, "_parent", None) is not None:
            g._plan._parent = None
            g._plan._info = None
            cuts += 1
        return cuts

    def __repr__(self) -> str:  # pragma: no cover
        return f"Graph({self.n_nodes} nodes, {self.n_edges} edges)"


# ---------------------------------------------------------------------------
# internals — the sort-first building blocks
# ---------------------------------------------------------------------------


def _dense_lookup(valid: np.ndarray, q: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """(dense position, present?) of original ids in the sorted id table."""
    q = np.asarray(q)
    if valid.size == 0 or q.size == 0:
        return np.zeros(q.shape, np.int64), np.zeros(q.shape, bool)
    pos = np.minimum(np.searchsorted(valid, q), valid.size - 1)
    return pos.astype(np.int64), valid[pos] == q


def _in_sorted(haystack: np.ndarray, needles: np.ndarray) -> np.ndarray:
    """Membership of needles in an ascending (possibly duplicated) array."""
    if haystack.size == 0 or needles.size == 0:
        return np.zeros(needles.shape, bool)
    pos = np.minimum(np.searchsorted(haystack, needles), haystack.size - 1)
    return haystack[pos] == needles


def _host_ptr(rows: np.ndarray, node_cap: int) -> np.ndarray:
    """CSR row pointers from sorted row ids — host-side counts + cumsum."""
    counts = np.bincount(rows, minlength=node_cap)
    ptr = np.zeros((node_cap + 1,), np.int64)
    np.cumsum(counts, out=ptr[1:])
    return ptr.astype(np.int32)


def _pad_ids(ids: jax.Array, cap: int) -> jax.Array:
    n = int(ids.shape[0])
    if n == cap:
        return ids.astype(jnp.int32)
    pad = jnp.full((cap - n,), INVALID_ID, dtype=jnp.int32)
    return jnp.concatenate([ids.astype(jnp.int32), pad])


def _csr_from_pairs(row: jax.Array, col: jax.Array, n_nodes: int,
                    node_cap: int, edge_cap: int) -> Tuple[jax.Array, jax.Array]:
    """Sort-first CSR: lexsort (row, col) -> counts -> ptr; no hash inserts."""
    e = int(row.shape[0])
    perm = jnp.lexsort((col, row))  # row primary, col secondary => sorted adjacency
    col_sorted = col[perm]
    counts = jnp.bincount(row, length=node_cap)  # "compute counts explicitly"
    ptr = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])
    idx = jnp.full((edge_cap,), jnp.int32(0))
    idx = idx.at[:e].set(col_sorted.astype(jnp.int32)) if e > 0 else idx
    return ptr.astype(jnp.int32), idx


def _row_of_edge(ptr: jax.Array, edge_cap: int) -> jax.Array:
    """Row id of each CSR slot: searchsorted(ptr, e, 'right')-1, vectorized."""
    e_idx = jnp.arange(edge_cap, dtype=jnp.int32)
    return (jnp.searchsorted(ptr, e_idx, side="right") - 1).astype(jnp.int32)


def _dedupe_pairs(src: jax.Array, dst: jax.Array, n_nodes: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Remove duplicate (src, dst) pairs — lexsorted-unique, eager size.

    Pure 32-bit: no combined key is formed, the pair is compared
    componentwise after a lexsort (collision-free at any scale).
    """
    if int(src.shape[0]) == 0:
        return src, dst
    order_ = jnp.lexsort((dst, src))
    ss, ds = src[order_], dst[order_]
    firsts = jnp.concatenate(
        [jnp.ones((1,), bool), (ss[1:] != ss[:-1]) | (ds[1:] != ds[:-1])])
    n_uniq = int(jnp.sum(firsts))
    pos = jnp.nonzero(firsts, size=max(n_uniq, 1), fill_value=0)[0]
    sel = order_[pos][:n_uniq]
    return src[sel], dst[sel]
