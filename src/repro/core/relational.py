"""Relational operations on Ringo tables (paper §2.3, Table 4).

Ringo provides select, join, project, group & aggregate, set operations and
order, plus two graph-construction ops unique to Ringo: **SimJoin** (join two
records if their distance is below a threshold) and **NextK** (join
predecessor-successor records, e.g. temporally ordered events).

TPU adaptation: every op is a *sort + searchsorted + segmented-scan*
composition — the contention-free, vectorizable duals of Ringo's hash-based
OpenMP loops (see DESIGN.md §2).  Output sizes are data-dependent, so the ops
run eagerly (like Ringo's interactive Python front end) with jitted inner
kernels; outputs are padded to power-of-two capacities.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .provenance import track
from .table import FLOAT, INT, STR, Schema, Table, next_capacity

__all__ = [
    "select",
    "select_inplace",
    "join",
    "order",
    "group_by",
    "project",
    "union",
    "intersect",
    "difference",
    "sim_join",
    "next_k",
    "unique",
]

# ---------------------------------------------------------------------------
# Predicates / select
# ---------------------------------------------------------------------------

_CMPS: Dict[str, Callable[[jax.Array, jax.Array], jax.Array]] = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


def _predicate_mask(t: Table, col: str, op: str, value) -> jax.Array:
    typ = t.schema.type_of(col)
    if typ == STR:
        if op not in ("==", "!="):
            raise ValueError("string columns support ==/!= only")
        try:
            code = t.dicts[col].index(value)
        except ValueError:
            code = -1  # not present: == matches nothing, != matches all
        value = code
    arr = t.column(col)
    return _CMPS[op](arr, jnp.asarray(value, dtype=arr.dtype))


@track("relational.select", "R.select")
def select(t: Table, col: str, op: str, value) -> Table:
    """New table with rows where ``col <op> value`` (paper's Select)."""
    mask = _predicate_mask(t, col, op, value)
    return t.compacted(mask)


@track("relational.select_inplace", "R.select_inplace")
def select_inplace(t: Table, col: str, op: str, value) -> Table:
    """Paper Table 4 benchmarks "select, in place": same storage, compacted.

    Functionally identical to :func:`select` under JAX's immutable arrays;
    the distinction Ringo draws (no new table object) maps to reusing the
    same capacity bucket, which :meth:`Table.compacted` already does.
    """
    return select(t, col, op, value)


@track("relational.project", "R.project")
def project(t: Table, cols: Sequence[str]) -> Table:
    schema = t.schema.project(cols)
    columns = {c: t.columns[c] for c in cols}
    dicts = {c: t.dicts[c] for c in cols if c in t.dicts}
    return Table(schema=schema, columns=columns, row_ids=t.row_ids,
                 n_valid=t.n_valid, dicts=dicts, next_row_id=t.next_row_id)


# ---------------------------------------------------------------------------
# Order (sort)
# ---------------------------------------------------------------------------


def _sort_key(t: Table, col: str) -> jax.Array:
    """Sortable key for a column; STR codes map to lexicographic ranks."""
    arr = t.column(col)
    if t.schema.type_of(col) == STR:
        uniq = t.dicts[col]
        rank_of = np.empty(max(len(uniq), 1), dtype=np.int32)
        for rank, idx in enumerate(sorted(range(len(uniq)),
                                          key=lambda i: uniq[i])):
            rank_of[idx] = rank
        arr = jnp.asarray(rank_of)[arr] if t.n_valid > 0 else arr
    return arr


@track("relational.order", "R.order")
def order(t: Table, cols: Sequence[str], ascending: bool = True) -> Table:
    """Sort rows lexicographically by ``cols`` (paper's Order)."""
    keys = [_sort_key(t, c) for c in reversed(cols)]  # lexsort: last primary
    perm = jnp.lexsort(tuple(keys))
    if not ascending:
        perm = perm[::-1]
    return t.gathered(perm, t.n_valid)


# ---------------------------------------------------------------------------
# Join (sort-merge, contention free)
# ---------------------------------------------------------------------------


def _align_str_keys(lt: Table, lcol: str, rt: Table, rcol: str) -> Tuple[jax.Array, jax.Array]:
    """Map both STR key columns into the left dictionary's code space."""
    ldict = lt.dicts[lcol]
    index = {s: i for i, s in enumerate(ldict)}
    remap = np.asarray([index.get(s, -1) for s in rt.dicts[rcol]], dtype=np.int32)
    lk = lt.column(lcol)
    rcodes = rt.column(rcol)
    rk = jnp.where(rcodes >= 0, jnp.asarray(remap)[rcodes], -1)
    return lk, rk


def _join_keys(lt: Table, lcol: str, rt: Table, rcol: str) -> Tuple[jax.Array, jax.Array]:
    ltyp, rtyp = lt.schema.type_of(lcol), rt.schema.type_of(rcol)
    if (ltyp == STR) != (rtyp == STR):
        raise TypeError("cannot join string column with non-string column")
    if ltyp == STR:
        return _align_str_keys(lt, lcol, rt, rcol)
    return lt.column(lcol), rt.column(rcol)


@jax.jit
def _join_counts(lk: jax.Array, rk_sorted: jax.Array):
    lo = jnp.searchsorted(rk_sorted, lk, side="left")
    hi = jnp.searchsorted(rk_sorted, lk, side="right")
    cnt = (hi - lo).astype(jnp.int32)
    return lo, cnt


@functools.partial(jax.jit, static_argnums=(3,))
def _expand_matches(lo: jax.Array, cnt: jax.Array, r_perm: jax.Array, out_cap: int):
    """Expand per-left-row match ranges into (left_idx, right_idx) pairs.

    Output row j belongs to left row i = searchsorted(offsets, j, 'right')-1
    with rank k = j - offsets[i]; its right index is r_perm[lo[i] + k].
    """
    offsets = jnp.cumsum(cnt)  # exclusive end per left row
    starts = offsets - cnt
    j = jnp.arange(out_cap, dtype=jnp.int32)
    total = offsets[-1] if offsets.shape[0] > 0 else jnp.int32(0)
    li = jnp.searchsorted(offsets, j, side="right").astype(jnp.int32)
    li = jnp.minimum(li, lo.shape[0] - 1)
    k = j - starts[li]
    ri_sorted_pos = lo[li] + k
    ri_sorted_pos = jnp.clip(ri_sorted_pos, 0, r_perm.shape[0] - 1)
    ri = r_perm[ri_sorted_pos]
    valid = j < total
    return jnp.where(valid, li, 0), jnp.where(valid, ri, 0)


@track("relational.join", "R.join")
def join(lt: Table, rt: Table, lcol: str, rcol: str,
         suffixes: Tuple[str, str] = ("_1", "_2")) -> Table:
    """Equi-join (paper's Join): sort-merge, parallel and contention-free.

    Column names colliding between the two inputs get ``suffixes``.
    Output row-ids are fresh (it is a new table, per the paper: "Ringo join
    operation always produces a new table object").
    """
    lk, rk = _join_keys(lt, lcol, rt, rcol)
    if lt.n_valid == 0 or rt.n_valid == 0:
        total, out_cap = 0, next_capacity(0)
        li = jnp.zeros((out_cap,), jnp.int32)
        ri = jnp.zeros((out_cap,), jnp.int32)
    else:
        r_perm = jnp.argsort(rk, stable=True).astype(jnp.int32)
        rk_sorted = rk[r_perm]
        lo, cnt = _join_counts(lk, rk_sorted)
        total = int(jnp.sum(cnt))
        out_cap = next_capacity(total)
        li, ri = _expand_matches(lo, cnt, r_perm, out_cap)

    # assemble output columns
    fields: List[Tuple[str, str]] = []
    columns: Dict[str, jax.Array] = {}
    dicts: Dict[str, List[str]] = {}

    def _emit(src: Table, idx: jax.Array, suffix: str, other: Table):
        for name, typ in src.schema.fields:
            out_name = name + suffix if name in other.schema else name
            fields.append((out_name, typ))
            # match indices only ever point into the valid prefix
            columns[out_name] = jnp.take(src.columns[name], idx, axis=0)
            if typ == STR:
                dicts[out_name] = list(src.dicts[name])

    _emit(lt, li, suffixes[0], rt)
    _emit(rt, ri, suffixes[1], lt)

    schema = Schema(tuple(fields))
    row_ids = jnp.where(jnp.arange(out_cap) < total,
                        jnp.arange(out_cap, dtype=jnp.int32), -1)
    return Table(schema=schema, columns=columns, row_ids=row_ids,
                 n_valid=total, dicts=dicts, next_row_id=total)


# ---------------------------------------------------------------------------
# Group & aggregate
# ---------------------------------------------------------------------------

_AGGS = ("sum", "min", "max", "count", "mean", "first")


@track("relational.group_by", "R.group_by")
def group_by(t: Table, key: str, aggs: Dict[str, Tuple[str, str]]) -> Table:
    """Group rows by ``key``; ``aggs`` maps out_col -> (in_col, agg).

    agg ∈ {sum, min, max, count, mean, first}.  Sort-based: sorting the key
    column turns grouping into segmented scans (no concurrent hash table —
    the TPU dual of Ringo's parallel group-by).
    """
    n = t.n_valid
    k = t.column(key)
    perm = jnp.argsort(k, stable=True)
    ks = k[perm]
    # segment starts where the sorted key changes
    is_start = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]]) if n > 0 \
        else jnp.zeros((0,), bool)
    seg_id = jnp.cumsum(is_start) - 1 if n > 0 else jnp.zeros((0,), jnp.int32)
    n_groups = int(seg_id[-1]) + 1 if n > 0 else 0
    cap = next_capacity(max(n_groups, 1))

    out_cols: Dict[str, jax.Array] = {}
    fields: List[Tuple[str, str]] = [(key, t.schema.type_of(key))]
    starts = jnp.nonzero(is_start, size=cap, fill_value=0)[0] if n > 0 \
        else jnp.zeros((cap,), jnp.int32)
    out_cols[key] = ks[starts] if n > 0 else jnp.zeros((cap,), k.dtype)

    for out_name, (in_col, agg) in aggs.items():
        if agg not in _AGGS:
            raise ValueError(f"unknown aggregate {agg}")
        typ = t.schema.type_of(in_col)
        v = t.column(in_col)[perm] if n > 0 else t.column(in_col)
        if agg == "count":
            vals = jax.ops.segment_sum(jnp.ones_like(v, dtype=jnp.int32), seg_id,
                                       num_segments=cap)
            fields.append((out_name, INT))
        elif agg == "sum":
            vals = jax.ops.segment_sum(v, seg_id, num_segments=cap)
            fields.append((out_name, typ))
        elif agg == "min":
            vals = jax.ops.segment_min(v, seg_id, num_segments=cap)
            fields.append((out_name, typ))
        elif agg == "max":
            vals = jax.ops.segment_max(v, seg_id, num_segments=cap)
            fields.append((out_name, typ))
        elif agg == "mean":
            s = jax.ops.segment_sum(v.astype(jnp.float32), seg_id, num_segments=cap)
            c = jax.ops.segment_sum(jnp.ones_like(v, jnp.float32), seg_id,
                                    num_segments=cap)
            vals = s / jnp.maximum(c, 1.0)
            fields.append((out_name, FLOAT))
        elif agg == "first":
            vals = v[starts] if n > 0 else jnp.zeros((cap,), v.dtype)
            fields.append((out_name, typ))
        out_cols[out_name] = vals

    schema = Schema(tuple(fields))
    row_ids = jnp.where(jnp.arange(cap) < n_groups,
                        jnp.arange(cap, dtype=jnp.int32), -1)
    dicts = {key: list(t.dicts[key])} if key in t.dicts else {}
    return Table(schema=schema, columns=out_cols, row_ids=row_ids,
                 n_valid=n_groups, dicts=dicts, next_row_id=n_groups)


@track("relational.unique", "R.unique")
def unique(t: Table, col: str) -> Table:
    """Distinct values of one column (sorted)."""
    return group_by(t, col, {})


# ---------------------------------------------------------------------------
# Set operations (on a key column)
# ---------------------------------------------------------------------------


def _set_op(lt: Table, rt: Table, col: str, mode: str) -> Table:
    lk, rk = _join_keys(lt, col, rt, col)
    rk_sorted = jnp.sort(rk)
    lo = jnp.searchsorted(rk_sorted, lk, side="left")
    hi = jnp.searchsorted(rk_sorted, lk, side="right")
    in_right = hi > lo
    if mode == "intersect":
        return lt.compacted(in_right)
    if mode == "difference":
        return lt.compacted(~in_right)
    raise ValueError(mode)


@track("relational.intersect", "R.intersect")
def intersect(lt: Table, rt: Table, col: str) -> Table:
    """Rows of ``lt`` whose key appears in ``rt`` (semi-join)."""
    return _set_op(lt, rt, col, "intersect")


@track("relational.difference", "R.difference")
def difference(lt: Table, rt: Table, col: str) -> Table:
    """Rows of ``lt`` whose key does NOT appear in ``rt`` (anti-join)."""
    return _set_op(lt, rt, col, "difference")


@track("relational.union", "R.union")
def union(lt: Table, rt: Table) -> Table:
    """Row union (concatenate; schemas must match by name/type)."""
    if lt.schema.names != rt.schema.names:
        raise ValueError("union requires identical schemas")
    n = lt.n_valid + rt.n_valid
    cap = next_capacity(n)
    cols: Dict[str, jax.Array] = {}
    dicts: Dict[str, List[str]] = {}
    for name, typ in lt.schema.fields:
        lv = lt.column(name)
        rv = rt.column(name)
        if typ == STR:
            # re-encode right codes into (extended) left dictionary
            merged = list(lt.dicts[name])
            index = {s: i for i, s in enumerate(merged)}
            remap = []
            for s in rt.dicts[name]:
                if s not in index:
                    index[s] = len(merged)
                    merged.append(s)
                remap.append(index[s])
            remap_a = jnp.asarray(np.asarray(remap, dtype=np.int32)) \
                if remap else jnp.zeros((1,), jnp.int32)
            rv = remap_a[rv] if rt.n_valid > 0 else rv
            dicts[name] = merged
        both = jnp.concatenate([lv, rv])
        pad = jnp.zeros((cap - n,), both.dtype)
        cols[name] = jnp.concatenate([both, pad])
    row_ids = jnp.where(jnp.arange(cap) < n, jnp.arange(cap, dtype=jnp.int32), -1)
    return Table(schema=lt.schema, columns=cols, row_ids=row_ids, n_valid=n,
                 dicts=dicts, next_row_id=n)


# ---------------------------------------------------------------------------
# SimJoin — join records whose distance is below a threshold (paper §2.3)
# ---------------------------------------------------------------------------


@track("relational.sim_join", "R.sim_join")
def sim_join(lt: Table, rt: Table, lcol: str, rcol: str, threshold: float,
             suffixes: Tuple[str, str] = ("_1", "_2")) -> Table:
    """Join rows with |l - r| <= threshold on numeric columns.

    Sort-based band join: sort the right column; each left value matches the
    contiguous sorted range [value-thr, value+thr] found by two searchsorteds.
    Same expansion machinery as the equi-join, so it parallelizes identically.
    """
    lk = lt.column(lcol).astype(jnp.float32)
    rk = rt.column(rcol).astype(jnp.float32)
    r_perm = jnp.argsort(rk, stable=True).astype(jnp.int32)
    rk_sorted = rk[r_perm]
    lo = jnp.searchsorted(rk_sorted, lk - threshold, side="left")
    hi = jnp.searchsorted(rk_sorted, lk + threshold, side="right")
    cnt = (hi - lo).astype(jnp.int32)
    total = int(jnp.sum(cnt))
    out_cap = next_capacity(total)
    li, ri = _expand_matches(lo.astype(jnp.int32), cnt, r_perm, out_cap)
    return _assemble_pair_table(lt, rt, li, ri, total, out_cap, suffixes)


# ---------------------------------------------------------------------------
# NextK — predecessor/successor join (paper §2.3)
# ---------------------------------------------------------------------------


@track("relational.next_k", "R.next_k")
def next_k(t: Table, key: str, time_col: str, k: int,
           suffixes: Tuple[str, str] = ("_1", "_2")) -> Table:
    """Join each record with its next ``k`` successors within the same key.

    E.g. consecutive events of the same user: edges (event_i -> event_{i+j})
    for j in 1..k.  Sort by (key, time); successor ranks are then index
    arithmetic — the sort-first trick again.
    """
    n = t.n_valid
    sorted_t = order(t, [key, time_col])
    kcol = sorted_t.column(key)
    base = jnp.arange(n, dtype=jnp.int32)
    lis, ris = [], []
    for j in range(1, k + 1):
        succ = base + j
        ok = succ < n
        same = jnp.where(ok, kcol[jnp.minimum(succ, n - 1)] == kcol, False)
        lis.append(jnp.where(same, base, -1))
        ris.append(jnp.where(same, succ, -1))
    li_all = jnp.concatenate(lis) if lis else jnp.zeros((1,), jnp.int32)
    ri_all = jnp.concatenate(ris) if ris else jnp.zeros((1,), jnp.int32)
    mask = li_all >= 0
    total = int(jnp.sum(mask))
    out_cap = next_capacity(total)
    # compact valid pairs to the front; pad the permutation out to capacity
    perm = jnp.argsort(~mask, stable=True)
    take = min(out_cap, int(perm.shape[0]))
    perm = jnp.concatenate([perm[:take],
                            jnp.zeros((out_cap - take,), perm.dtype)])
    valid = jnp.arange(out_cap) < total
    li = jnp.where(valid, jnp.maximum(li_all[perm], 0), 0)
    ri = jnp.where(valid, jnp.maximum(ri_all[perm], 0), 0)
    return _assemble_pair_table(sorted_t, sorted_t, li, ri, total, out_cap, suffixes)


# ---------------------------------------------------------------------------
# shared output assembly
# ---------------------------------------------------------------------------


def _assemble_pair_table(lt: Table, rt: Table, li: jax.Array, ri: jax.Array,
                         total: int, out_cap: int,
                         suffixes: Tuple[str, str]) -> Table:
    fields: List[Tuple[str, str]] = []
    columns: Dict[str, jax.Array] = {}
    dicts: Dict[str, List[str]] = {}

    def _emit(src: Table, idx: jax.Array, suffix: str, other: Table, always_suffix: bool):
        for name, typ in src.schema.fields:
            clash = name in other.schema
            out_name = name + suffix if (clash or always_suffix) else name
            fields.append((out_name, typ))
            columns[out_name] = jnp.take(src.columns[name], idx, axis=0)
            if typ == STR:
                dicts[out_name] = list(src.dicts[name])

    same = lt is rt
    _emit(lt, li, suffixes[0], rt, always_suffix=same)
    _emit(rt, ri, suffixes[1], lt, always_suffix=same)
    schema = Schema(tuple(fields))
    row_ids = jnp.where(jnp.arange(out_cap) < total,
                        jnp.arange(out_cap, dtype=jnp.int32), -1)
    return Table(schema=schema, columns=columns, row_ids=row_ids,
                 n_valid=total, dicts=dicts, next_row_id=total)
