"""Table ↔ graph conversions (Ringo §2.4, Table 5).

``to_graph`` implements the paper's **sort-first** algorithm: copy the source
and destination columns, sort them (parallel, contention-free), compute the
number of neighbors for each node explicitly, then bulk-copy the adjacency
vectors.  On TPU this is `lexsort + bincount + cumsum + gather` — all native,
no thread-safe hash inserts, no size estimation (DESIGN.md §2).

``graph_to_edge_table`` / ``graph_to_node_table`` mirror the reverse
conversion: partition edges/nodes, pre-allocate the output, bulk-write.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .graph import Graph
from .provenance import track
from .table import INT, FLOAT, Schema, Table, next_capacity

__all__ = [
    "to_graph",
    "graph_to_edge_table",
    "graph_to_node_table",
    "table_from_map",
]


@track("convert.to_graph", "C.to_graph")
def to_graph(t: Table, src_col: str, dst_col: str, dedupe: bool = True,
             drop_self_loops: bool = False) -> Graph:
    """Paper's ``ToGraph(T, S, D)``: nodes = unique values of S ∪ D, one edge
    per row.  STR key columns are joined through their dictionaries first."""
    styp, dtyp = t.schema.type_of(src_col), t.schema.type_of(dst_col)
    if (styp == "str") != (dtyp == "str"):
        raise TypeError("src/dst columns must both be ids or both strings")
    if styp == "str":
        # unify the two dictionaries into one id space
        sdict, ddict = t.dicts[src_col], t.dicts[dst_col]
        index = {s: i for i, s in enumerate(sdict)}
        remap = []
        merged = list(sdict)
        for s in ddict:
            if s not in index:
                index[s] = len(merged)
                merged.append(s)
            remap.append(index[s])
        remap_a = jnp.asarray(remap, dtype=jnp.int32) if remap else jnp.zeros((1,), jnp.int32)
        src = t.column(src_col)
        dst = remap_a[t.column(dst_col)] if t.n_valid > 0 else t.column(dst_col)
    else:
        src = t.column(src_col)
        dst = t.column(dst_col)
    return Graph.from_edges(src, dst, dedupe=dedupe, drop_self_loops=drop_self_loops)


@track("convert.graph_to_edge_table", "C.graph_to_edge_table")
def graph_to_edge_table(g: Graph, src_name: str = "src", dst_name: str = "dst") -> Table:
    """Edge table with original node ids (paper: graph→table at ~50 M edges/s)."""
    s, d = g.out_edges()
    return Table.from_columns(
        Schema.of([(src_name, INT), (dst_name, INT)]),
        {src_name: g.original_of(s), dst_name: g.original_of(d)},
    )


@track("convert.graph_to_node_table", "C.graph_to_node_table")
def graph_to_node_table(g: Graph, values: Optional[Dict[str, jax.Array]] = None,
                        id_name: str = "node") -> Table:
    """Node table: original ids plus optional per-node value columns
    (e.g. PageRank scores) indexed by dense id."""
    fields = [(id_name, INT)]
    data: Dict[str, jax.Array] = {id_name: g.node_ids[: g.n_nodes]}
    for name, v in (values or {}).items():
        typ = FLOAT if jnp.issubdtype(v.dtype, jnp.floating) else INT
        fields.append((name, typ))
        data[name] = v[: g.n_nodes]
    return Table.from_columns(Schema.of(fields), data)


@track("convert.table_from_map", "C.table_from_map")
def table_from_map(g: Graph, scores: jax.Array, key_name: str = "node",
                   value_name: str = "score") -> Table:
    """Paper's ``TableFromHashMap(PR, 'User', 'Scr')`` analogue: per-node
    result map -> two-column table, sorted by score descending."""
    t = graph_to_node_table(g, {value_name: scores}, id_name=key_name)
    order_ = jnp.argsort(-t.column(value_name), stable=True)
    return t.gathered(order_, t.n_valid)
