"""Model assembly: init / forward / loss / prefill / decode for all families.

Families (DESIGN.md §4):
  dense   — pre-norm decoder (GQA + RoPE + [SwiGLU|GeLU])      qwen*, starcoder2, mistral
  moe     — dense layer with MoE FFN                           grok-1, qwen3-moe
  hybrid  — periods of (attn_every-1) Mamba + 1 attention,
            MoE FFN every ``moe_every``-th layer               jamba
  ssm     — xLSTM block pattern (mLSTM/sLSTM cycle, no FFN)    xlstm
  audio   — whisper enc-dec: bidirectional encoder over stub
            frame embeddings + causal decoder w/ cross-attn
  vlm     — decoder over [patch-embedding prefix | tokens]     internvl2

Layers are **scanned** (stacked params, `lax.scan` over the layer/period
axis) so the HLO stays one-layer-sized regardless of depth — essential for
94-layer dry-run compiles — with `jax.checkpoint` applied to the scan body
per ``cfg.remat``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..launch.sharding import shard
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (dtype_of, embed_init, embed_apply, mlp_apply, mlp_init,
                     norm_apply, norm_init, unembed_apply)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _dense_layer_init(cfg, dtype):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   dtype, cfg.qkv_bias),
            "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
        }
        if cfg.n_experts > 0:
            p["moe"] = moe_mod.moe_init(k2, cfg.d_model, cfg.d_ff,
                                        cfg.n_experts, cfg.act, dtype)
        else:
            p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype)
        return p

    return init


def _hybrid_period_init(cfg, dtype):
    """One jamba period: (attn_every-1) mamba mixers + 1 attention,
    FFN per sub-layer: MoE on odd global indices, dense MLP on even."""
    n_mamba = cfg.attn_every - 1
    n_moe = sum(1 for i in range(cfg.attn_every) if i % cfg.moe_every == 1)
    n_mlp = cfg.attn_every - n_moe

    def init(key):
        ks = jax.random.split(key, 6)
        return {
            "mamba": _stacked(lambda k: ssm_mod.mamba_init(k, cfg.d_model, cfg,
                                                           dtype),
                              ks[0], n_mamba),
            "mix_ln": _stacked(lambda k: norm_init(cfg.d_model, cfg.norm,
                                                   dtype), ks[1], cfg.attn_every),
            "attn": attn.attn_init(ks[2], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   dtype, cfg.qkv_bias),
            "ffn_ln": _stacked(lambda k: norm_init(cfg.d_model, cfg.norm,
                                                   dtype), ks[3], cfg.attn_every),
            "moe": _stacked(lambda k: moe_mod.moe_init(k, cfg.d_model, cfg.d_ff,
                                                       cfg.n_experts, cfg.act,
                                                       dtype), ks[4], n_moe),
            "mlp": _stacked(lambda k: mlp_init(k, cfg.d_model, cfg.d_ff,
                                               cfg.act, dtype), ks[5], n_mlp),
        }

    return init


def _xlstm_period_init(cfg, dtype):
    pattern = cfg.block_pattern

    def init(key):
        ks = jax.random.split(key, len(pattern) + 1)
        p = {"ln": _stacked(lambda k: norm_init(cfg.d_model, cfg.norm, dtype),
                            ks[-1], len(pattern))}
        for i, kind in enumerate(pattern):
            if kind == "mlstm":
                p[f"b{i}_mlstm"] = xlstm_mod.mlstm_init(ks[i], cfg.d_model,
                                                        cfg, dtype)
            else:
                p[f"b{i}_slstm"] = xlstm_mod.slstm_init(ks[i], cfg.d_model,
                                                        cfg, dtype)
        return p

    return init


def _enc_layer_init(cfg, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   dtype, cfg.qkv_bias),
            "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
        }

    return init


def _xdec_layer_init(cfg, dtype):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   dtype, cfg.qkv_bias),
            "ln_x": norm_init(cfg.d_model, cfg.norm, dtype),
            "xattn": attn.attn_init(k2, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.resolved_head_dim,
                                    dtype, cfg.qkv_bias),
            "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype),
        }

    return init


def n_scan_steps(cfg) -> int:
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "ssm":
        assert cfg.n_layers % len(cfg.block_pattern) == 0
        return cfg.n_layers // len(cfg.block_pattern)
    return cfg.n_layers


def init_params(cfg, key) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 6)
    params: Params = {
        "embed": {"tok": embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                    dtype)},
        "norm_f": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"table": embed_init(keys[1], cfg.vocab_size,
                                                 cfg.d_model, dtype)["table"]}
    if cfg.family == "hybrid":
        layer_init = _hybrid_period_init(cfg, dtype)
    elif cfg.family == "ssm":
        layer_init = _xlstm_period_init(cfg, dtype)
    elif cfg.is_encoder_decoder:
        layer_init = _xdec_layer_init(cfg, dtype)
    else:
        layer_init = _dense_layer_init(cfg, dtype)
    params["layers"] = _stacked(layer_init, keys[2], n_scan_steps(cfg))
    if cfg.is_encoder_decoder:
        params["enc"] = {
            "layers": _stacked(_enc_layer_init(cfg, dtype), keys[3],
                               cfg.n_enc_layers),
            "norm_f": norm_init(cfg.d_model, cfg.norm, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# forward bodies (shared by train & prefill)
# ---------------------------------------------------------------------------


def _maybe_ckpt(body, cfg):
    if cfg.remat == "none":
        return body
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(body, policy=policy)
    return jax.checkpoint(body)


def _dense_body(cfg, enc_out=None, chunk: int = 1024,
                skip_upper_triangle: bool = True):
    def body(carry, lp):
        x, aux = carry
        h = norm_apply(lp["ln1"], x, cfg.norm)
        a = attn.attention_train(lp["attn"], h, cfg, causal=True, chunk=chunk,
                                 skip_upper_triangle=skip_upper_triangle)
        x = x + a
        if enc_out is not None:
            h = norm_apply(lp["ln_x"], x, cfg.norm)
            a = attn.attention_train(lp["xattn"], h, cfg,
                                     kv_override=(enc_out, enc_out),
                                     chunk=chunk)
            x = x + a
        h = norm_apply(lp["ln2"], x, cfg.norm)
        if "moe" in lp:
            f, aux_delta = moe_mod.moe_apply(lp["moe"], h, cfg)
            aux = aux + aux_delta
        else:
            f = mlp_apply(lp["mlp"], h, cfg.act, h.dtype, shard=shard)
        x = shard(x + f, ("batch", "seq", "embed"))
        return (x, aux), None

    return body


def _hybrid_body(cfg, chunk: int = 1024, skip_upper_triangle: bool = True):
    n_mamba = cfg.attn_every - 1

    def body(carry, lp):
        x, aux = carry
        mi, oi, di_ = 0, 0, 0
        for i in range(cfg.attn_every):
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["mix_ln"]), x,
                           cfg.norm)
            if i == n_mamba:       # the one attention layer per period
                a = attn.attention_train(lp["attn"], h, cfg, causal=True,
                                         chunk=chunk,
                                         skip_upper_triangle=skip_upper_triangle)
            else:
                a = ssm_mod.mamba_train(
                    jax.tree.map(lambda t: t[mi], lp["mamba"]), h, cfg)
                mi += 1
            x = x + a
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["ffn_ln"]), x,
                           cfg.norm)
            if i % cfg.moe_every == 1:
                f, aux_d = moe_mod.moe_apply(
                    jax.tree.map(lambda t: t[oi], lp["moe"]), h, cfg)
                aux = aux + aux_d
                oi += 1
            else:
                f = mlp_apply(jax.tree.map(lambda t: t[di_], lp["mlp"]), h,
                              cfg.act, h.dtype, shard=shard)
                di_ += 1
            x = shard(x + f, ("batch", "seq", "embed"))
        return (x, aux), None

    return body


def _xlstm_body(cfg):
    def body(carry, lp):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["ln"]), x, cfg.norm)
            if kind == "mlstm":
                y = xlstm_mod.mlstm_train(lp[f"b{i}_mlstm"], h, cfg)
            else:
                y = xlstm_mod.slstm_train(lp[f"b{i}_slstm"], h, cfg)
            x = shard(x + y, ("batch", "seq", "embed"))
        return (x, aux), None

    return body


def _encoder_forward(params, cfg, enc_embeds, chunk: int = 1024):
    """Bidirectional encoder over stub frame embeddings (B, Se, d)."""
    x = enc_embeds.astype(dtype_of(cfg.compute_dtype))

    def body(carry, lp):
        h, _ = carry
        a = attn.attention_train(lp["attn"], norm_apply(lp["ln1"], h, cfg.norm),
                                 cfg, causal=False, chunk=chunk,
                                 skip_upper_triangle=False)
        h = h + a
        f = mlp_apply(lp["mlp"], norm_apply(lp["ln2"], h, cfg.norm), cfg.act,
                      h.dtype, shard=shard)
        h = shard(h + f, ("batch", "frames", "embed"))
        return (h, jnp.float32(0)), None

    body = _maybe_ckpt(body, cfg)
    (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0)),
                             params["enc"]["layers"])
    return norm_apply(params["enc"]["norm_f"], x, cfg.norm)


def forward(params: Params, cfg, batch: Dict[str, jax.Array],
            chunk: int = 1024, skip_upper_triangle: bool = True
            ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits, aux_loss).

    batch keys: tokens (B,S); audio: enc_embeds (B,Se,d);
    vlm: patch_embeds (B,P,d) prepended to the token embeddings.
    """
    compute = dtype_of(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = embed_apply(params["embed"]["tok"], tokens, compute)
    if cfg.n_patches:
        patches = batch["patch_embeds"].astype(compute)
        x = jnp.concatenate([patches, x], axis=1)
    x = shard(x, ("batch", "seq", "embed"))

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(params, cfg, batch["enc_embeds"], chunk)

    if cfg.family == "hybrid":
        body = _hybrid_body(cfg, chunk, skip_upper_triangle)
    elif cfg.family == "ssm":
        body = _xlstm_body(cfg)
    else:
        body = _dense_body(cfg, enc_out=enc_out, chunk=chunk,
                           skip_upper_triangle=skip_upper_triangle)
    body = _maybe_ckpt(body, cfg)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), params["layers"])

    x = norm_apply(params["norm_f"], x, cfg.norm)
    head = params["embed"]["tok"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed_apply(head, x, compute)
    logits = shard(logits, ("batch", "seq", "vocab"))
    if cfg.n_patches:
        logits = logits[:, cfg.n_patches:]
    return logits, aux


def loss_fn(params: Params, cfg, batch: Dict[str, jax.Array],
            chunk: int = 1024, skip_upper_triangle: bool = True
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, batch, chunk, skip_upper_triangle)
    targets = batch["targets"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = ce + 0.01 * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, max_seq: int) -> Params:
    """Stacked (per scan step) decode state for the family."""
    cdtype = dtype_of(cfg.compute_dtype)
    n = n_scan_steps(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def rep(tree):
        return jax.tree.map(lambda t: jnp.broadcast_to(t, (n,) + t.shape), tree)

    if cfg.family == "hybrid":
        n_mamba = cfg.attn_every - 1
        per = {
            "attn": attn.init_kv_cache(batch_size, max_seq, hkv, hd, cdtype),
            "mamba": jax.tree.map(
                lambda t: jnp.broadcast_to(t, (n_mamba,) + t.shape),
                ssm_mod.mamba_init_cache(batch_size, cfg.d_model, cfg, cdtype)),
        }
        return rep(per)
    if cfg.family == "ssm":
        per = {}
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "mlstm":
                per[f"b{i}"] = xlstm_mod.mlstm_init_cache(batch_size,
                                                          cfg.d_model, cfg,
                                                          cdtype)
            else:
                per[f"b{i}"] = xlstm_mod.slstm_init_cache(batch_size,
                                                          cfg.d_model, cfg,
                                                          cdtype)
        return rep(per)
    return rep({"attn": attn.init_kv_cache(batch_size, max_seq, hkv, hd,
                                           cdtype)})


def _shard_cache(cache):
    def f(leaf):
        if leaf.ndim == 5:  # (n, B, S, hkv, hd) attention cache
            return shard(leaf, ("layers", "batch", "kv_seq", "kv_heads", None))
        return leaf

    return jax.tree.map(f, cache)


def prefill(params: Params, cfg, batch: Dict[str, jax.Array], max_seq: int,
            chunk: int = 1024) -> Tuple[jax.Array, Params]:
    """Process the full prompt, returning (last-token logits, filled cache).

    For attention families the cache is written with the prompt's K/V; for
    SSM/hybrid the recurrent states are advanced through the prompt.
    """
    compute = dtype_of(cfg.compute_dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_apply(params["embed"]["tok"], tokens, compute)
    if cfg.n_patches:
        x = jnp.concatenate([batch["patch_embeds"].astype(compute), x], axis=1)
    x = shard(x, ("batch", "seq", "embed"))
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(params, cfg, batch["enc_embeds"], chunk)

    cache = init_cache(cfg, b, max_seq)
    cache = _shard_cache(cache)

    if cfg.family in ("hybrid", "ssm"):
        # run the train-mode body but also recompute terminal states cheaply:
        # recurrent caches advance inside the body via a rerun of the mixers
        # on the last positions; for simplicity we reuse train bodies and
        # fill only attention caches (hybrid) / terminal states (ssm).
        body = _hybrid_prefill_body(cfg, chunk) if cfg.family == "hybrid" \
            else _xlstm_prefill_body(cfg)
    else:
        body = _dense_prefill_body(cfg, enc_out, chunk)

    (x, _), cache = jax.lax.scan(body, (x, jnp.float32(0)),
                                 (params["layers"], cache))
    x = norm_apply(params["norm_f"], x, cfg.norm)
    head = params["embed"]["tok"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed_apply(head, x[:, -1:], compute)
    return logits, cache


def _dense_prefill_body(cfg, enc_out, chunk):
    def body(carry, inp):
        lp, lcache = inp
        x, aux = carry
        h = norm_apply(lp["ln1"], x, cfg.norm)
        a, new_attn = attn.attention_prefill(lp["attn"], h, cfg,
                                             lcache["attn"], chunk=chunk)
        x = x + a
        if enc_out is not None:
            h = norm_apply(lp["ln_x"], x, cfg.norm)
            x = x + attn.attention_train(lp["xattn"], h, cfg,
                                         kv_override=(enc_out, enc_out),
                                         chunk=chunk)
        h = norm_apply(lp["ln2"], x, cfg.norm)
        if "moe" in lp:
            f, aux_d = moe_mod.moe_apply(lp["moe"], h, cfg)
            aux = aux + aux_d
        else:
            f = mlp_apply(lp["mlp"], h, cfg.act, h.dtype, shard=shard)
        x = shard(x + f, ("batch", "seq", "embed"))
        return (x, aux), {"attn": new_attn}

    return body


def _hybrid_prefill_body(cfg, chunk):
    n_mamba = cfg.attn_every - 1

    def body(carry, inp):
        lp, lcache = inp
        x, aux = carry
        mamba_states = []
        mi, oi, di_ = 0, 0, 0
        new_attn = lcache["attn"]
        for i in range(cfg.attn_every):
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["mix_ln"]), x,
                           cfg.norm)
            if i == n_mamba:
                a, new_attn = attn.attention_prefill(lp["attn"], h, cfg,
                                                     lcache["attn"],
                                                     chunk=chunk)
            else:
                mp = jax.tree.map(lambda t: t[mi], lp["mamba"])
                a = ssm_mod.mamba_train(mp, h, cfg)
                # terminal state for decode: advance a fresh cache over the
                # prompt via a single-step replay of the last token
                mamba_states.append(_mamba_terminal_state(mp, h, cfg))
                mi += 1
            x = x + a
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["ffn_ln"]), x,
                           cfg.norm)
            if i % cfg.moe_every == 1:
                f, aux_d = moe_mod.moe_apply(
                    jax.tree.map(lambda t: t[oi], lp["moe"]), h, cfg)
                aux += aux_d
                oi += 1
            else:
                f = mlp_apply(jax.tree.map(lambda t: t[di_], lp["mlp"]), h,
                              cfg.act, h.dtype, shard=shard)
                di_ += 1
            x = shard(x + f, ("batch", "seq", "embed"))
        mstack = jax.tree.map(lambda *xs: jnp.stack(xs), *mamba_states)
        return (x, aux), {"attn": new_attn, "mamba": mstack}

    return body


def _mamba_terminal_state(mp, h, cfg):
    """Terminal SSM state after the prompt (recomputed scan, states only)."""
    compute = h.dtype
    from .layers import dense
    u = dense(mp["in_proj"], h, compute)
    u = jax.nn.silu(ssm_mod._causal_conv(u, mp["conv_w"].astype(compute)))
    da, dbu, _ = ssm_mod._ssm_params(mp, u, compute)

    def combine(x1, x2):
        a1, b1 = x1
        a2, b2 = x2
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbu), axis=1)
    h_last = b_cum[:, -1]
    wdt = cfg.ssm_conv_width
    conv_tail = dense(mp["in_proj"], h[:, -(wdt - 1):], compute)
    return {"h": h_last, "conv": conv_tail}


def _xlstm_prefill_body(cfg):
    def body(carry, inp):
        lp, lcache = inp
        x, aux = carry
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["ln"]), x, cfg.norm)
            if kind == "mlstm":
                y, st = xlstm_mod.mlstm_train(lp[f"b{i}_mlstm"], h, cfg,
                                              return_state=True)
            else:
                y, st = xlstm_mod.slstm_train(lp[f"b{i}_slstm"], h, cfg,
                                              return_state=True)
            new_cache[f"b{i}"] = st
            x = shard(x + y, ("batch", "seq", "embed"))
        return (x, aux), new_cache

    return body


def decode_step(params: Params, cfg, cache: Params, tokens: jax.Array,
                pos: jax.Array,
                enc_out: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params]:
    """One decode step. tokens: (B, 1) -> (logits (B,1,V), new cache)."""
    compute = dtype_of(cfg.compute_dtype)
    x = embed_apply(params["embed"]["tok"], tokens, compute)
    x = shard(x, ("batch", None, "embed"))

    if cfg.family == "hybrid":
        body = _hybrid_decode_body(cfg, pos)
    elif cfg.family == "ssm":
        body = _xlstm_decode_body(cfg)
    else:
        body = _dense_decode_body(cfg, pos, enc_out)

    (x, _), new_cache = jax.lax.scan(body, (x, jnp.float32(0)),
                                     (params["layers"], cache))
    x = norm_apply(params["norm_f"], x, cfg.norm)
    head = params["embed"]["tok"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed_apply(head, x, compute)
    logits = shard(logits, ("batch", None, "vocab"))
    return logits, new_cache


def _dense_decode_body(cfg, pos, enc_out):
    def body(carry, inp):
        lp, lcache = inp
        x, aux = carry
        h = norm_apply(lp["ln1"], x, cfg.norm)
        a, new_attn = attn.attention_decode(lp["attn"], h, cfg, lcache["attn"],
                                            pos)
        x = x + a
        if enc_out is not None:
            h = norm_apply(lp["ln_x"], x, cfg.norm)
            a, _ = attn.attention_decode(lp["xattn"], h, cfg, lcache["attn"],
                                         pos, kv_override=(enc_out, enc_out))
            x = x + a
        h = norm_apply(lp["ln2"], x, cfg.norm)
        if "moe" in lp:
            f, aux_d = moe_mod.moe_apply(lp["moe"], h, cfg)
            aux += aux_d
        else:
            f = mlp_apply(lp["mlp"], h, cfg.act, h.dtype, shard=shard)
        return (x + f, aux), {"attn": new_attn}

    return body


def _hybrid_decode_body(cfg, pos):
    n_mamba = cfg.attn_every - 1

    def body(carry, inp):
        lp, lcache = inp
        x, aux = carry
        new_mamba = []
        new_attn = lcache["attn"]
        mi, oi, di_ = 0, 0, 0
        for i in range(cfg.attn_every):
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["mix_ln"]), x,
                           cfg.norm)
            if i == n_mamba:
                a, new_attn = attn.attention_decode(lp["attn"], h, cfg,
                                                    lcache["attn"], pos)
            else:
                mc = jax.tree.map(lambda t: t[mi], lcache["mamba"])
                a, ms = ssm_mod.mamba_decode(
                    jax.tree.map(lambda t: t[mi], lp["mamba"]), h, cfg, mc)
                new_mamba.append(ms)
                mi += 1
            x = x + a
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["ffn_ln"]), x,
                           cfg.norm)
            if i % cfg.moe_every == 1:
                f, aux_d = moe_mod.moe_apply(
                    jax.tree.map(lambda t: t[oi], lp["moe"]), h, cfg)
                aux += aux_d
                oi += 1
            else:
                f = mlp_apply(jax.tree.map(lambda t: t[di_], lp["mlp"]), h,
                              cfg.act, h.dtype, shard=shard)
                di_ += 1
            x = x + f
        mstack = jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba)
        return (x, aux), {"attn": new_attn, "mamba": mstack}

    return body


def _xlstm_decode_body(cfg):
    def body(carry, inp):
        lp, lcache = inp
        x, aux = carry
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            h = norm_apply(jax.tree.map(lambda t: t[i], lp["ln"]), x, cfg.norm)
            if kind == "mlstm":
                y, st = xlstm_mod.mlstm_decode(lp[f"b{i}_mlstm"], h, cfg,
                                               lcache[f"b{i}"])
            else:
                y, st = xlstm_mod.slstm_decode(lp[f"b{i}_slstm"], h, cfg,
                                               lcache[f"b{i}"])
            new_cache[f"b{i}"] = st
            x = x + y
        return (x, aux), new_cache

    return body
