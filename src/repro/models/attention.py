"""Attention: GQA + RoPE (+ optional QKV bias), flash-style chunking, decode.

Three execution modes:

* ``attention_train``   — full-sequence causal (or bidirectional) attention,
  computed **chunked** over query/key blocks with a running-softmax carry
  (flash attention in pure JAX).  Nothing of shape (S, S) is ever
  materialized, which is what makes the 32k-prefill cells feasible:
  peak extra memory is (B, H, q_chunk, k_chunk) per step.
* ``attention_decode``  — one query token against a static KV cache with a
  position mask (memory-bound by design; the roofline shows it).
* sequence-sharded decode for 500k contexts lives in serve/flash_decode.py.

The causal chunk loop supports **triangle skipping**: with causal=True only
the lower-triangular (qi >= ki) chunk pairs are computed — an HLO-visible
2× FLOP reduction on causal attention (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..launch.sharding import shard
from .layers import dense, dense_init

Params = Dict


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (S,) or broadcastable."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (S, D/2)
    cos = jnp.cos(angles)[..., :, None, :]                  # (S, 1, D/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              dtype, qkv_bias: bool = False) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, n_heads * head_dim, dtype, bias=qkv_bias),
        "wk": dense_init(kk, d_model, n_kv_heads * head_dim, dtype, bias=qkv_bias),
        "wv": dense_init(kv, d_model, n_kv_heads * head_dim, dtype, bias=qkv_bias),
        "wo": dense_init(ko, n_heads * head_dim, d_model, dtype),
    }


def _project_qkv(p: Params, x: jax.Array, n_heads: int, n_kv_heads: int,
                 head_dim: int, compute_dtype):
    b, s, _ = x.shape
    q = dense(p["wq"], x, compute_dtype).reshape(b, s, n_heads, head_dim)
    k = dense(p["wk"], x, compute_dtype).reshape(b, s, n_kv_heads, head_dim)
    v = dense(p["wv"], x, compute_dtype).reshape(b, s, n_kv_heads, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked (flash-style) core
# ---------------------------------------------------------------------------


def _fit_chunk(s: int, desired: int) -> int:
    """Largest chunk <= desired that divides s (whisper's 1536 frames etc.)."""
    c = min(desired, s)
    while s % c:
        c -= 1
    return max(c, 1)


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D) by head replication."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)
                            ).reshape(b, s, h * groups, d)


@functools.partial(jax.jit, static_argnames=("causal", "q_chunk", "k_chunk",
                                             "skip_upper_triangle"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, q_chunk: int = 1024,
                    k_chunk: int = 1024,
                    skip_upper_triangle: bool = True) -> jax.Array:
    """Memory-efficient attention. q,k,v: (B, S, H, D) with equal H.

    Scans over query chunks (outer) and key chunks (inner) carrying running
    (max, denominator, accumulator) — flash attention in pure JAX.

    ``causal and skip_upper_triangle`` statically unrolls the query-chunk
    loop so each query chunk's inner scan stops at the diagonal: the 2×
    causal-FLOP saving is visible to ``compiled.cost_analysis()`` (this is
    the "triangle skipping" perf move in EXPERIMENTS.md §Perf; baseline mode
    computes the full rectangle like a naive port would).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    q_chunk = _fit_chunk(sq, q_chunk)
    k_chunk = _fit_chunk(sk, k_chunk)
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = 1.0 / (d ** 0.5)

    qc = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,D)
    kc = k.reshape(b, nk, k_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, k_chunk, h, d).transpose(1, 0, 3, 2, 4)

    neg = jnp.float32(-1e30)

    def make_k_step(q_i, qi):
        def k_step(carry, ki):
            acc, m, l = carry
            k_i, v_i = kc[ki], vc[ki]
            s_ij = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_i,
                              preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * k_chunk + jnp.arange(k_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s_ij = jnp.where(mask[None, None], s_ij, neg)
            m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1, keepdims=True))
            p_ij = jnp.exp(s_ij - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p_ij, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p_ij.astype(v_i.dtype), v_i,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        return k_step

    def init_carry():
        return (jnp.zeros((b, h, q_chunk, d), jnp.float32),
                jnp.full((b, h, q_chunk, 1), neg),
                jnp.zeros((b, h, q_chunk, 1), jnp.float32))

    if causal and skip_upper_triangle:
        # static unroll over query chunks; inner scan stops at the diagonal
        outs = []
        for qi in range(nq):
            n_valid = (qi * q_chunk) // k_chunk + 1
            (acc, m, l), _ = jax.lax.scan(make_k_step(qc[qi], qi),
                                          init_carry(), jnp.arange(n_valid))
            outs.append(acc / jnp.maximum(l, 1e-30))
        stacked = jnp.stack(outs)                      # (nq, B, H, qc, D)
    else:
        def q_block(qi):
            (acc, m, l), _ = jax.lax.scan(make_k_step(qc[qi], qi),
                                          init_carry(), jnp.arange(nk))
            return acc / jnp.maximum(l, 1e-30)

        stacked = jax.lax.map(q_block, jnp.arange(nq))

    return stacked.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# public layer entry points
# ---------------------------------------------------------------------------


def attention_train(p: Params, x: jax.Array, cfg, *, causal: bool = True,
                    positions: Optional[jax.Array] = None,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    chunk: int = 1024,
                    skip_upper_triangle: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, d_model)."""
    compute = x.dtype
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, h, hkv, hd, compute)
    if kv_override is not None:   # cross-attention: K/V from encoder states
        enc = kv_override[0]
        se = enc.shape[1]
        k = dense(p["wk"], enc, compute).reshape(b, se, hkv, hd)
        v = dense(p["wv"], enc, compute).reshape(b, se, hkv, hd)
        causal = False            # no RoPE across modalities
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, ("batch", "seq", "heads", None))
    # constrain K/V only after GQA head replication: kv_heads rarely divide
    # the model axis (qwen2.5 has 2), the replicated head dim always does
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    k = shard(k, ("batch", "seq", "heads", None))
    v = shard(v, ("batch", "seq", "heads", None))
    out = flash_attention(q, k, v, causal=causal, q_chunk=chunk, k_chunk=chunk,
                          skip_upper_triangle=skip_upper_triangle)
    out = shard(out, ("batch", "seq", "heads", None))
    return dense(p["wo"], out.reshape(b, s, h * hd), compute)


def init_kv_cache(batch: int, max_seq: int, n_kv_heads: int, head_dim: int,
                  dtype) -> Params:
    return {
        "k": jnp.zeros((batch, max_seq, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_seq, n_kv_heads, head_dim), dtype),
    }


def attention_prefill(p: Params, x: jax.Array, cfg, cache: Params,
                      chunk: int = 1024) -> Tuple[jax.Array, Params]:
    """Causal attention over the prompt, filling the cache in one shot."""
    compute = x.dtype
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, h, hkv, hd, compute)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1),
    }
    kf = _repeat_kv(k, h // hkv)
    vf = _repeat_kv(v, h // hkv)
    out = flash_attention(q, kf, vf, causal=True, q_chunk=chunk, k_chunk=chunk)
    y = dense(p["wo"], out.reshape(b, s, h * hd), compute)
    return y, new_cache


def attention_decode(p: Params, x: jax.Array, cfg, cache: Params,
                     pos: jax.Array,
                     kv_override: Optional[Tuple[jax.Array, jax.Array]] = None
                     ) -> Tuple[jax.Array, Params]:
    """One-token decode. x: (B, 1, d_model); cache K/V: (B, S_max, Hkv, D)."""
    compute = x.dtype
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    q = dense(p["wq"], x, compute).reshape(b, 1, h, hd)
    if kv_override is None:       # cross-attention skips RoPE (as in train)
        q = apply_rope(q, pos[None], cfg.rope_theta)

    if kv_override is None:
        k1 = dense(p["wk"], x, compute).reshape(b, 1, hkv, hd)
        v1 = dense(p["wv"], x, compute).reshape(b, 1, hkv, hd)
        k1 = apply_rope(k1, pos[None], cfg.rope_theta)
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k1.astype(cache["k"].dtype), pos, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v1.astype(cache["v"].dtype), pos, axis=1),
        }
        valid_upto = pos + 1
        k = cache["k"].astype(compute)
        v = cache["v"].astype(compute)
    else:
        # cross-attention: project the encoder states (matches train path)
        enc = kv_override[0]
        se = enc.shape[1]
        k = dense(p["wk"], enc, compute).reshape(b, se, hkv, hd)
        v = dense(p["wv"], enc, compute).reshape(b, se, hkv, hd)
        valid_upto = jnp.asarray(se)
    s_max = k.shape[1]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    mask = jnp.arange(s_max)[None, None, None, :] < valid_upto
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(compute)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v,
                     preferred_element_type=jnp.float32).astype(compute)
    y = dense(p["wo"], out.reshape(b, 1, h * hd), compute)
    return y, cache
