"""Common NN layers (functional, params-as-pytrees — no framework deps).

Conventions
-----------
* ``init_*`` functions build param dicts in ``cfg.param_dtype``.
* ``*_apply`` functions cast to ``cfg.compute_dtype`` internally and return
  activations in compute dtype (norms accumulate in f32).
* Logical activation sharding goes through :func:`repro.launch.sharding.shard`.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dtype_of", "dense_init", "dense", "norm_init", "norm_apply",
    "embed_init", "embed_apply", "unembed_apply", "mlp_init", "mlp_apply",
]

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    scale = 1.0 / (d_in ** 0.5)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    y = jnp.dot(x.astype(compute_dtype), p["w"].astype(compute_dtype))
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_init(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed_apply(p: Params, tokens: jax.Array, compute_dtype) -> jax.Array:
    return p["table"].astype(compute_dtype)[tokens]


def unembed_apply(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    """Logits = x @ tableᵀ (used tied or with a separate lm_head table)."""
    return jnp.dot(x.astype(compute_dtype),
                   p["table"].astype(compute_dtype).T)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": dense_init(k1, d, d_ff, dtype),
         "wo": dense_init(k2, d_ff, d, dtype)}
    if act == "swiglu":
        p["wg"] = dense_init(k3, d, d_ff, dtype)
    return p


def mlp_apply(p: Params, x: jax.Array, act: str, compute_dtype,
              shard=None) -> jax.Array:
    h = dense(p["wi"], x, compute_dtype)
    if act == "swiglu":
        g = dense(p["wg"], x, compute_dtype)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    if shard is not None:
        h = shard(h, ("batch", "seq", "ff"))
    return dense(p["wo"], h, compute_dtype)
