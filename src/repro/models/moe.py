"""Mixture-of-Experts FFN with sort-based (megablox-style) routing.

Instead of the classic (tokens × experts × capacity) one-hot dispatch tensor
— infeasible at qwen3's 128 experts — tokens are **sorted by assigned
expert** and gathered into per-expert capacity buckets:

    flatten -> top-k route -> sort by expert -> bucket to (E, C, d)
    -> batched expert matmuls -> scatter-combine with router weights.

The sort is the same contention-free primitive the whole framework is built
on (DESIGN.md §2); under GSPMD the (tokens)[data] → (experts)[model]
re-bucketing lowers to the expected EP all-to-all pair.

Overflowing tokens beyond ``capacity = tokens·k/E · capacity_factor`` are
dropped (their combine weight is zero) — standard capacity-based semantics.
An auxiliary load-balancing loss is returned for training.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..launch.sharding import shard
from .layers import dense_init

Params = Dict


def moe_init(key, d_model: int, d_ff: int, n_experts: int, act: str,
             dtype) -> Params:
    kr, ki, kg, ko = jax.random.split(key, 4)
    scale_in = 1.0 / (d_model ** 0.5)
    scale_out = 1.0 / (d_ff ** 0.5)
    p = {
        "router": dense_init(kr, d_model, n_experts, jnp.float32),
        "wi": (jax.random.normal(ki, (n_experts, d_model, d_ff)) * scale_in
               ).astype(dtype),
        "wo": (jax.random.normal(ko, (n_experts, d_ff, d_model)) * scale_out
               ).astype(dtype),
    }
    if act == "swiglu":
        p["wg"] = (jax.random.normal(kg, (n_experts, d_model, d_ff)) * scale_in
                   ).astype(dtype)
    return p


def moe_apply(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Dispatch on cfg.moe_impl (see module docstring / §Perf)."""
    if getattr(cfg, "moe_impl", "sorted") == "expert_tp":
        out = moe_apply_expert_tp(p, x, cfg)
        if out is not None:
            return out
    return moe_apply_sorted(p, x, cfg)


def moe_apply_sorted(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    compute = x.dtype
    b, s, d = x.shape
    e = cfg.n_experts
    k = cfg.experts_per_token
    t = b * s
    capacity = max(int(t * k / e * cfg.capacity_factor), 1)
    # round capacity to an MXU-friendly multiple
    capacity = -(-capacity // 128) * 128 if capacity >= 128 else capacity

    xf = x.reshape(t, d)
    logits = jnp.dot(xf.astype(jnp.float32), p["router"]["w"])     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # -- load balance aux (Switch-style)
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e), axis=0)
    aux = jnp.sum(me * ce) * e

    # -- sort token-expert assignments by expert (the sort-first trick)
    flat_expert = gate_idx.reshape(-1)                             # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se_, st_, sg_ = flat_expert[order], flat_token[order], flat_gate[order]

    # rank of each assignment within its expert group
    seg_start = jnp.searchsorted(se_, jnp.arange(e))               # (E,)
    rank = jnp.arange(t * k) - seg_start[se_]
    keep = rank < capacity                                          # drop overflow

    # bucket index (E, C) -> position in sorted stream
    bucket_pos = seg_start[:, None] + jnp.arange(capacity)[None, :]
    bucket_valid = bucket_pos < jnp.searchsorted(se_, jnp.arange(e),
                                                 side="right")[:, None]
    bucket_pos = jnp.minimum(bucket_pos, t * k - 1)
    bucket_tok = jnp.where(bucket_valid, st_[bucket_pos], 0)        # (E, C)

    xe = xf[bucket_tok] * bucket_valid[..., None].astype(compute)   # (E, C, d)
    # capacity dim shards over data (tokens), expert dim over model (EP):
    # compute is 1/(data·model) per device; the (tokens)[data] ->
    # (experts)[model] re-bucketing is the EP all-to-all.
    xe = shard(xe, ("experts", "batch", "embed"))

    # -- expert FFN (batched over experts; shards over the expert axis)
    wi = p["wi"].astype(compute)
    wo = p["wo"].astype(compute)
    h = jnp.einsum("ecd,edf->ecf", xe, wi, preferred_element_type=compute)
    if "wg" in p:
        g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(compute),
                       preferred_element_type=compute)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, ("experts", "batch", "expert_ff"))
    ye = jnp.einsum("ecf,efd->ecd", h, wo, preferred_element_type=compute)
    ye = shard(ye, ("experts", "batch", "embed"))

    # -- combine back: scatter expert outputs to (sorted) assignments
    flat_out = ye.reshape(e * capacity, d)
    assign_bucket = jnp.where(keep, se_ * capacity + jnp.minimum(rank, capacity - 1),
                              0)
    contrib = flat_out[assign_bucket] * (sg_ * keep)[:, None].astype(compute)
    out = jax.ops.segment_sum(contrib, st_, num_segments=t)         # (T, d)
    return out.reshape(b, s, d).astype(compute), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# expert-TP implementation (§Perf optimization)
# ---------------------------------------------------------------------------
#
# The sorted/GSPMD path above routes through a *global* argsort over T·k
# sharded assignments and a scatter-add combine; XLA lowers both to repeated
# (T, d)-sized all-reduces — ~850 s of collective time per step at qwen3
# scale (measured, EXPERIMENTS.md §Perf).  This path instead treats the
# expert axis as tensor parallelism:
#
#   * activations are replicated across the model axis anyway (standard TP),
#     so every model shard can bucket ITS experts' tokens locally — no
#     communication to dispatch;
#   * each shard runs its E/m experts over its local data-shard tokens;
#   * one psum over the model axis combines expert outputs — exactly the
#     collective a dense TP FFN already pays.
#
# Capacity semantics become per-(data-shard, expert) — the standard
# practical relaxation.


def moe_apply_expert_tp(p: Params, x: jax.Array, cfg):
    """shard_map MoE: local bucketing, expert-sharded FFN, psum combine.

    Returns None if no mesh/rules are installed (caller falls back)."""
    import functools

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..launch.sharding import current_rules

    rules = current_rules()
    if rules is None or rules.mesh is None:
        return None
    mesh = rules.mesh
    model_axis = rules.mapping.get("experts")
    if model_axis is None:   # experts not sharded: sorted path handles it
        return None
    dp = rules.mapping.get("batch")
    m_size = mesh.shape[model_axis]
    e = cfg.n_experts
    if e % m_size:
        return None
    e_local = e // m_size

    b, s, d = x.shape
    dp_axes = tuple(a for a in ((dp,) if isinstance(dp, str) else (dp or ()))
                    )
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    t_local = (b // dp_total) * s
    k = cfg.experts_per_token
    cap = max(int(t_local * k / e * cfg.capacity_factor), 8)

    x_spec = P(dp, None, None)
    w_spec_in = P(model_axis, rules.mapping.get("w_embed"), None)
    w_spec_out = P(model_axis, None, rules.mapping.get("w_embed"))
    r_spec = P(rules.mapping.get("w_embed"), None)

    has_gate = "wg" in p
    in_specs = [x_spec, r_spec, w_spec_in, w_spec_out]
    args = [x, p["router"]["w"], p["wi"], p["wo"]]
    if has_gate:
        in_specs.append(w_spec_in)
        args.append(p["wg"])

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(dp, None, None), P()),
        check_rep=False)
    def run(x_l, router_w, wi, wo, *rest):
        wg = rest[0] if rest else None
        compute = x_l.dtype
        bl = x_l.shape[0]
        xf = x_l.reshape(bl * s, d)                       # local tokens
        tl = xf.shape[0]
        # router weights may be d-sharded (2D weights): gather them
        if w_spec_in[1] is not None:
            router_w = jax.lax.all_gather(
                router_w, w_spec_in[1], axis=0, tiled=True)
            wi = jax.lax.all_gather(wi, w_spec_in[1], axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, w_spec_out[2], axis=2, tiled=True)
            if wg is not None:
                wg = jax.lax.all_gather(wg, w_spec_in[1], axis=1, tiled=True)
        logits = jnp.dot(xf.astype(jnp.float32), router_w)       # (tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        me_ = jnp.mean(probs, axis=0)
        ce_ = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e), axis=0)
        aux = jnp.sum(me_ * ce_) * e

        # my experts: [e0, e0 + e_local)
        mi = jax.lax.axis_index(model_axis)
        e0 = mi * e_local
        # rank of each (token, slot) within its expert via sorted positions
        flat_e = gate_idx.reshape(-1)                             # (tl·k,)
        order = jnp.argsort(flat_e, stable=True)                  # local sort
        se_ = flat_e[order]
        st_ = (jnp.repeat(jnp.arange(tl), k))[order]
        sg_ = gate_vals.reshape(-1)[order]
        seg_start = jnp.searchsorted(se_, jnp.arange(e))
        # bucket my experts' assignments into (e_local, cap)
        bucket_pos = seg_start[e0 + jnp.arange(e_local)][:, None] \
            + jnp.arange(cap)[None, :]
        seg_end = jnp.searchsorted(se_, jnp.arange(e), side="right")
        bucket_valid = bucket_pos < seg_end[e0 + jnp.arange(e_local)][:, None]
        bucket_pos = jnp.minimum(bucket_pos, tl * k - 1)
        bucket_tok = jnp.where(bucket_valid, st_[bucket_pos], 0)
        bucket_gate = jnp.where(bucket_valid, sg_[bucket_pos], 0.0)

        xe = xf[bucket_tok] * bucket_valid[..., None].astype(compute)
        wi_l = wi.astype(compute)
        h = jnp.einsum("ecd,edf->ecf", xe, wi_l,
                       preferred_element_type=compute)
        if wg is not None:
            g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(compute),
                           preferred_element_type=compute)
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        ye = jnp.einsum("ecf,efd->ecd", h, wo.astype(compute),
                        preferred_element_type=compute)
        # weighted scatter back to local tokens (local segment_sum)
        contrib = (ye * bucket_gate[..., None].astype(compute)
                   ).reshape(e_local * cap, d)
        out = jax.ops.segment_sum(contrib, bucket_tok.reshape(-1),
                                  num_segments=tl)
        # combine across expert shards — the TP-FFN psum
        out = jax.lax.psum(out.astype(compute), model_axis)
        aux = jax.lax.pmean(aux, model_axis)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return out.reshape(bl, s, d), aux.astype(jnp.float32)

    out, aux = run(*args)
    return out, aux
