"""Mamba-style selective SSM block (jamba's sub-quadratic mixer).

Selective state space: per-timestep input-dependent (Δ, B, C) with diagonal
A.  Train runs a **chunked scan**: sequential `lax.scan` over time chunks,
each chunk materializing only (batch, chunk, d_inner, d_state) — the HBM-
friendly middle ground between a pure time scan (too serial) and a full
associative scan (too much memory at 4k × d_inner 16k).  Decode carries the
(batch, d_inner, d_state) state — O(1) per token, which is what makes the
500k-context cells runnable (DESIGN.md §Arch-applicability).

The depthwise causal conv is included (width 4, as in Mamba); the modality
of jamba's conv is faithful, the kernel weights are ours.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..launch.sharding import shard
from .layers import dense, dense_init

Params = Dict


def mamba_init(key, d_model: int, cfg, dtype) -> Params:
    di = d_model * cfg.ssm_expand
    n = cfg.ssm_state_dim
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], d_model, di, dtype),
        "gate_proj": dense_init(ks[1], d_model, di, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv_width, di)) * 0.2
                   ).astype(dtype),
        "x_proj_b": dense_init(ks[3], di, n, dtype),
        "x_proj_c": dense_init(ks[4], di, n, dtype),
        "x_proj_dt": dense_init(ks[5], di, 1, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, float(n), n))[None, :].repeat(di, 0
                  ).astype(dtype),                       # (di, n)
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[6], di, d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, di); w: (W, di)."""
    wdt = w.shape[0]
    pad = jnp.zeros(x.shape[:1] + (wdt - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(wdt):                                   # W is tiny (4)
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out


def _ssm_params(p: Params, u: jax.Array, compute):
    """Input-dependent (dA, dBu, C) for a chunk. u: (B, L, di)."""
    n = p["a_log"].shape[1]
    bmat = dense(p["x_proj_b"], u, compute)                # (B, L, n)
    cmat = dense(p["x_proj_c"], u, compute)                # (B, L, n)
    dt = jax.nn.softplus(dense(p["x_proj_dt"], u, compute)
                         + p["dt_bias"].astype(compute))   # (B, L, di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))           # (di, n)
    da = jnp.exp(dt[..., None].astype(jnp.float32) * a)    # (B, L, di, n)
    dbu = (dt * u).astype(jnp.float32)[..., None] * \
        bmat.astype(jnp.float32)[..., None, :]             # (B, L, di, n)
    return da, dbu, cmat.astype(jnp.float32)


def mamba_train(p: Params, x: jax.Array, cfg, chunk: int = 256) -> jax.Array:
    """Full-sequence selective scan. x: (B, S, d_model)."""
    compute = x.dtype
    b, s, _ = x.shape
    u = dense(p["in_proj"], x, compute)
    z = dense(p["gate_proj"], x, compute)
    u = jax.nn.silu(_causal_conv(u, p["conv_w"].astype(compute)))
    u = shard(u, ("batch", "seq", "ssm_inner"))
    di = u.shape[-1]
    n = p["a_log"].shape[1]

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    uc = u.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)  # (nc, B, L, di)

    def chunk_step(h, u_i):
        da, dbu, c = _ssm_params(p, u_i, compute)          # (B,L,di,n) ×2
        # within-chunk associative scan on (a, b) pairs: h' = a·h + b
        def combine(x1, x2):
            a1, b1 = x1
            a2, b2 = x2
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbu), axis=1)
        hs = a_cum * h[:, None] + b_cum                     # (B, L, di, n)
        y = jnp.einsum("bldn,bln->bld", hs, c)              # contract state
        h_next = hs[:, -1]
        return h_next, y.astype(compute)

    h0 = jnp.zeros((b, di, n), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, uc)                # (nc, B, L, di)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = y + u * p["d_skip"].astype(compute)
    y = y * jax.nn.silu(z)
    return dense(p["out_proj"], y, compute)


def mamba_init_cache(batch: int, d_model: int, cfg, dtype) -> Params:
    di = d_model * cfg.ssm_expand
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dtype),
    }


def mamba_decode(p: Params, x: jax.Array, cfg, cache: Params
                 ) -> Tuple[jax.Array, Params]:
    """One-token step. x: (B, 1, d_model); O(1) state update."""
    compute = x.dtype
    b = x.shape[0]
    u = dense(p["in_proj"], x, compute)                    # (B, 1, di)
    z = dense(p["gate_proj"], x, compute)
    # rolling conv window
    win = jnp.concatenate([cache["conv"], u], axis=1)      # (B, W, di)
    w = p["conv_w"].astype(compute)
    u1 = jax.nn.silu(jnp.einsum("bwd,wd->bd", win, w))[:, None]  # (B, 1, di)
    da, dbu, c = _ssm_params(p, u1, compute)               # L=1
    h = cache["h"] * da[:, 0] + dbu[:, 0]                  # (B, di, n)
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0])[:, None]      # (B, 1, di)
    y = y.astype(compute) + u1 * p["d_skip"].astype(compute)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y, compute)
    return out, {"h": h, "conv": win[:, 1:]}
