"""xLSTM blocks: mLSTM (matrix memory, parallelizable) + sLSTM (scalar memory).

Follows arXiv:2405.04517 at block level:

* **mLSTM** — per head a (d_k × d_v) matrix memory C with exponential
  input/forget gates and a normalizer state; queries read C like attention
  reads a KV cache.  Train uses a chunked time scan (chunk-parallel inner
  compute, sequential chunk carry); decode is an O(1) state update, which is
  why xlstm-350m runs the 500k-context cell.
* **sLSTM** — scalar memory per channel with exponential gating and the
  m-state stabilizer; strictly sequential over time (the paper accepts this:
  sLSTM trades parallelism for state tracking), so train scans per step.

Both blocks use pre-norm residual wiring and a 2× up-projection, standing in
for the paper's block structure (documented simplification: we alternate
blocks by ``cfg.block_pattern`` instead of the 7:1 placement)."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..launch.sharding import shard
from .layers import dense, dense_init

Params = Dict


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, d_model: int, cfg, dtype) -> Params:
    di = d_model * cfg.ssm_expand
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], d_model, di, dtype),
        "wk": dense_init(ks[1], d_model, di, dtype),
        "wv": dense_init(ks[2], d_model, di, dtype),
        "wi": dense_init(ks[3], d_model, di, dtype, bias=True),   # input gate
        "wf": dense_init(ks[4], d_model, di, dtype, bias=True),   # forget gate
        "wz": dense_init(ks[5], d_model, di, dtype),              # out gate
        "proj_out": dense_init(ks[6], di, d_model, dtype),
    }


def _mlstm_heads(cfg, di: int) -> Tuple[int, int]:
    h = cfg.n_heads
    return h, di // h


def mlstm_train(p: Params, x: jax.Array, cfg, chunk: int = 128,
                return_state: bool = False):
    """x: (B, S, d_model). Chunked recurrent form of the mLSTM.

    ``return_state`` also returns the terminal (C, n, m) — used by prefill
    so decode continues from the end of the prompt."""
    compute = x.dtype
    b, s, _ = x.shape
    q = dense(p["wq"], x, compute)
    k = dense(p["wk"], x, compute)
    v = dense(p["wv"], x, compute)
    ig = dense(p["wi"], x, compute).astype(jnp.float32)       # log-space gates
    fg = dense(p["wf"], x, compute).astype(jnp.float32)
    og = jax.nn.sigmoid(dense(p["wz"], x, compute))
    h_heads, dk = _mlstm_heads(cfg, q.shape[-1])

    def split(t):
        return t.reshape(b, s, h_heads, dk)

    q, k, v = split(q), split(k), split(v)
    # xLSTM has few heads (4) — shard the wide dk dim over the model axis
    # instead (heads % model_parallelism != 0 caused involuntary SPMD
    # remat copies; §Perf xlstm iteration 1)
    q = shard(q, ("batch", "seq", None, "ssm_inner"))
    k = shard(k, ("batch", "seq", None, "ssm_inner"))
    v = shard(v, ("batch", "seq", None, "ssm_inner"))
    ig = ig.reshape(b, s, h_heads, dk).mean(-1)               # per-head gates
    fg = jax.nn.log_sigmoid(fg.reshape(b, s, h_heads, dk).mean(-1))

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def to_chunks(t, extra):
        return t.reshape((b, nc, chunk) + extra).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(extra))))

    qc = to_chunks(q, (h_heads, dk))
    kc = to_chunks(k, (h_heads, dk))
    vc = to_chunks(v, (h_heads, dk))
    ic = to_chunks(ig, (h_heads,))
    fc = to_chunks(fg, (h_heads,))

    def chunk_step(carry, inp):
        c_state, n_state, m_state = carry                      # (B,H,dk,dk),(B,H,dk),(B,H)
        q_i, k_i, v_i, i_i, f_i = inp                          # (B,L,H,*)
        # cumulative log forget within chunk
        f_cum = jnp.cumsum(f_i, axis=1)                        # (B,L,H)
        # stabilizer: m_new[t] = max(m + f_cum[t], max_j<=t (f_cum[t]-f_cum[j]+i[j]))
        g = f_cum[:, :, None, :] - f_cum[:, None, :, :] + i_i[:, None, :, :]
        lmask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
                 )[None, :, :, None]
        g = jnp.where(lmask, g, -jnp.inf)                      # (B,L,L',H)
        m_intra = jnp.max(g, axis=2)                           # (B,L,H)
        m_new = jnp.maximum(m_state[:, None] + f_cum, m_intra)
        # intra-chunk attention-like term
        w_intra = jnp.exp(g - m_new[:, :, None, :])            # (B,L,L',H)
        scale = 1.0 / (dk ** 0.5)
        scores = jnp.einsum("blhd,bmhd->blmh", q_i.astype(jnp.float32),
                            k_i.astype(jnp.float32)) * scale
        w = w_intra * scores
        num_intra = jnp.einsum("blmh,bmhd->blhd", w, v_i.astype(jnp.float32))
        den_intra = jnp.sum(w, axis=2)                         # (B,L,H)... per dk? abs
        # inter-chunk contribution from carried state
        decay = jnp.exp(m_state[:, None] + f_cum - m_new)      # (B,L,H)
        num_inter = jnp.einsum("blhd,bhde->blhe", q_i.astype(jnp.float32),
                               c_state) * decay[..., None] * scale
        den_inter = jnp.einsum("blhd,bhd->blh", q_i.astype(jnp.float32),
                               n_state) * decay * scale
        den = jnp.abs(den_intra + den_inter)
        y = (num_intra + num_inter) / jnp.maximum(den, 1.0)[..., None]
        # carry update: fold the whole chunk into (C, n, m)
        m_end = m_new[:, -1]                                   # (B,H)
        w_in = jnp.exp(f_cum[:, -1:, :] - f_cum + i_i - m_end[:, None])
        kv = jnp.einsum("blhd,blhe,blh->bhde", k_i.astype(jnp.float32),
                        v_i.astype(jnp.float32), w_in)
        ksum = jnp.einsum("blhd,blh->bhd", k_i.astype(jnp.float32), w_in)
        carry_decay = jnp.exp(m_state + f_cum[:, -1] - m_end)[..., None]
        c_next = c_state * carry_decay[..., None] + kv
        n_next = n_state * carry_decay + ksum
        return (c_next, n_next, m_end), y.astype(compute)

    c0 = jnp.zeros((b, h_heads, dk, dk), jnp.float32)
    n0 = jnp.zeros((b, h_heads, dk), jnp.float32)
    m0 = jnp.full((b, h_heads), -1e30, jnp.float32)
    (c_f, n_f, m_f), ys = jax.lax.scan(chunk_step, (c0, n0, m0),
                                       (qc, kc, vc, ic, fc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, -1)
    y = y * og
    out = dense(p["proj_out"], y, compute)
    if return_state:
        return out, {"c": c_f, "n": n_f, "m": m_f}
    return out


def mlstm_init_cache(batch: int, d_model: int, cfg, dtype) -> Params:
    di = d_model * cfg.ssm_expand
    h, dk = cfg.n_heads, di // cfg.n_heads
    return {
        "c": jnp.zeros((batch, h, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, h, dk), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(p: Params, x: jax.Array, cfg, cache: Params
                 ) -> Tuple[jax.Array, Params]:
    """One-token mLSTM step. x: (B, 1, d_model)."""
    compute = x.dtype
    b = x.shape[0]
    q = dense(p["wq"], x, compute)[:, 0]
    k = dense(p["wk"], x, compute)[:, 0]
    v = dense(p["wv"], x, compute)[:, 0]
    ig = dense(p["wi"], x, compute).astype(jnp.float32)[:, 0]
    fg = dense(p["wf"], x, compute).astype(jnp.float32)[:, 0]
    og = jax.nn.sigmoid(dense(p["wz"], x, compute))[:, 0]
    h_heads, dk = _mlstm_heads(cfg, q.shape[-1])

    def split(t):
        return t.reshape(b, h_heads, dk)

    q, k, v = split(q.astype(jnp.float32)), split(k.astype(jnp.float32)), \
        split(v.astype(jnp.float32))
    i_t = ig.reshape(b, h_heads, dk).mean(-1)
    f_t = jax.nn.log_sigmoid(fg.reshape(b, h_heads, dk).mean(-1))
    m_new = jnp.maximum(cache["m"] + f_t, i_t)
    fdec = jnp.exp(cache["m"] + f_t - m_new)[..., None]
    iw = jnp.exp(i_t - m_new)[..., None]
    c = cache["c"] * fdec[..., None] + jnp.einsum("bhd,bhe->bhde", k, v) * iw[..., None]
    n = cache["n"] * fdec + k * iw
    scale = 1.0 / (dk ** 0.5)
    num = jnp.einsum("bhd,bhde->bhe", q, c) * scale
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)) * scale
    y = num / jnp.maximum(den, 1.0)[..., None]
    y = (y.reshape(b, 1, -1).astype(compute)) * og[:, None]
    return dense(p["proj_out"], y, compute), {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, d_model: int, cfg, dtype) -> Params:
    di = d_model * cfg.ssm_expand
    ks = jax.random.split(key, 6)
    return {
        "wz": dense_init(ks[0], d_model, di, dtype, bias=True),  # cell input
        "wi": dense_init(ks[1], d_model, di, dtype, bias=True),
        "wf": dense_init(ks[2], d_model, di, dtype, bias=True),
        "wo_gate": dense_init(ks[3], d_model, di, dtype, bias=True),
        "r_h": dense_init(ks[4], di, di, dtype),                 # recurrent mix
        "proj_out": dense_init(ks[5], di, d_model, dtype),
    }


def slstm_step(p: Params, state, zi, ii, fi, oi, compute):
    """One sLSTM timestep with exponential gating + m stabilizer."""
    c, n, h, m = state
    rh = jnp.dot(h, p["r_h"]["w"].astype(jnp.float32))
    z = jnp.tanh(zi + rh)
    i_log = ii + rh
    f_log = jax.nn.log_sigmoid(fi + rh)
    m_new = jnp.maximum(f_log + m, i_log)
    i_ = jnp.exp(i_log - m_new)
    f_ = jnp.exp(f_log + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h_new = jax.nn.sigmoid(oi) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_train(p: Params, x: jax.Array, cfg, return_state: bool = False):
    """x: (B, S, d_model); strictly sequential scan over time.

    §Perf: the four gate projections run as ONE fused matmul and the scan
    consumes ONE (S, B, 4·di) stream — a single dynamic-slice per step
    instead of four, which cut the measured per-step HBM traffic ~2×
    (EXPERIMENTS.md §Perf, xlstm iteration 2)."""
    compute = x.dtype
    b, s, _ = x.shape
    # NOTE(§Perf xlstm iterations 2-3, both refuted): fusing the four gate
    # projections into one stream — either concatenated along di or stacked
    # on a fresh axis — INCREASED measured HBM traffic (+66% / +23%): the
    # concat slices a model-sharded dim per step (per-step reshard), and
    # the stacked form still loses the per-stream fusion structure.  The
    # four separate streams below are the measured optimum for XLA's
    # scan lowering; the structural fix is a Pallas recurrence kernel
    # (state resident in VMEM across steps), left as documented follow-up.
    zi = dense(p["wz"], x, compute).astype(jnp.float32)
    ii = dense(p["wi"], x, compute).astype(jnp.float32)
    fi = dense(p["wf"], x, compute).astype(jnp.float32)
    oi = dense(p["wo_gate"], x, compute).astype(jnp.float32)
    di = zi.shape[-1]

    def step(state, inp):
        z, i_, f_, o_ = inp
        new = slstm_step(p, state, z, i_, f_, o_, compute)
        return new, new[2]

    init = tuple(jnp.zeros((b, di), jnp.float32) for _ in range(3)) + \
        (jnp.full((b, di), -1e30, jnp.float32),)
    xs = tuple(t.transpose(1, 0, 2) for t in (zi, ii, fi, oi))
    (c_f, n_f, h_f, m_f), hs = jax.lax.scan(step, init, xs)
    y = hs.transpose(1, 0, 2).astype(compute)
    out = dense(p["proj_out"], y, compute)
    if return_state:
        return out, {"c": c_f, "n": n_f, "h": h_f, "m": m_f}
    return out


def slstm_init_cache(batch: int, d_model: int, cfg, dtype) -> Params:
    di = d_model * cfg.ssm_expand
    return {
        "c": jnp.zeros((batch, di), jnp.float32),
        "n": jnp.zeros((batch, di), jnp.float32),
        "h": jnp.zeros((batch, di), jnp.float32),
        "m": jnp.full((batch, di), -1e30, jnp.float32),
    }


def slstm_decode(p: Params, x: jax.Array, cfg, cache: Params
                 ) -> Tuple[jax.Array, Params]:
    compute = x.dtype
    zi = dense(p["wz"], x, compute).astype(jnp.float32)[:, 0]
    ii = dense(p["wi"], x, compute).astype(jnp.float32)[:, 0]
    fi = dense(p["wf"], x, compute).astype(jnp.float32)[:, 0]
    oi = dense(p["wo_gate"], x, compute).astype(jnp.float32)[:, 0]
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = slstm_step(p, state, zi, ii, fi, oi, compute)
    y = h[:, None].astype(compute)
    return dense(p["proj_out"], y, compute), {"c": c, "n": n, "h": h, "m": m}
