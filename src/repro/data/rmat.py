"""R-MAT graph generator (Chakrabarti et al.) — LiveJournal/Twitter-like
synthetic power-law graphs for the paper-table benchmarks.

The SNAP datasets themselves aren't shipped in this container; R-MAT with
(a,b,c,d) = (0.57, 0.19, 0.19, 0.05) gives the community structure +
heavy-tail degree distribution these benchmarks care about.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["rmat_edges"]


def rmat_edges(scale: int, edge_factor: int = 16, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Generate 2^scale nodes and edge_factor·2^scale directed edges."""
    n = 1 << scale
    m = edge_factor * n
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    abc = a + b + c
    for bit in range(scale):
        r = rng.random(m)
        src_bit = (r >= ab).astype(np.int64)
        # within chosen half, pick column quadrant
        r2 = rng.random(m)
        thresh = np.where(src_bit == 0, a / ab, c / (1.0 - ab))
        dst_bit = (r2 >= thresh).astype(np.int64)
        src |= src_bit << bit
        dst |= dst_bit << bit
    # permute labels to kill the bit-pattern locality artifact
    perm = rng.permutation(n)
    return perm[src].astype(np.int32), perm[dst].astype(np.int32)
