"""Graph-derived training corpora — the bridge between the paper's engine
and the LM substrate (DESIGN.md §4).

Ringo's workflow ends with "results back to tables"; here a table/graph
round-trips into an LM token stream: random walks over a Graph become
sequences (DeepWalk-style), so the LM examples train on data produced by the
graph engine itself.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import Graph

__all__ = ["RandomWalkCorpus"]


class RandomWalkCorpus:
    """Batches of random-walk token sequences over a graph.

    Node ids are tokens (vocab = n_nodes, callers cap/remap as needed).
    Deterministic per (seed, step) like SyntheticLM.
    """

    def __init__(self, g: Graph, batch: int, seq_len: int, seed: int = 0):
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.n = g.n_nodes
        # host-side CSR copies for fast walking
        self.ptr = np.asarray(g.out_ptr)
        self.idx = np.asarray(g.out_idx)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        walks = np.zeros((self.batch, self.seq + 1), np.int32)
        cur = rng.integers(0, self.n, self.batch)
        walks[:, 0] = cur
        for t in range(1, self.seq + 1):
            lo = self.ptr[cur]
            hi = self.ptr[cur + 1]
            deg = hi - lo
            # dangling nodes teleport
            jump = rng.integers(0, self.n, self.batch)
            offs = (rng.random(self.batch) * np.maximum(deg, 1)).astype(np.int64)
            nxt = np.where(deg > 0, self.idx[lo + offs], jump)
            cur = nxt.astype(np.int64)
            walks[:, t] = cur
        return {"tokens": walks[:, :-1], "targets": walks[:, 1:]}
