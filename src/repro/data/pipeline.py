"""Data pipeline: deterministic synthetic token streams + host-side
prefetch + per-shard feeding.

Determinism contract (fault tolerance): batch ``i`` is a pure function of
``(seed, i)`` — after checkpoint-restart the pipeline resumes mid-stream
exactly, with no state to save beyond the step counter.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "Prefetcher", "make_batch_specs"]


class SyntheticLM:
    """Zipf-ish synthetic LM stream (B, S) int32 tokens + next-token targets."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        # zipf-like marginal over the vocab (realistic embedding traffic)
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        tokens = (z % self.vocab).astype(np.int32)
        return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of host batches onto device."""

    def __init__(self, source, depth: int = 2, sharding=None, start_step: int = 0):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.sharding = sharding
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            host = self.source.batch_at(step)
            dev = {k: (jax.device_put(v, self.sharding) if self.sharding is not None
                       else jnp.asarray(v)) for k, v in host.items()}
            self.q.put((step, dev))
            step += 1

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def make_batch_specs(cfg, shape, dtype_tokens=jnp.int32):
    """ShapeDtypeStructs for a (train) batch of the given ShapeSpec."""
    b, s = shape.global_batch, shape.seq_len
    spec = {
        "tokens": jax.ShapeDtypeStruct((b, s), dtype_tokens),
        "targets": jax.ShapeDtypeStruct((b, s), dtype_tokens),
    }
    if cfg.is_encoder_decoder:
        spec["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.n_patches:
        spec["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return spec
