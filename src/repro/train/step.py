"""Train-step builders: the GSPMD step (production) and an explicit
shard_map DDP step (gradient-compression path).

``make_train_step(cfg)`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
ready for ``jax.jit`` with in/out shardings from launch/sharding.py.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import transformer as model
from . import compress as compress_mod
from .optimizer import OptHyper, clip_by_global_norm, get_optimizer

Params = Any


def make_train_step(cfg, hyper: OptHyper = OptHyper(), *,
                    attn_chunk: int = 1024, skip_upper_triangle: bool = True):
    opt = get_optimizer(cfg.optimizer)

    def train_step(params, opt_state, batch, step):
        def lf(p):
            return model.loss_fn(p, cfg, batch, chunk=attn_chunk,
                                 skip_upper_triangle=skip_upper_triangle)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, hyper.clip_norm)
        params, opt_state = opt.update(params, grads, opt_state, step, hyper)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def init_train_state(cfg, key):
    params = model.init_params(cfg, key)
    opt = get_optimizer(cfg.optimizer)
    return params, opt.init(params)


# ---------------------------------------------------------------------------
# explicit DDP (shard_map) with optional int8 gradient compression
# ---------------------------------------------------------------------------


def make_ddp_step(cfg, mesh, hyper: OptHyper = OptHyper(), *,
                  axis: str = "data", compress: bool = False,
                  attn_chunk: int = 1024):
    """Pure data parallelism with an explicit gradient psum.

    Demonstrates the compression trick end-to-end (params replicated, batch
    sharded over ``axis``); the production path uses GSPMD instead.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    opt = get_optimizer(cfg.optimizer)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(), P()),
        out_specs=(P(), P(), P(), P()),
        check_rep=False,
    )
    def ddp_step(params, opt_state, batch, step, residuals):
        def lf(p):
            return model.loss_fn(p, cfg, batch, chunk=attn_chunk)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if compress:
            grads, residuals = compress_mod.compressed_psum(grads, residuals,
                                                            axis)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
        grads, gnorm = clip_by_global_norm(grads, hyper.clip_norm)
        params, opt_state = opt.update(params, grads, opt_state, step, hyper)
        loss = jax.lax.pmean(loss, axis)
        return params, opt_state, loss, residuals

    return ddp_step
