"""Gradient compression (int8 + error feedback) for data-parallel all-reduce.

At 1000+ nodes the DP gradient all-reduce dominates the network; quantizing
to int8 with per-tensor scales cuts those bytes 4× vs f32 (2× vs bf16).
Error feedback (residual carried to the next step) keeps convergence
unbiased in practice.

Used by the explicit shard_map DP path (`train/step.py::make_ddp_step`);
under the GSPMD path compression stays off (XLA owns the reduction there) —
recorded as a distributed-optimization option in DESIGN.md §5.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, residuals, axis_name: str):
    """psum int8-compressed gradients with error feedback.

    Common-scale scheme (exact): one scalar `pmax` fixes a shared scale per
    leaf, every device quantizes to int8 against it, the payload is summed in
    int32 (log2(n) carry bits), and dequantized once.  The wire payload is
    the int8 tensor + one scalar — 4× fewer bytes than f32, 2× vs bf16.
    New residual = local value - its quantized representation.
    Must run inside shard_map with ``axis_name`` bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
