"""Optimizers: AdamW + Adafactor, global-norm clipping, ZeRO-style state
sharding helpers.  Functional (state is a pytree), no external deps.

Adafactor (factored second moments) is selected for the ≥300 B-param archs
(grok, jamba, qwen3-moe): Adam's two f32 state tensors would exceed a single
pod's 4 TB HBM (DESIGN.md §5), Adafactor's row/col factors are ~d+f instead
of d·f per matrix.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptHyper:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    epsilon1: float = 1e-30
    epsilon2: float = 1e-3


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(params, grads, state, step, h: OptHyper):
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - h.beta1 ** t
    bc2 = 1.0 - h.beta2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = h.beta1 * m + (1 - h.beta1) * g
        v_new = h.beta2 * v + (1 - h.beta2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + h.eps) + h.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - h.lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moments, no momentum
# ---------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params) -> Dict:
    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"f": jax.tree.map(init, params,
                              is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(params, grads, state, step, h: OptHyper):
    t = step.astype(jnp.float32) + 1.0
    rho = 1.0 - t ** (-h.decay_rate)

    def upd(p, g, s):
        g = g.astype(jnp.float32)
        g2 = g * g + h.epsilon1
        if _factored(p.shape):
            vr = rho * s["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
            vc = rho * s["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
            rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), h.epsilon1)
            update = g / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc)[..., None, :]
                          + h.epsilon2)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = rho * s["v"] + (1 - rho) * g2
            update = g / (jnp.sqrt(v) + h.epsilon2)
            new_s = {"v": v}
        # update clipping (RMS <= 1) as in the paper
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + h.epsilon1)
        update = update / jnp.maximum(1.0, rms)
        new_p = (p.astype(jnp.float32) - h.lr * update
                 - h.lr * h.weight_decay * p.astype(jnp.float32)).astype(p.dtype)
        return new_p, new_s

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["f"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    return (treedef.unflatten([o[0] for o in out]),
            {"f": treedef.unflatten([o[1] for o in out])})


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (params, grads, state, step, hyper) -> (params, state)


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return Optimizer(adamw_init, adamw_update)
    if name == "adafactor":
        return Optimizer(adafactor_init, adafactor_update)
    raise ValueError(f"unknown optimizer {name!r}")


# ---------------------------------------------------------------------------
# ZeRO-1 style optimizer-state sharding
# ---------------------------------------------------------------------------


def zero1_extend_spec(spec, shape, mesh, data_axis="data"):
    """Extend one PartitionSpec by sharding the first large replicated dim
    over the data axis — ZeRO-1 semantics under GSPMD (the optimizer state
    lives reduce-scattered across data-parallel replicas)."""
    from jax.sharding import PartitionSpec as P

    dsize = 1
    for ax in (data_axis if isinstance(data_axis, tuple) else (data_axis,)):
        dsize *= mesh.shape[ax]
    axes = list(spec) if spec is not None else []
    axes = axes + [None] * (len(shape) - len(axes))
    axes = axes[: len(shape)]
    # the data axis can appear at most once across the whole spec
    used = set()
    for a in axes:
        for x in (a if isinstance(a, tuple) else (a,)):
            used.add(x)
    dnames = set(data_axis if isinstance(data_axis, tuple) else (data_axis,))
    if used & dnames:
        return P(*axes)
    for i in range(len(shape)):
        if axes[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
            axes[i] = data_axis
            break
    return P(*axes)


def opt_state_specs(opt_name: str, param_specs, state_shapes, mesh,
                    data_axis="data", zero1: bool = True):
    """PartitionSpec tree for the optimizer state.

    adamw: m/v mirror params -> reuse (optionally ZeRO-extended) param specs.
    adafactor: factored leaves get their largest dim sharded over data.
    """
    from jax.sharding import PartitionSpec as P

    if opt_name == "adamw":
        def one(spec, shaped):
            if zero1:
                return zero1_extend_spec(spec, shaped.shape, mesh, data_axis)
            return spec
        m = jax.tree.map(one, param_specs, state_shapes["m"])
        v = jax.tree.map(one, param_specs, state_shapes["v"])
        return {"m": m, "v": v}
    # adafactor: shapes don't mirror params; shard biggest dim over data
    def fac(shaped):
        if zero1:
            return zero1_extend_spec(P(), shaped.shape, mesh, data_axis)
        return P()
    return {"f": jax.tree.map(fac, state_shapes["f"])}
