"""Public jit'd wrappers around the Pallas kernels + host-side re-blocking.

These give graph-level entry points (``pagerank_bsr``, ``triangle_count_bsr``,
``segment_sum_sorted``) used by benchmarks and the distributed engine.  The
host-side helpers perform the *re-blocking* that adapts Ringo's per-edge
algorithms to MXU tiles: edges → 128×128 BSR tiles / 128-wide chunked
segments.  On non-TPU backends the kernels run in interpret mode
(``interpret=None`` → auto).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import Graph
from .bsr_spmv import bsr_spmv
from .bsr_tricount import bsr_tricount
from .segment_sum import DEFAULT_BLOCK, DEFAULT_CHUNK, segment_sum_chunked

__all__ = [
    "auto_interpret",
    "edges_to_bsr",
    "build_block_triples",
    "pagerank_bsr",
    "triangle_count_bsr",
    "segment_sum_sorted",
]


def auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


# ---------------------------------------------------------------------------
# host-side re-blocking (numpy; conversion-time work, done once per graph)
# ---------------------------------------------------------------------------


def edges_to_bsr(src: np.ndarray, dst: np.ndarray, n: int,
                 values: Optional[np.ndarray] = None,
                 block: int = DEFAULT_BLOCK
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Build (tiles, rows, cols, n_blocks) BSR with every row-block present.

    Matrix semantics: M[dst, src] = value  (the PageRank pull layout:
    y = M @ x gathers from sources into destinations).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    vals = np.ones_like(src, dtype=np.float32) if values is None \
        else np.asarray(values, dtype=np.float32)
    nb = (n + block - 1) // block
    rb, cb = dst // block, src // block
    key = rb * nb + cb
    uniq, inv = np.unique(key, return_inverse=True)
    # ensure every row block appears (zero tile on the diagonal)
    present = np.unique(uniq // nb)
    missing = np.setdiff1d(np.arange(nb), present)
    n_tiles = len(uniq) + len(missing)
    tiles = np.zeros((max(n_tiles, 1), block, block), np.float32)
    ri = (dst % block).astype(np.int64)
    ci = (src % block).astype(np.int64)
    np.add.at(tiles, (inv, ri, ci), vals)
    rows = np.concatenate([uniq // nb, missing])
    cols = np.concatenate([uniq % nb, missing])
    order = np.argsort(rows, kind="stable")
    tiles = tiles[order] if n_tiles else tiles
    rows, cols = rows[order], cols[order]
    return (jnp.asarray(tiles), jnp.asarray(rows.astype(np.int32)),
            jnp.asarray(cols.astype(np.int32)), nb)


def build_block_triples(rows: np.ndarray, cols: np.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Enumerate tile triples (I,J),(I,K),(K,J) all nonzero.

    Block-level analogue of "for each edge, intersect the two endpoint
    neighborhoods": the (I,J) tile plays the edge, K sweeps the common
    block-neighborhood.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    nnzb = len(rows)
    tile_of = {(int(r), int(c)): t for t, (r, c) in enumerate(zip(rows, cols))}
    by_row: dict = {}
    for t, r in enumerate(rows):
        by_row.setdefault(int(r), []).append(t)
    t_ij, t_ik, t_kj = [], [], []
    for ij in range(nnzb):
        i, j = int(rows[ij]), int(cols[ij])
        for ik in by_row.get(i, ()):        # tiles (i, k)
            k = int(cols[ik])
            kj = tile_of.get((k, j))
            if kj is not None:
                t_ij.append(ij)
                t_ik.append(ik)
                t_kj.append(kj)
    if not t_ij:  # keep grid non-empty
        t_ij, t_ik, t_kj = [0], [0], [0]
    return (jnp.asarray(t_ij, jnp.int32), jnp.asarray(t_ik, jnp.int32),
            jnp.asarray(t_kj, jnp.int32))


# ---------------------------------------------------------------------------
# graph-level entry points
# ---------------------------------------------------------------------------


def pagerank_bsr(g: Graph, n_iter: int = 10, damping: float = 0.85,
                 interpret: Optional[bool] = None,
                 block: int = DEFAULT_BLOCK) -> jax.Array:
    """PageRank with the BSR SpMV Pallas kernel as the inner contraction."""
    interpret = auto_interpret(interpret)
    n = g.n_nodes
    src, dst = g.in_edges()
    out_deg = np.asarray(g.out_degrees(), dtype=np.float32)
    src_np = np.asarray(src)
    w = 1.0 / out_deg[src_np]                       # column-stochastic M
    tiles, rows, cols, nb = edges_to_bsr(src_np, np.asarray(dst), n,
                                         values=w, block=block)
    dangling = jnp.asarray(out_deg == 0)
    pr = jnp.full((nb * block,), 0.0).at[:n].set(1.0 / n)
    for _ in range(n_iter):
        x_blocks = pr.reshape(nb, block)
        y = bsr_spmv(tiles, rows, cols, x_blocks, nb, interpret=interpret)
        y = y.reshape(-1)[: n]
        dang = jnp.sum(jnp.where(dangling, pr[:n], 0.0))
        new = (1.0 - damping) / n + damping * (y + dang / n)
        pr = pr.at[:n].set(new)
    return pr[:n]


def triangle_count_bsr(g: Graph, interpret: Optional[bool] = None,
                       block: int = DEFAULT_BLOCK) -> int:
    """Triangle count via the A∘(A·A) MXU kernel (g must be undirected)."""
    interpret = auto_interpret(interpret)
    src, dst = g.out_edges()
    tiles, rows, cols, nb = edges_to_bsr(np.asarray(dst), np.asarray(src),
                                         g.n_nodes, block=block)
    tiles = jnp.minimum(tiles, 1.0)                 # simple graph: 0/1
    t_ij, t_ik, t_kj = build_block_triples(np.asarray(rows), np.asarray(cols))
    six_t = bsr_tricount(tiles, t_ij, t_ik, t_kj, interpret=interpret)
    return int(round(float(six_t) / 6.0))


def segment_sum_sorted(vals: jax.Array, seg_ids: jax.Array, n_segments: int,
                       chunk: int = DEFAULT_CHUNK,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Segment-sum of values whose ``seg_ids`` are sorted ascending.

    Host-side chunking: group by 128-wide id block (already contiguous),
    pad each group to a multiple of ``chunk``, then run the one-hot-matmul
    kernel.  Returns (n_segments,) f32.
    """
    interpret = auto_interpret(interpret)
    b = DEFAULT_BLOCK
    nb = (n_segments + b - 1) // b
    seg_np = np.asarray(seg_ids, dtype=np.int64)
    val_np = np.asarray(vals, dtype=np.float32)
    blocks = seg_np // b
    # group boundaries per 128-block (sorted input => contiguous)
    starts = np.searchsorted(blocks, np.arange(nb), side="left")
    ends = np.searchsorted(blocks, np.arange(nb), side="right")
    counts = ends - starts
    n_chunks = np.maximum((counts + chunk - 1) // chunk, 1)  # >=1 per block
    total_chunks = int(n_chunks.sum())
    cvals = np.zeros((total_chunks, chunk), np.float32)
    clids = np.full((total_chunks, chunk), b, np.int32)      # pad id = b
    cblk = np.zeros((total_chunks,), np.int32)
    ci = 0
    for blk in range(nb):
        lo, hi = int(starts[blk]), int(ends[blk])
        for off in range(0, max(hi - lo, 1), chunk):
            take = min(chunk, max(hi - lo - off, 0))
            if take > 0:
                cvals[ci, :take] = val_np[lo + off: lo + off + take]
                clids[ci, :take] = (seg_np[lo + off: lo + off + take] % b)
            cblk[ci] = blk
            ci += 1
    out = segment_sum_chunked(jnp.asarray(cvals), jnp.asarray(clids),
                              jnp.asarray(cblk), nb, interpret=interpret)
    return out.reshape(-1)[: n_segments]
