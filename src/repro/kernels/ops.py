"""Host-side re-blocking helpers + compat shims for the BSR graph kernels.

The host-side helpers perform the *re-blocking* that adapts Ringo's per-edge
algorithms to MXU tiles: edges → 128×128 BSR tiles / 128-wide chunked
segments.  They are conversion-time work, invoked once per graph by
:class:`repro.core.plan.GraphPlan` and cached there.

``pagerank_bsr`` / ``triangle_count_bsr`` are retained as thin compatibility
shims: the BSR kernels are now a *backend* of the unified traversal engine
(``core/engine.py``), so these simply run the shared algorithm with
``backend="bsr"`` instead of maintaining a rival implementation.  On non-TPU
backends the kernels run in interpret mode (``interpret=None`` → auto).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import Graph
from .bsr_tricount import bsr_tricount
from .segment_sum import (DEFAULT_BLOCK, DEFAULT_CHUNK, chunk_layout,
                          segment_sum_chunked)

__all__ = [
    "auto_interpret",
    "edges_to_bsr",
    "build_block_triples",
    "pagerank_bsr",
    "triangle_count_bsr",
    "segment_sum_sorted",
]


def auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


# ---------------------------------------------------------------------------
# host-side re-blocking (numpy; conversion-time work, done once per graph)
# ---------------------------------------------------------------------------


def edges_to_bsr(src: np.ndarray, dst: np.ndarray, n: int,
                 values: Optional[np.ndarray] = None,
                 block: int = DEFAULT_BLOCK
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Build (tiles, rows, cols, n_blocks) BSR with every row-block present.

    Matrix semantics: M[dst, src] = value  (the PageRank pull layout:
    y = M @ x gathers from sources into destinations).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    vals = np.ones_like(src, dtype=np.float32) if values is None \
        else np.asarray(values, dtype=np.float32)
    # nb >= 1 even for empty graphs: the "every row block appears" pass then
    # emits one zero tile, so the SpMV kernel grid is never empty (the
    # degenerate dual of build_block_triples' non-empty-grid guard)
    nb = max((n + block - 1) // block, 1)
    rb, cb = dst // block, src // block
    key = rb * nb + cb
    uniq, inv = np.unique(key, return_inverse=True)
    # ensure every row block appears (zero tile on the diagonal)
    present = np.unique(uniq // nb)
    missing = np.setdiff1d(np.arange(nb), present)
    n_tiles = len(uniq) + len(missing)
    tiles = np.zeros((max(n_tiles, 1), block, block), np.float32)
    ri = (dst % block).astype(np.int64)
    ci = (src % block).astype(np.int64)
    np.add.at(tiles, (inv, ri, ci), vals)
    rows = np.concatenate([uniq // nb, missing])
    cols = np.concatenate([uniq % nb, missing])
    order = np.argsort(rows, kind="stable")
    tiles = tiles[order] if n_tiles else tiles
    rows, cols = rows[order], cols[order]
    return (jnp.asarray(tiles), jnp.asarray(rows.astype(np.int32)),
            jnp.asarray(cols.astype(np.int32)), nb)


def build_block_triples(rows: np.ndarray, cols: np.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Enumerate tile triples (I,J),(I,K),(K,J) all nonzero.

    Block-level analogue of "for each edge, intersect the two endpoint
    neighborhoods": the (I,J) tile plays the edge, K sweeps the common
    block-neighborhood.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    nnzb = len(rows)
    tile_of = {(int(r), int(c)): t for t, (r, c) in enumerate(zip(rows, cols))}
    by_row: dict = {}
    for t, r in enumerate(rows):
        by_row.setdefault(int(r), []).append(t)
    t_ij, t_ik, t_kj = [], [], []
    for ij in range(nnzb):
        i, j = int(rows[ij]), int(cols[ij])
        for ik in by_row.get(i, ()):        # tiles (i, k)
            k = int(cols[ik])
            kj = tile_of.get((k, j))
            if kj is not None:
                t_ij.append(ij)
                t_ik.append(ik)
                t_kj.append(kj)
    if not t_ij:  # keep grid non-empty
        t_ij, t_ik, t_kj = [0], [0], [0]
    return (jnp.asarray(t_ij, jnp.int32), jnp.asarray(t_ik, jnp.int32),
            jnp.asarray(t_kj, jnp.int32))


# ---------------------------------------------------------------------------
# graph-level entry points — compat shims over the unified engine
# ---------------------------------------------------------------------------


def pagerank_bsr(g: Graph, n_iter: int = 10, damping: float = 0.85,
                 interpret: Optional[bool] = None,
                 block: int = DEFAULT_BLOCK) -> jax.Array:
    """PageRank on the engine's "bsr" backend (BSR SpMV inner contraction)."""
    from ..core import algorithms, engine
    if g.n_nodes == 0:
        return jnp.zeros((0,), jnp.float32)
    plan = g.plan()
    ex = engine.get_exec(plan, "bsr", interpret=interpret, block=block)
    pr0 = jnp.full((g.n_nodes,), 1.0 / g.n_nodes, dtype=jnp.float32)
    return engine.fixpoint(ex, algorithms._pagerank_body, pr0, n_iter=n_iter,
                           args=(jnp.float32(damping), plan.inv_out_deg,
                                 plan.dangling))


def triangle_count_bsr(g: Graph, interpret: Optional[bool] = None,
                       block: int = DEFAULT_BLOCK) -> int:
    """Triangle count via the A∘(A·A) MXU kernel (g must be undirected)."""
    from ..core.algorithms import triangle_count
    if block == DEFAULT_BLOCK:
        return triangle_count(g, backend="bsr", interpret=interpret)
    if g.n_edges == 0 or g.n_nodes == 0:
        return 0
    plan = g.plan()
    tiles, _, _, _ = plan.bsr(block)
    t_ij, t_ik, t_kj = plan.tri_triples(block)
    six_t = bsr_tricount(jnp.minimum(tiles, 1.0), t_ij, t_ik, t_kj,
                         interpret=auto_interpret(interpret))
    return int(round(float(six_t) / 6.0))


def segment_sum_sorted(vals: jax.Array, seg_ids: jax.Array, n_segments: int,
                       chunk: int = DEFAULT_CHUNK,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Segment-sum of values whose ``seg_ids`` are sorted ascending.

    Host-side chunking via :func:`kernels.segment_sum.chunk_layout` (fully
    vectorized; the same structure GraphPlan caches per graph): group by
    128-wide id block, split each group into ``chunk``-long chunks, scatter
    the values in and run the one-hot-matmul kernel.  Returns (n_segments,)
    f32.
    """
    interpret = auto_interpret(interpret)
    entry_chunk, entry_slot, lids, cblk, nb, total = chunk_layout(
        np.asarray(seg_ids), n_segments, chunk)
    cvals = jnp.zeros((total, chunk), jnp.float32)
    cvals = cvals.at[jnp.asarray(entry_chunk), jnp.asarray(entry_slot)].set(
        jnp.asarray(vals).astype(jnp.float32))
    out = segment_sum_chunked(cvals, jnp.asarray(lids), jnp.asarray(cblk),
                              nb, interpret=interpret)
    return out.reshape(-1)[: n_segments]
