"""Sorted segmented reduction — Pallas TPU kernel for the conversion hot loop.

The paper's sort-first table→graph conversion (§2.4) reduces to: *after
sorting edges by destination, sum/count contributions per destination*.  On
CPU Ringo does atomic-free writes because each thread owns a partition; on
TPU the scatter itself must become arithmetic.  The trick: a segment-sum of a
chunk whose segment ids all fall in one 128-wide id block is a **one-hot
matmul**

    partial[s] = Σ_e vals[e]·[seg(e) == s]   ⇔   onehotᵀ(L×B) · vals(L)

which the MXU executes at full rate.  The host groups edges by 128-wide
destination block (they are already sorted — zero cost), pads each group to
the chunk length L, and the kernel accumulates chunks into the owning output
block, which stays in VMEM across the consecutive chunks of one block.

VMEM per step: L ids + L vals + L×B one-hot + B accumulator ≈ 0.27 MiB at
L=512, B=128, f32.  Also the group-by/aggregate hot loop (relational.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["segment_sum_chunked", "chunk_layout"]

DEFAULT_CHUNK = 512
DEFAULT_BLOCK = 128


def chunk_layout(seg_ids: np.ndarray, n_segments: int,
                 chunk: int = DEFAULT_CHUNK
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                            int, int]:
    """Static chunking structure for **sorted** segment ids (host-side).

    Groups entries by 128-wide output block and splits each group into
    ``chunk``-long chunks (every block gets >= 1 chunk so the kernel's
    accumulator init fires).  The structure depends only on ``seg_ids``, so
    callers (``GraphPlan``) compute it once per graph and re-scatter fresh
    values into it on every reduction:

        cvals = zeros((C, L)).at[entry_chunk, entry_slot].set(vals)

    Returns ``(entry_chunk, entry_slot, local_ids, chunk_block, nb, C)``
    where ``local_ids`` is (C, L) int32 with pad id = 128, ``chunk_block``
    is (C,) sorted ascending, ``nb`` the output block count and ``C`` the
    total chunk count.
    """
    b = DEFAULT_BLOCK
    nb = max((n_segments + b - 1) // b, 1)
    seg = np.asarray(seg_ids, dtype=np.int64)
    e = int(seg.shape[0])
    blocks = seg // b
    starts = np.searchsorted(blocks, np.arange(nb), side="left")
    ends = np.searchsorted(blocks, np.arange(nb), side="right")
    counts = ends - starts
    n_chunks = np.maximum((counts + chunk - 1) // chunk, 1)
    base = np.concatenate([[0], np.cumsum(n_chunks)[:-1]])
    total = int(n_chunks.sum())
    pos = np.arange(e) - starts[blocks]
    entry_chunk = (base[blocks] + pos // chunk).astype(np.int32)
    entry_slot = (pos % chunk).astype(np.int32)
    local_ids = np.full((total, chunk), b, np.int32)
    if e:
        local_ids[entry_chunk, entry_slot] = (seg % b).astype(np.int32)
    chunk_block = np.repeat(np.arange(nb), n_chunks).astype(np.int32)
    return entry_chunk, entry_slot, local_ids, chunk_block, nb, total


def _segsum_kernel(outblk_ref, vals_ref, lids_ref, out_ref):
    t = pl.program_id(0)
    first = t == 0
    prev = outblk_ref[jnp.maximum(t, 1) - 1]
    changed = outblk_ref[t] != prev

    @pl.when(first | changed)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    b = out_ref.shape[-1]
    lids = lids_ref[0]                                   # (L,) in [0, B] (B = pad)
    onehot = (lids[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
              ).astype(jnp.float32)                      # (L, B)
    out_ref[...] += jnp.dot(vals_ref[0].astype(jnp.float32)[None, :], onehot,
                            preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_out_blocks", "interpret"))
def segment_sum_chunked(vals: jax.Array, local_ids: jax.Array,
                        chunk_block: jax.Array, n_out_blocks: int,
                        interpret: bool = False) -> jax.Array:
    """Segment-sum of pre-chunked sorted data.

    Args:
      vals:       (C, L) chunked values (padding entries may hold anything).
      local_ids:  (C, L) int32 segment id *within* the owning 128-block;
                  padding entries must be >= B (one-hot row of zeros).
      chunk_block:(C,) int32 owning output block per chunk, sorted ascending,
                  covering every output block at least once.
      n_out_blocks: static number of 128-wide output blocks.

    Returns: (n_out_blocks, B) f32 segment sums.
    """
    c, l = vals.shape
    b = DEFAULT_BLOCK
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, l), lambda t, blk: (t, 0)),
            pl.BlockSpec((1, l), lambda t, blk: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda t, blk: (blk[t], 0)),
    )
    return pl.pallas_call(
        _segsum_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out_blocks, b), jnp.float32),
        interpret=interpret,
    )(chunk_block, vals, local_ids)
