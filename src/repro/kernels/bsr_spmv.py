"""Block-sparse SpMV Pallas TPU kernel — PageRank's hot loop.

Ringo's PageRank inner loop is a per-edge gather/scatter over the CSR
(OpenMP on 80 hyperthreads).  A TPU has no scatter hardware and wants
128-aligned dense tiles on the MXU, so we re-block the hypersparse adjacency
into **BSR**: 128×128 dense tiles stored only where the graph has edges
(DESIGN.md §2).  One PageRank iteration is then

    y[R] += Σ_{tiles t in row-block R}  A_t @ x[col_block(t)]

with the tile stream sorted by row-block so each output block stays resident
in VMEM across consecutive grid steps (zero HBM round-trips for partial
sums).  Tile indices arrive via scalar prefetch so the DMA pipeline can look
ahead through the sparse structure.

VMEM working set per grid step: one (B,B) tile + one (B,) x block + one (B,)
y accumulator = B²+2B floats ≈ 64 KiB + 1 KiB at B=128/f32 — comfortably
inside the ~16 MiB VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bsr_spmv"]

DEFAULT_BLOCK = 128


def _bsr_spmv_kernel(rows_ref, cols_ref, a_ref, x_ref, y_ref):
    t = pl.program_id(0)
    first = t == 0
    prev_row = rows_ref[jnp.maximum(t, 1) - 1]
    row_changed = rows_ref[t] != prev_row

    @pl.when(first | row_changed)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    # MXU tile contraction; accumulate in f32 regardless of tile dtype
    y_ref[...] += jnp.dot(
        a_ref[0], x_ref[0].astype(a_ref.dtype),
        preferred_element_type=jnp.float32,
    )[None, :].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_row_blocks", "interpret"))
def bsr_spmv(tiles: jax.Array, rows: jax.Array, cols: jax.Array,
             x_blocks: jax.Array, n_row_blocks: int,
             interpret: bool = False) -> jax.Array:
    """y = A @ x for BSR ``A``.

    Args:
      tiles: (nnzb, B, B) dense tiles (f32 or bf16).
      rows:  (nnzb,) int32 row-block ids, **sorted ascending**, covering
             every row block at least once (use a zero tile for empty rows).
      cols:  (nnzb,) int32 col-block ids.
      x_blocks: (n_col_blocks, B) input vector, blocked.
      n_row_blocks: static output row-block count.

    Returns: (n_row_blocks, B) f32.
    """
    nnzb, b, _ = tiles.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nnzb,),
        in_specs=[
            pl.BlockSpec((1, b, b), lambda t, rows, cols: (t, 0, 0)),
            pl.BlockSpec((1, b), lambda t, rows, cols: (cols[t], 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda t, rows, cols: (rows[t], 0)),
    )
    return pl.pallas_call(
        _bsr_spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_row_blocks, b), jnp.float32),
        interpret=interpret,
    )(rows, cols, tiles, x_blocks)
