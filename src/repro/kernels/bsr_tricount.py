"""Triangle counting as block-sparse A∘(A·A) on the MXU — Pallas TPU kernel.

Ringo counts triangles by intersecting per-node *sorted adjacency vectors*
(scalar compares, OpenMP).  A systolic array cannot branch per element, but
set intersection over a 128-node tile IS a matmul:  for symmetric 0/1
adjacency A,

    #triangles = (1/6) Σ_{I,J} sum( A_IJ ∘ (Σ_K A_IK · A_KJ) )

so we enumerate nonzero **block triples** (I,K)(K,J) with (I,J) nonzero —
the block-level analogue of "for each edge, intersect neighborhoods" — and
feed 128×128×128 dense products to the MXU (2·B³ useful flops each).  The
elementwise mask ∘A_IJ and the global reduction run on the VPU while the
next triple's tiles stream HBM→VMEM (grid is sequential, the scalar output
block stays in VMEM the whole kernel).

This is the hardware adaptation documented in DESIGN.md §2: per-edge
branching → re-blocked arithmetic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bsr_tricount"]


def _tricount_kernel(tij_ref, tik_ref, tkj_ref, a1_ref, a2_ref, a3_ref, acc_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    prod = jnp.dot(a2_ref[0], a3_ref[0], preferred_element_type=jnp.float32)
    masked = a1_ref[0].astype(jnp.float32) * prod
    acc_ref[0, 0] += jnp.sum(masked)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_tricount(tiles: jax.Array, t_ij: jax.Array, t_ik: jax.Array,
                 t_kj: jax.Array, interpret: bool = False) -> jax.Array:
    """Ordered-triple count = 6 × #triangles.

    Args:
      tiles: (nnzb, B, B) symmetric 0/1 adjacency tiles.
      t_ij, t_ik, t_kj: (n_triples,) int32 tile indices per block triple.

    Returns: scalar f32 — divide by 6 for the triangle count.
    """
    n_triples = t_ij.shape[0]
    _, b, _ = tiles.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_triples,),
        in_specs=[
            pl.BlockSpec((1, b, b), lambda t, ij, ik, kj: (ij[t], 0, 0)),
            pl.BlockSpec((1, b, b), lambda t, ij, ik, kj: (ik[t], 0, 0)),
            pl.BlockSpec((1, b, b), lambda t, ij, ik, kj: (kj[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t, ij, ik, kj: (0, 0)),
    )
    out = pl.pallas_call(
        _tricount_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(t_ij, t_ik, t_kj, tiles, tiles, tiles)
    return out[0, 0]
