"""Flash-attention forward Pallas TPU kernel — the §Perf follow-up.

The pure-XLA chunked attention (models/attention.py) materializes f32
(q_chunk × k_chunk) score tiles in HBM between fusions; §Roofline shows
they dominate the memory term of every attention arch.  This kernel keeps
the running (acc, m, l) state AND the score tile in VMEM for the entire
query block — HBM traffic collapses to the q/k/v/o streams:

    arithmetic intensity:  ~14 flops/B (XLA chunks)  →  ~2·q_chunk/6 ≈ 170
    (past the v5e ridge of 240 only for q_chunk ≥ 720; at the default 512
    it still cuts the attention memory term ~12×).

Grid: (batch·heads, n_q_blocks, n_k_blocks), k innermost (sequential on
TPU) so the VMEM scratch carries across k steps.  Causality is enforced
per-tile with an index mask; fully-masked tiles are skipped via
``@pl.when`` (no MXU issue, though the blocks still occupy grid steps —
the XLA-level triangle skip in models/attention.py removes them from the
grid entirely, which is why both exist).

Forward only: training uses the XLA path (autodiff through a Pallas call
needs a custom VJP kernel — documented follow-up); serving (prefill) is
where the memory term hurts most anyway.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, q_chunk: int, k_chunk: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (not causal) or True  # tile-level skip below

    @pl.when((not causal) or (ki * k_chunk <= qi * q_chunk + q_chunk - 1))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (qc, D)
        k = k_ref[0].astype(jnp.float32)                  # (kc, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * q_chunk + jax.lax.broadcasted_iota(
                jnp.int32, (q_chunk, k_chunk), 0)
            kpos = ki * k_chunk + jax.lax.broadcasted_iota(
                jnp.int32, (q_chunk, k_chunk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_chunk", "k_chunk",
                                             "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, q_chunk: int = 512,
                        k_chunk: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q,k,v: (B, S, H, D) with equal head counts (repeat GQA first).

    Returns (B, S, H, D); accumulation in f32, output in q.dtype.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    while sq % q_chunk:
        q_chunk //= 2
    while sk % k_chunk:
        k_chunk //= 2
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = 1.0 / (d ** 0.5)

    # (B, S, H, D) -> (B*H, S, D) streams
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)

    kernel = functools.partial(_flash_kernel, causal=causal,
                               q_chunk=q_chunk, k_chunk=k_chunk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_chunk, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, k_chunk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, k_chunk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_chunk, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_chunk, d), jnp.float32),
            pltpu.VMEM((q_chunk, 1), jnp.float32),
            pltpu.VMEM((q_chunk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
