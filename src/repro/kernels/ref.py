"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["bsr_spmv_ref", "bsr_tricount_ref", "segment_sum_chunked_ref",
           "bsr_to_dense"]


def bsr_to_dense(tiles, rows, cols, n_row_blocks: int, n_col_blocks: int) -> jnp.ndarray:
    """Assemble a dense matrix from BSR tiles (duplicate tiles accumulate)."""
    nnzb, b, _ = tiles.shape
    dense = np.zeros((n_row_blocks * b, n_col_blocks * b), np.float32)
    tiles_np = np.asarray(tiles, dtype=np.float32)
    rows_np = np.asarray(rows)
    cols_np = np.asarray(cols)
    for t in range(nnzb):
        r, c = int(rows_np[t]), int(cols_np[t])
        dense[r * b:(r + 1) * b, c * b:(c + 1) * b] += tiles_np[t]
    return jnp.asarray(dense)


def bsr_spmv_ref(tiles, rows, cols, x_blocks, n_row_blocks: int) -> jax.Array:
    """Dense assemble + matmul."""
    n_col_blocks, b = x_blocks.shape
    dense = bsr_to_dense(tiles, rows, cols, n_row_blocks, n_col_blocks)
    y = dense @ x_blocks.reshape(-1).astype(jnp.float32)
    return y.reshape(n_row_blocks, b)


def bsr_tricount_ref(tiles, rows, cols, n_blocks: int) -> jax.Array:
    """6 × #triangles = sum(A ∘ (A @ A)) for symmetric 0/1 A."""
    a = bsr_to_dense(tiles, rows, cols, n_blocks, n_blocks)
    return jnp.sum(a * (a @ a))


def segment_sum_chunked_ref(vals, local_ids, chunk_block, n_out_blocks: int) -> jax.Array:
    """Scatter-add oracle over the same chunked layout."""
    c, l = vals.shape
    b = 128
    seg = chunk_block[:, None] * b + jnp.minimum(local_ids, b)  # pad -> block*b+b
    flat_seg = seg.reshape(-1)
    flat_val = vals.reshape(-1).astype(jnp.float32)
    valid = (local_ids < b).reshape(-1)
    out = jax.ops.segment_sum(jnp.where(valid, flat_val, 0.0),
                              jnp.where(valid, flat_seg, 0),
                              num_segments=n_out_blocks * b)
    return out.reshape(n_out_blocks, b)
