"""Per-op rolling-window SLO tracking: objectives, error budgets, burn rate.

Ringo's pitch (§2.1) is a latency contract on a shared machine; PR 7 made
latency *measurable*, this module makes it *judgeable*.  An
:class:`Objective` says "requests for this op should finish within
``latency_ms``, and at most ``error_budget`` of them may be bad (slow,
errored, or expired) over the rolling window".  The tracker turns the
stream of completions into a **burn rate** — bad fraction divided by the
budget — and a three-level verdict per op and overall:

* ``ok``        — burn rate below ``degraded_burn`` (default 1.0: within
  budget);
* ``degraded``  — budget being consumed faster than allotted;
* ``breaching`` — burn rate at or past ``breach_burn`` (default 2.0).

Two feeds, per the "no new hot-path instrumentation" rule:

* :meth:`observe` is called once per request *at completion time* by the
  flight recorder (which the scheduler already calls) — one dict update in
  a time-bucketed ring, nothing on the submit/execute path;
* :meth:`tick` folds **registry snapshot deltas** (``service.*`` counters,
  ``bench.latency_ms``/``sched.*`` histogram bucket counts) into the same
  window, so process-wide rejected/expired volume is judged even for
  requests that never produced a per-op completion.

The window is a ring of ``n_buckets`` time buckets spanning ``window_s``
seconds, advanced lazily from an injectable clock (tests drive window-
boundary math with a fake clock).  Verdicts have **hysteresis**: they
escalate immediately but de-escalate only after ``clear_ticks``
consecutive healthier evaluations, so a flapping burn rate cannot whipsaw
admission control.  :meth:`should_shed` is the cheap cached query the
scheduler uses when ``AdmissionPolicy(slo_shed=True)`` is set.

Everything returned by :meth:`health` / :meth:`report` is a plain tree of
scalars/dicts/lists — wire-codec- and JSON-safe by construction.
"""

from __future__ import annotations

import threading
import time
from bisect import bisect_left
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from .metrics import DEFAULT_BUCKETS_MS, Registry, _quantile

__all__ = ["Objective", "SLOTracker"]

_LEVELS = {"ok": 0, "degraded": 1, "breaching": 2}
_NAMES = {v: k for k, v in _LEVELS.items()}

#: registry counters folded into the window by :meth:`SLOTracker.tick`
_TICK_COUNTERS = ("service.requests", "service.rejected", "service.expired",
                  "sched.admitted", "sched.rejected", "sched.expired")
#: registry histograms whose bucket-count deltas ride along in the window
_TICK_HISTOGRAMS = ("bench.latency_ms", "sched.queued_ms", "sched.engine_ms")

_EDGES = DEFAULT_BUCKETS_MS


@dataclass
class Objective:
    """One op's service-level objective.

    ``latency_ms`` is the per-request threshold (a completion slower than
    this is "bad"); ``error_budget`` the tolerated bad fraction over the
    window; ``quantile`` which windowed latency percentile health/report
    surfaces alongside the verdict.
    """

    latency_ms: float = 1000.0
    error_budget: float = 0.01
    quantile: float = 0.99

    def as_dict(self) -> Dict[str, float]:
        return {"latency_ms": float(self.latency_ms),
                "error_budget": float(self.error_budget),
                "quantile": float(self.quantile)}


def _new_rec() -> Dict[str, Any]:
    return {"n": 0, "slow": 0, "errors": 0, "expired": 0,
            "latency_sum": 0.0, "latency_counts": [0] * (len(_EDGES) + 1)}


def _new_service_rec() -> Dict[str, Any]:
    return {name: 0 for name in _TICK_COUNTERS}


class SLOTracker:
    """Rolling-window burn-rate tracker with hysteretic verdicts."""

    def __init__(self, registry: Registry, *,
                 window_s: float = 60.0, n_buckets: int = 12,
                 objectives: Optional[Dict[str, Objective]] = None,
                 default: Optional[Objective] = None,
                 degraded_burn: float = 1.0, breach_burn: float = 2.0,
                 clear_ticks: int = 2, shed_refresh_s: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        if n_buckets <= 0 or window_s <= 0:
            raise ValueError("window_s and n_buckets must be positive")
        self._registry = registry
        self.window_s = float(window_s)
        self.n_buckets = int(n_buckets)
        self._bucket_s = self.window_s / self.n_buckets
        self.default_objective = default or Objective()
        self.degraded_burn = float(degraded_burn)
        self.breach_burn = float(breach_burn)
        self.clear_ticks = int(clear_ticks)
        self.shed_refresh_s = float(shed_refresh_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._objectives: Dict[str, Objective] = dict(objectives or {})
        # ring of {"idx": int bucket index, "ops": {op: rec},
        #          "service": rec} — advanced lazily on every touch
        self._buckets: deque = deque()
        self._verdicts: Dict[str, Tuple[str, int]] = {}
        self._shedding: set = set()
        self._last_snap: Optional[Dict[str, Any]] = None
        self._health_at: Optional[float] = None

    # -- objectives ---------------------------------------------------------
    def objective_for(self, op: str) -> Objective:
        return self._objectives.get(op, self.default_objective)

    def set_objective(self, op: str, *, latency_ms: Optional[float] = None,
                      error_budget: Optional[float] = None,
                      quantile: Optional[float] = None) -> Objective:
        """Create or tighten one op's objective; omitted fields keep the
        current (or default) value."""
        with self._lock:
            cur = self._objectives.get(op, self.default_objective)
            obj = Objective(
                latency_ms=cur.latency_ms if latency_ms is None
                else float(latency_ms),
                error_budget=cur.error_budget if error_budget is None
                else float(error_budget),
                quantile=cur.quantile if quantile is None
                else float(quantile))
            self._objectives[op] = obj
            # objective changed -> cached shed verdicts are stale
            self._health_at = None
        return obj

    # -- window plumbing ----------------------------------------------------
    def _advance_locked(self, now: float) -> Dict[str, Any]:
        idx = int(now // self._bucket_s)
        if not self._buckets or self._buckets[-1]["idx"] != idx:
            self._buckets.append({"idx": idx, "ops": {}, "service": None})
        cutoff = idx - self.n_buckets
        while self._buckets and self._buckets[0]["idx"] <= cutoff:
            self._buckets.popleft()
        return self._buckets[-1]

    # -- feeds --------------------------------------------------------------
    def observe(self, op: str, latency_ms: float, *, error: bool = False,
                expired: bool = False) -> None:
        """One completed request (called at completion time, off the hot
        submit/execute path)."""
        if not self._registry.enabled:
            return
        obj = self.objective_for(op)
        lat = float(latency_ms or 0.0)
        now = self._clock()
        with self._lock:
            bucket = self._advance_locked(now)
            rec = bucket["ops"].get(op)
            if rec is None:
                rec = bucket["ops"][op] = _new_rec()
            rec["n"] += 1
            rec["latency_sum"] += lat
            rec["latency_counts"][bisect_left(_EDGES, lat)] += 1
            if error:
                rec["errors"] += 1
            elif expired:
                rec["expired"] += 1
            elif lat > obj.latency_ms:
                rec["slow"] += 1

    def tick(self, snapshot: Optional[Dict[str, Any]] = None
             ) -> Dict[str, Any]:
        """Fold registry snapshot deltas into the current window bucket.

        Returns the computed delta (counter increments and per-histogram
        ``{"buckets", "counts", "count"}`` bucket-count deltas) — also what
        lands in the window's service record.  ``max(0, ...)`` guards make
        a registry reset between ticks read as "no traffic", not negative.
        """
        if not self._registry.enabled:
            return {}
        snap = snapshot if snapshot is not None else self._registry.snapshot()
        prev = self._last_snap or {}
        delta: Dict[str, Any] = {}
        for name in _TICK_COUNTERS:
            cur = (snap.get(name) or {}).get("value", 0)
            old = (prev.get(name) or {}).get("value", 0)
            delta[name] = max(0, int(cur) - int(old))
        for name in _TICK_HISTOGRAMS:
            cur = snap.get(name)
            if not cur or cur.get("type") != "histogram":
                continue
            pc = (prev.get(name) or {}).get("counts") or []
            counts = [max(0, c - (pc[i] if i < len(pc) else 0))
                      for i, c in enumerate(cur["counts"])]
            delta[name] = {"buckets": list(cur["buckets"]),
                           "counts": counts, "count": sum(counts)}
        self._last_snap = snap
        now = self._clock()
        with self._lock:
            bucket = self._advance_locked(now)
            svc = bucket["service"]
            if svc is None:
                svc = bucket["service"] = _new_service_rec()
            for name in _TICK_COUNTERS:
                svc[name] += delta[name]
        return delta

    # -- aggregation --------------------------------------------------------
    def _window_locked(self) -> Tuple[Dict[str, Dict[str, Any]],
                                      Dict[str, int]]:
        ops: Dict[str, Dict[str, Any]] = {}
        svc = _new_service_rec()
        for bucket in self._buckets:
            for op, rec in bucket["ops"].items():
                agg = ops.get(op)
                if agg is None:
                    agg = ops[op] = _new_rec()
                agg["n"] += rec["n"]
                agg["slow"] += rec["slow"]
                agg["errors"] += rec["errors"]
                agg["expired"] += rec["expired"]
                agg["latency_sum"] += rec["latency_sum"]
                lc = agg["latency_counts"]
                for i, c in enumerate(rec["latency_counts"]):
                    lc[i] += c
            if bucket["service"]:
                for name, v in bucket["service"].items():
                    svc[name] += v
        return ops, svc

    def _hysteresis_locked(self, key: str, raw: str) -> str:
        lvl = _LEVELS[raw]
        prev, streak = self._verdicts.get(key, ("ok", 0))
        plvl = _LEVELS[prev]
        if lvl >= plvl:
            self._verdicts[key] = (raw, 0)
            return raw
        streak += 1
        if streak >= self.clear_ticks:
            self._verdicts[key] = (raw, 0)
            return raw
        self._verdicts[key] = (prev, streak)
        return prev

    def _burn(self, bad: int, n: int, obj: Objective
              ) -> Tuple[float, float]:
        frac = (bad / n) if n else 0.0
        if obj.error_budget > 0:
            burn = frac / obj.error_budget
        else:
            burn = float("inf") if bad else 0.0
        return frac, burn

    # -- verdicts -----------------------------------------------------------
    def health(self) -> Dict[str, Any]:
        """The machine-readable verdict: overall + per-op status, burn
        rates, and human-parseable ``reasons`` strings."""
        if not self._registry.enabled:
            return {"status": "ok", "enabled": False, "ops": {},
                    "reasons": [], "window_s": self.window_s}
        self.tick()
        ops_out: Dict[str, Any] = {}
        reasons: list = []
        with self._lock:
            self._advance_locked(self._clock())
            ops, svc = self._window_locked()
            worst = 0
            for op in sorted(ops):
                rec = ops[op]
                obj = self.objective_for(op)
                bad = rec["slow"] + rec["errors"] + rec["expired"]
                frac, burn = self._burn(bad, rec["n"], obj)
                raw = ("breaching" if burn >= self.breach_burn else
                       "degraded" if burn >= self.degraded_burn else "ok")
                verdict = self._hysteresis_locked(op, raw)
                # overall takes the *raw* level (it has hysteresis of its
                # own) — stacking per-op and overall hysteresis would make
                # the service verdict clear two windows late
                worst = max(worst, _LEVELS[raw])
                p = _quantile(_EDGES, rec["latency_counts"], rec["n"],
                              obj.quantile)
                op_reasons = []
                if rec["slow"]:
                    op_reasons.append(
                        f"{rec['slow']}/{rec['n']} over "
                        f"{obj.latency_ms:g}ms")
                if rec["errors"]:
                    op_reasons.append(f"{rec['errors']} errors")
                if rec["expired"]:
                    op_reasons.append(f"{rec['expired']} expired")
                ops_out[op] = {
                    "status": verdict, "n": rec["n"], "slow": rec["slow"],
                    "errors": rec["errors"], "expired": rec["expired"],
                    "bad_fraction": round(frac, 6),
                    "burn_rate": round(min(burn, 1e9), 4),
                    "latency_quantile_ms":
                        None if p is None else round(p, 3),
                    "objective": obj.as_dict(),
                    "reasons": op_reasons}
                if verdict != "ok":
                    reasons.append(
                        f"{op}: {verdict} (burn rate {burn:.2f} of budget "
                        f"{obj.error_budget:g}; " + "; ".join(op_reasons)
                        + ")")
            overall = self._hysteresis_locked("_overall", _NAMES[worst])
            # Global shedding keys off *combined* traffic judged against the
            # default budget, not the worst single op: one small breaching op
            # sheds only itself; a fleet-wide burn sheds everything.
            tot_n = sum(r["n"] for r in ops.values())
            tot_bad = sum(r["slow"] + r["errors"] + r["expired"]
                          for r in ops.values())
            cfrac, cburn = self._burn(tot_bad, tot_n, self.default_objective)
            combined_raw = ("breaching" if cburn >= self.breach_burn else
                            "degraded" if cburn >= self.degraded_burn
                            else "ok")
            combined = self._hysteresis_locked("_combined", combined_raw)
            self._shedding = {op for op, o in ops_out.items()
                              if o["status"] == "breaching"}
            if combined == "breaching":
                self._shedding.add("*")
            self._health_at = self._clock()
        return {"status": overall, "window_s": self.window_s,
                "ops": ops_out, "reasons": reasons,
                "combined": {"status": combined, "n": tot_n,
                             "bad_fraction": round(cfrac, 6),
                             "burn_rate": round(min(cburn, 1e9), 4)},
                "service": {k: int(v) for k, v in svc.items()},
                "generated_unix": time.time()}

    def should_shed(self, op: Optional[str] = None) -> bool:
        """Cheap cached query for admission control: is this op (or the
        service overall) breaching?  Recomputes at most every
        ``shed_refresh_s`` seconds."""
        if not self._registry.enabled:
            return False
        at = self._health_at
        if at is None or self._clock() - at > self.shed_refresh_s:
            self.health()
        return "*" in self._shedding or (op is not None
                                         and op in self._shedding)

    # -- reporting ----------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        """Everything :meth:`health` knows plus windowed p50/p99 and mean
        latency per op, and the configured objectives — the ``slo_report``
        RPC payload and the dashboard's data source."""
        if not self._registry.enabled:
            return {"enabled": False, "window_s": self.window_s, "ops": {},
                    "objectives": {}, "service": {}}
        self.tick()
        with self._lock:
            self._advance_locked(self._clock())
            ops, svc = self._window_locked()
            objectives = {op: o.as_dict()
                          for op, o in sorted(self._objectives.items())}
        ops_out = {}
        for op in sorted(ops):
            rec = ops[op]
            obj = self.objective_for(op)
            bad = rec["slow"] + rec["errors"] + rec["expired"]
            frac, burn = self._burn(bad, rec["n"], obj)
            qs = {}
            for q, label in ((0.5, "p50_ms"), (0.99, "p99_ms")):
                v = _quantile(_EDGES, rec["latency_counts"], rec["n"], q)
                qs[label] = None if v is None else round(v, 3)
            ops_out[op] = {
                "n": rec["n"], "slow": rec["slow"], "errors": rec["errors"],
                "expired": rec["expired"], "bad_fraction": round(frac, 6),
                "burn_rate": round(min(burn, 1e9), 4),
                "mean_ms": round(rec["latency_sum"] / rec["n"], 3)
                if rec["n"] else None,
                **qs, "objective": obj.as_dict()}
        return {"enabled": True, "window_s": self.window_s,
                "n_buckets": self.n_buckets, "ops": ops_out,
                "objectives": objectives,
                "default_objective": self.default_objective.as_dict(),
                "thresholds": {"degraded_burn": self.degraded_burn,
                               "breach_burn": self.breach_burn,
                               "clear_ticks": self.clear_ticks},
                "service": {k: int(v) for k, v in svc.items()},
                "generated_unix": time.time()}

    def reset(self) -> None:
        """Test hygiene: drop window data, verdict state, custom
        objectives, and the snapshot baseline."""
        with self._lock:
            self._buckets.clear()
            self._verdicts.clear()
            self._shedding = set()
            self._objectives.clear()
            self._last_snap = None
            self._health_at = None
