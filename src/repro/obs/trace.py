"""Per-request trace spans with Chrome trace-event export.

A **trace id** names one logical request's journey through the stack — it
is minted at the edge (the remote client's ``submit``, or any caller of
:meth:`Tracer.new_trace_id`), rides the wire inside the request payload
(``serve/wire.py``), and every span recorded while serving that request
carries it.  Span *nesting* within a thread propagates through a
``contextvars.ContextVar``: a span opened inside a ``with tracer.span(...)``
block inherits the enclosing span's trace id and parent id automatically,
so the engine never needs to be told which request it is serving.

Cross-thread timing (a request's queued interval starts on the submitting
thread and ends on a scheduler worker) is recorded retroactively with
:meth:`Tracer.add_complete` from the two ``perf_counter`` stamps the
service already keeps — no live span object crosses threads.

Finished spans land in a bounded ring buffer (old spans fall off; tracing
never grows without bound) and :meth:`export_chrome_trace` renders them as
Chrome trace-event JSON (``{"traceEvents": [...]}``) viewable in
``chrome://tracing`` or https://ui.perfetto.dev — optionally filtered to a
single trace id, which is how a remote client fetches a trace of *its own*
requests.  Timestamps are ``perf_counter`` microseconds: monotonic and
shared by every thread in the process, which is all the viewer needs.

Disabled mode is allocation-free: :meth:`Tracer.span` returns a shared
no-op singleton and :meth:`instant`/:meth:`add_complete` return before
building anything.
"""

from __future__ import annotations

import contextvars
import itertools
import json
import os
import secrets
import threading
import time
from collections import deque
from typing import Any, Dict, Optional, Sequence, Tuple

__all__ = ["Tracer", "Span", "NOOP_SPAN"]

_CTX: "contextvars.ContextVar[Optional[Span]]" = contextvars.ContextVar(
    "repro_obs_span", default=None)

# per-process nonce: trace ids minted by a client process can never collide
# with ids minted by the server it talks to
_NONCE = secrets.token_hex(4)
_TRACE_SEQ = itertools.count(1)
_SPAN_SEQ = itertools.count(1)


class _NoopSpan:
    """Shared do-nothing span returned when tracing is disabled."""

    __slots__ = ()
    trace = None
    span_id = 0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **args: Any) -> None:
        pass

    def finish(self, **args: Any) -> None:
        pass


NOOP_SPAN = _NoopSpan()


class Span:
    """One timed interval; record by ``with`` (nests via contextvar) or by
    calling :meth:`finish` directly (no nesting side effects)."""

    __slots__ = ("_tracer", "name", "trace", "traces", "span_id",
                 "parent_id", "cat", "args", "_t0", "_tid", "_token",
                 "_done")

    def __init__(self, tracer: "Tracer", name: str, trace: Optional[str],
                 traces: Tuple[str, ...], parent_id: int, cat: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.trace = trace
        self.traces = traces
        self.span_id = next(_SPAN_SEQ)
        self.parent_id = parent_id
        self.cat = cat
        self.args = args
        self._t0 = time.perf_counter()
        self._tid = threading.get_ident()
        self._token = None
        self._done = False

    def set(self, **args: Any) -> None:
        self.args.update(args)

    def __enter__(self) -> "Span":
        self._token = _CTX.set(self)
        return self

    def __exit__(self, et, ev, tb) -> bool:
        if self._token is not None:
            _CTX.reset(self._token)
            self._token = None
        if et is not None:
            self.args.setdefault("error", et.__name__)
        self.finish()
        return False

    def finish(self, **args: Any) -> None:
        if self._done:
            return
        self._done = True
        if args:
            self.args.update(args)
        self._tracer._append(
            (self.name, "X", self._t0, time.perf_counter() - self._t0,
             self.trace, self.traces, self._tid, self.span_id,
             self.parent_id, self.cat, self.args))


class Tracer:
    """Bounded ring buffer of finished spans + the context machinery.

    Ring overflow is *accounted*, not silent: every span evicted to make
    room bumps :attr:`dropped` (visible in :meth:`stats` and in the
    ``metadata`` block of :meth:`export_chrome_trace`), and the optional
    :attr:`drop_hook` callable fires once per drop — the obs package wires
    it to the ``trace.dropped`` registry counter so exports and dashboards
    can tell "quiet system" from "ring wrapped and ate the evidence".
    """

    def __init__(self, enabled: bool = True, capacity: int = 65536):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._dropped = 0
        #: optional zero-arg callable invoked (outside the ring lock) once
        #: per dropped span; wired to a registry counter by ``obs``
        self.drop_hook = None

    # -- ids / context ------------------------------------------------------
    def new_trace_id(self) -> str:
        return f"t{_NONCE}-{next(_TRACE_SEQ)}"

    def current(self) -> Optional[Span]:
        s = _CTX.get()
        return s if isinstance(s, Span) else None

    def current_trace(self) -> Optional[str]:
        s = _CTX.get()
        return s.trace if s is not None else None

    # -- recording ----------------------------------------------------------
    def span(self, name: str, *, trace: Optional[str] = None,
             traces: Sequence[str] = (), cat: str = "repro",
             **args: Any):
        """New span starting now.  ``with`` it to nest children under it;
        or keep the handle and :meth:`Span.finish` later (same thread or
        another — only ``with`` touches the context)."""
        if not self.enabled:
            return NOOP_SPAN
        parent = _CTX.get()
        if trace is None and parent is not None:
            trace = parent.trace
        return Span(self, name, trace, tuple(traces),
                    parent.span_id if parent is not None else 0, cat,
                    dict(args))

    def instant(self, name: str, *, trace: Optional[str] = None,
                traces: Sequence[str] = (), cat: str = "repro",
                **args: Any) -> None:
        """Zero-duration point event (admission reject, deadline drop)."""
        if not self.enabled:
            return
        parent = _CTX.get()
        if trace is None and parent is not None:
            trace = parent.trace
        self._append((name, "i", time.perf_counter(), 0.0, trace,
                      tuple(traces), threading.get_ident(), next(_SPAN_SEQ),
                      parent.span_id if parent is not None else 0, cat,
                      dict(args)))

    def add_complete(self, name: str, t0_s: float, t1_s: float, *,
                     trace: Optional[str] = None,
                     traces: Sequence[str] = (), cat: str = "repro",
                     **args: Any) -> None:
        """Record a span retroactively from two ``perf_counter`` stamps."""
        if not self.enabled:
            return
        self._append((name, "X", t0_s, max(t1_s - t0_s, 0.0), trace,
                      tuple(traces), threading.get_ident(), next(_SPAN_SEQ),
                      0, cat, dict(args)))

    def _append(self, ev: Tuple) -> None:
        with self._lock:
            dropped = (self._events.maxlen is not None
                       and len(self._events) == self._events.maxlen)
            if dropped:
                self._dropped += 1
            self._events.append(ev)
        if dropped and self.drop_hook is not None:
            self.drop_hook()

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    @property
    def dropped(self) -> int:
        """Spans evicted by ring overflow since the last :meth:`clear`."""
        return self._dropped

    def stats(self) -> Dict[str, Any]:
        """Ring accounting: ``{enabled, capacity, buffered, dropped}``."""
        with self._lock:
            return {"enabled": bool(self.enabled),
                    "capacity": self._events.maxlen,
                    "buffered": len(self._events),
                    "dropped": self._dropped}

    # -- export -------------------------------------------------------------
    def export_chrome_trace(self, path: Optional[str] = None, *,
                            trace: Optional[str] = None) -> Dict[str, Any]:
        """Chrome trace-event JSON document for ``chrome://tracing``.

        ``trace=<id>`` keeps only events carrying that trace id (directly or
        in their ``traces`` membership list — a fused engine call belongs to
        every member request's trace).  Thread idents map to small stable
        ints with ``thread_name`` metadata so the viewer's rows are legible.
        ``path`` additionally writes the JSON to disk.
        """
        with self._lock:
            evs = list(self._events)
            dropped = self._dropped
        out, tids = self._render(evs, trace)
        pid = os.getpid()
        for ident, small in tids.items():
            out.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": small,
                        "args": {"name": f"thread-{ident}"}})
        doc = {"traceEvents": out, "displayTimeUnit": "ms",
               "metadata": {"dropped_events": dropped,
                            "capacity": self._events.maxlen,
                            "buffered": len(evs)}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc

    def events_for_trace(self, trace: str,
                         limit: Optional[int] = None) -> list:
        """Chrome-style event dicts for one trace id, oldest first.

        The flight recorder calls this *at request completion time* to
        freeze a slow/failed request's span tree into an exemplar before
        ring wrap can evict it.  ``limit`` keeps only the newest N events.
        """
        with self._lock:
            evs = list(self._events)
        out, _ = self._render(evs, trace)
        if limit is not None and len(out) > limit:
            out = out[-limit:]
        return out

    def _render(self, evs, trace):
        """Event tuples -> Chrome event dicts (+ tid small-int mapping)."""
        pid = os.getpid()
        tids: Dict[int, int] = {}
        out = []
        for (name, ph, t0, dur, tr, trs, tid, sid, parent, cat,
             args) in evs:
            if trace is not None and tr != trace and trace not in trs:
                continue
            if tid not in tids:
                tids[tid] = len(tids) + 1
            a = {k: _jsonable(v) for k, v in args.items()}
            if tr is not None:
                a["trace"] = tr
            if trs:
                a["traces"] = list(trs)
            a["span_id"] = sid
            if parent:
                a["parent_id"] = parent
            ev: Dict[str, Any] = {"name": name, "ph": ph, "cat": cat,
                                  "ts": round(t0 * 1e6, 3),
                                  "pid": pid, "tid": tids[tid], "args": a}
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            else:
                ev["s"] = "t"
            out.append(ev)
        return out, tids


def _jsonable(v: Any) -> Any:
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)
