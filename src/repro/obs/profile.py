"""Engine profiling: where fixpoint time actually goes.

PR 7's spans say *that* an engine call took 80 ms; this layer says *why*:

* **compile vs execute per backend** — the engine brackets every jitted
  fixpoint runner call with retrace detection (a module-level signature set
  keyed on the runner identity plus the argument shapes/dtypes): the first
  call for a new signature pays trace+lower+compile and lands in
  ``engine.profile.<backend>.compile_ms``; repeat calls land in
  ``engine.profile.<backend>.execute_ms``.  Execute time is dispatch-to-
  return wall time — on the CPU backends used here that is effectively the
  run time, but it is *not* a device-synchronized measurement (the
  authoritative per-request engine time remains ``sched.engine_ms``).
* **per-round frontier phase timing** — the frontier host loop's
  dense/sparse step durations land in
  ``engine.profile.frontier.{dense,sparse}_ms`` (one observation per
  round, measured dispatch-to-stats-fetch so it covers the round's actual
  compute).
* **sharded halo traffic** — the sharded backend runs its whole fixpoint
  inside one ``shard_map`` region, so per-round halo *time* is not
  attributable from the host; what is exact is the per-round halo *bytes*
  (``d * halo_width * itemsize``, the same figure as
  ``ShardPlan.halo_bytes_per_round``) and the whole-loop wall time.  Both
  are recorded, plus total exchanged bytes when the round count is known
  (tol/n_iter modes).

Everything lands in ordinary registry instruments under
``engine.profile.*`` — snapshot/Prometheus/wire exposition come for free —
and :func:`profile_report` renders any snapshot (live, remote, or from a
saved debug bundle) as a text table.

The module is bound to a registry by ``obs/__init__`` (:func:`bind`); all
record calls are no-ops until then and the engine additionally guards them
with ``obs.REGISTRY.enabled``, preserving the zero-cost disabled path.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from .metrics import (BYTE_BUCKETS, DEFAULT_BUCKETS_MS, Registry,
                      quantile_from_snapshot)

__all__ = ["bind", "record_runner", "record_frontier_round",
           "record_sharded", "profile_report"]

_REG: Optional[Registry] = None
_lock = threading.Lock()
_cache: Dict[str, Any] = {}


def bind(registry: Registry) -> None:
    """Attach the profiling instruments to a registry (done once by the
    ``obs`` package for the process-global one)."""
    global _REG
    with _lock:
        _REG = registry
        _cache.clear()


def _hist(name: str, buckets=DEFAULT_BUCKETS_MS):
    h = _cache.get(name)
    if h is None:
        if _REG is None:
            return None
        with _lock:
            h = _cache.get(name)
            if h is None and _REG is not None:
                h = _cache[name] = _REG.histogram(name, buckets)
    return h


def _counter(name: str):
    c = _cache.get(name)
    if c is None:
        if _REG is None:
            return None
        with _lock:
            c = _cache.get(name)
            if c is None and _REG is not None:
                c = _cache[name] = _REG.counter(name)
    return c


def record_runner(backend: str, compiled: bool, dt_ms: float) -> None:
    """One fixpoint runner invocation: ``compiled`` means this call paid a
    trace+compile for a fresh signature (retrace bracketing)."""
    kind = "compile_ms" if compiled else "execute_ms"
    h = _hist(f"engine.profile.{backend}.{kind}")
    if h is not None:
        h.observe(dt_ms)


def record_frontier_round(mode: str, dt_ms: float) -> None:
    """One frontier round's step duration; ``mode`` is ``dense`` or
    ``sparse``."""
    h = _hist(f"engine.profile.frontier.{mode}_ms")
    if h is not None:
        h.observe(dt_ms)


def record_sharded(d: int, halo_bytes_per_round: int, dt_ms: float,
                   rounds: Optional[int] = None) -> None:
    """One sharded fixpoint loop: device count, per-round halo bytes, and
    whole-loop wall time; total bytes when the round count is static."""
    h = _hist("engine.profile.sharded.loop_ms")
    if h is not None:
        h.observe(dt_ms)
    hb = _hist("engine.profile.sharded.halo_bytes_per_round", BYTE_BUCKETS)
    if hb is not None:
        hb.observe(float(halo_bytes_per_round))
    if rounds is not None:
        c = _counter("engine.profile.sharded.halo_bytes_total")
        if c is not None:
            c.inc(int(rounds) * int(halo_bytes_per_round))
        cr = _counter("engine.profile.sharded.rounds")
        if cr is not None:
            cr.inc(int(rounds))


# -- reporting --------------------------------------------------------------

def _fmt(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if v >= 100:
        return f"{v:,.0f}"
    return f"{v:.2f}"


def _hist_row(name: str, snap: Dict[str, Any]) -> tuple:
    n = int(snap.get("count", 0))
    total = float(snap.get("sum", 0.0))
    p50 = quantile_from_snapshot(snap, 0.5) if n else None
    p99 = quantile_from_snapshot(snap, 0.99) if n else None
    mean = (total / n) if n else None
    return (name, n, mean, p50, p99, total)


def profile_report(snapshot: Optional[Dict[str, Any]] = None) -> str:
    """Text table of every ``engine.profile.*`` instrument in a registry
    snapshot (defaults to the bound registry's live snapshot).

    Works identically against a remote server's shipped snapshot or the
    ``metrics`` block of a saved debug bundle — the renderer only needs
    the plain snapshot dict.
    """
    if snapshot is None:
        if _REG is None:
            return "engine profile: no registry bound\n"
        snapshot = _REG.snapshot()
    rows = []
    counters = []
    for name in sorted(snapshot):
        if not name.startswith("engine.profile."):
            continue
        snap = snapshot[name]
        short = name[len("engine.profile."):]
        if snap.get("type") == "histogram":
            rows.append(_hist_row(short, snap))
        else:
            counters.append((short, snap.get("value", 0)))
    lines = ["engine profile"]
    if not rows and not counters:
        lines.append("  (no engine.profile.* samples recorded)")
        return "\n".join(lines) + "\n"
    if rows:
        w = max(len(r[0]) for r in rows)
        lines.append(f"  {'phase':<{w}}  {'count':>7}  {'mean':>10}  "
                     f"{'p50':>10}  {'p99':>10}  {'total':>12}")
        for name, n, mean, p50, p99, total in rows:
            lines.append(f"  {name:<{w}}  {n:>7}  {_fmt(mean):>10}  "
                         f"{_fmt(p50):>10}  {_fmt(p99):>10}  "
                         f"{_fmt(total):>12}")
    for name, v in counters:
        lines.append(f"  {name} = {v:g}")
    # companion engine counters that contextualize the phases
    extras = [n for n in ("engine.frontier.rounds",
                          "engine.frontier.dense_rounds",
                          "engine.frontier.direction_switches",
                          "engine.frontier.retraces",
                          "engine.exec_cache.hits",
                          "engine.exec_cache.misses")
              if n in snapshot]
    if extras:
        lines.append("  --")
        for n in extras:
            lines.append(f"  {n} = {snapshot[n].get('value', 0):g}")
    return "\n".join(lines) + "\n"
