"""Always-on flight recorder: exemplars of slow/failed requests + bundles.

The trace ring answers "what happened recently"; it cannot answer "why was
*that* request at 3 a.m. slow" once it wraps.  The flight recorder closes
that gap by capturing **exemplars at completion time**: when a request
finishes slower than its op's SLO threshold, or errors, or expires, its
full span tree (pulled from the ring *now*, before wrap can evict it),
the engine/sched/service counter deltas since the previous capture, the
current queue depth, and the sched metadata (queued/engine time, cached/
fused flags) are frozen into a bounded per-op store.  Healthy requests
cost one enabled-check plus an SLO window update — nothing is captured.

Feeds (both off the hot submit path, both called with the request already
resolved):

* :meth:`record_completion` — every scheduler completion
  (``Scheduler._done``): ok, error, and expired outcomes;
* :meth:`record_pending` — submit-time resolutions that never reach the
  scheduler (cache hits resolved at submit, input-resolution errors).

:meth:`debug_bundle` assembles the postmortem artifact: metrics snapshot,
Chrome trace, exemplars, SLO health/report, profile report, structured-log
tail, config/env/versions — one JSON-safe dict, optionally written to
disk.  The bundle is pure plain data (scalars/lists/dicts), so it ships
over the wire codec unchanged and ``json.load(json.dump(bundle)) ==
bundle`` holds exactly.
"""

from __future__ import annotations

import os
import platform
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

from . import profile as _profile
from .log import tail as _log_tail
from .metrics import Registry
from .slo import SLOTracker
from .trace import Tracer, _jsonable

__all__ = ["FlightRecorder"]

#: counter prefixes whose deltas ride along in every exemplar
_COUNTER_PREFIXES = ("engine.", "sched.", "service.", "trace.")


def _versions() -> Dict[str, str]:
    out: Dict[str, str] = {"python": platform.python_version()}
    for mod in ("jax", "numpy"):
        try:
            out[mod] = __import__(mod).__version__
        except Exception:
            out[mod] = "unavailable"
    return out


class FlightRecorder:
    """Bounded per-op exemplar store + debug-bundle assembly."""

    def __init__(self, tracer: Tracer, registry: Registry,
                 slo: Optional[SLOTracker] = None, *,
                 per_op_capacity: int = 8, span_limit: int = 160,
                 min_capture_interval_s: float = 0.25):
        self._tracer = tracer
        self._registry = registry
        self._slo = slo
        self.per_op_capacity = int(per_op_capacity)
        self.span_limit = int(span_limit)
        #: floor between captures of merely-*slow* (successful) requests,
        #: per op: a sustained breach means every completion qualifies, and
        #: freezing a span tree costs a ring scan — rate-limiting keeps the
        #: recorder's completion-path cost bounded under exactly the load
        #: that triggers it.  Errors and expiries are exempt (rare, and the
        #: evidence matters most).
        self.min_capture_interval_s = float(min_capture_interval_s)
        self._lock = threading.Lock()
        self._store: Dict[str, deque] = {}
        self._last_counters: Dict[str, float] = {}
        self._last_slow_capture: Dict[str, float] = {}
        self._c_seen = registry.counter("flight.completions")
        self._c_captured = registry.counter("flight.exemplars")
        self._c_throttled = registry.counter("flight.throttled")

    # -- feeds --------------------------------------------------------------
    def record_completion(self, q: Any, *, engine_ms: float = 0.0,
                          expired: bool = False) -> None:
        """Scheduler completion feed; ``q`` is duck-typed as a
        ``QueuedRequest`` (``.op``, ``.session``, ``.pending``) whose
        pending is already resolved."""
        if not self._registry.enabled:
            return
        p = q.pending
        outcome = ("expired" if expired
                   else "error" if p.error is not None else "ok")
        self._record(op=q.op, session=q.session, trace=p.trace,
                     latency_ms=p.latency_ms, queued_ms=p.queued_ms,
                     engine_ms=engine_ms, outcome=outcome, error=p.error,
                     cached=p.cached, fused=p.fused)

    def record_pending(self, pending: Any, *, op: str,
                       session: str) -> None:
        """Submit-time resolutions that bypass the scheduler entirely
        (cache hits resolved at submit, input-resolution errors)."""
        if not self._registry.enabled or not pending.done:
            return
        outcome = "error" if pending.error is not None else "ok"
        self._record(op=op, session=session, trace=pending.trace,
                     latency_ms=pending.latency_ms, queued_ms=None,
                     engine_ms=0.0, outcome=outcome, error=pending.error,
                     cached=pending.cached, fused=pending.fused)

    def _record(self, *, op: str, session: str, trace: Optional[str],
                latency_ms: Optional[float], queued_ms: Optional[float],
                engine_ms: float, outcome: str, error: Any,
                cached: bool, fused: bool) -> None:
        self._c_seen.inc()
        threshold = float("inf")
        if self._slo is not None:
            self._slo.observe(op, latency_ms or 0.0,
                              error=outcome == "error",
                              expired=outcome == "expired")
            threshold = self._slo.objective_for(op).latency_ms
        slow = latency_ms is not None and latency_ms > threshold
        if outcome == "ok" and not slow:
            return
        if outcome == "ok":
            # slow-but-successful: rate-limited per op (see __init__)
            now_m = time.monotonic()
            with self._lock:
                last = self._last_slow_capture.get(op)
                if (last is not None and
                        now_m - last < self.min_capture_interval_s):
                    throttled = True
                else:
                    throttled = False
                    self._last_slow_capture[op] = now_m
            if throttled:
                self._c_throttled.inc()
                return
        # -- exemplar path: rare by construction, so snapshot cost is fine
        spans = (self._tracer.events_for_trace(trace, limit=self.span_limit)
                 if trace else [])
        snap = self._registry.snapshot()
        counters = {name: s["value"] for name, s in snap.items()
                    if s.get("type") == "counter"
                    and name.startswith(_COUNTER_PREFIXES)}
        depth = (snap.get("sched.queue_depth") or {}).get("value", 0)
        exemplar = {
            "op": op, "session": session, "trace": trace,
            "outcome": outcome, "slow": bool(slow),
            "captured_unix": time.time(),
            "latency_ms": None if latency_ms is None
            else round(float(latency_ms), 3),
            "queued_ms": None if queued_ms is None
            else round(float(queued_ms), 3),
            "engine_ms": round(float(engine_ms), 3),
            "cached": bool(cached), "fused": bool(fused),
            "error": None if error is None
            else f"{type(error).__name__}: {error}",
            "slo_latency_ms": None if threshold == float("inf")
            else float(threshold),
            "queue_depth": int(depth),
            "spans": spans,
        }
        with self._lock:
            delta = {name: v - self._last_counters.get(name, 0)
                     for name, v in counters.items()
                     if v != self._last_counters.get(name, 0)}
            self._last_counters = counters
            exemplar["counters_delta"] = delta
            dq = self._store.get(op)
            if dq is None:
                dq = self._store[op] = deque(maxlen=self.per_op_capacity)
            dq.append(_jsonable(exemplar))
        self._c_captured.inc()

    # -- queries ------------------------------------------------------------
    def exemplars(self, op: Optional[str] = None):
        """Exemplars for one op (a list, oldest first) or all ops (a dict
        of lists)."""
        with self._lock:
            if op is not None:
                return list(self._store.get(op, ()))
            return {o: list(d) for o, d in sorted(self._store.items())}

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            per_op = {op: len(d) for op, d in sorted(self._store.items())}
        return {"completions": self._c_seen.value,
                "exemplars": self._c_captured.value,
                "throttled": self._c_throttled.value,
                "per_op": per_op,
                "per_op_capacity": self.per_op_capacity}

    # -- postmortem artifact ------------------------------------------------
    def debug_bundle(self, path: Optional[str] = None, *,
                     trace: Optional[str] = None) -> Dict[str, Any]:
        """One JSON artifact with everything a postmortem needs.

        ``trace`` optionally narrows the embedded Chrome trace to a single
        trace id; exemplars/metrics/SLO state are always global.  ``path``
        additionally writes the JSON to disk.  The returned dict is
        JSON-round-trip exact (tuples already normalized to lists).
        """
        metrics = self._registry.snapshot()
        bundle: Dict[str, Any] = {
            "kind": "repro-debug-bundle", "version": 1,
            "created_unix": time.time(),
            "host": {"pid": os.getpid(),
                     "platform": platform.platform()},
            "versions": _versions(),
            "config": {
                "obs_enabled": bool(self._registry.enabled),
                "tracing_enabled": bool(self._tracer.enabled),
                "env": {k: os.environ[k] for k in sorted(os.environ)
                        if k.startswith("REPRO_")}},
            "health": self._slo.health() if self._slo is not None else None,
            "slo": self._slo.report() if self._slo is not None else None,
            "metrics": metrics,
            "profile": _profile.profile_report(metrics),
            "trace": self._tracer.export_chrome_trace(trace=trace),
            "tracer": self._tracer.stats(),
            "flight": self.stats(),
            "exemplars": self.exemplars(),
            "log_tail": _log_tail(),
        }
        bundle = _jsonable(bundle)
        if path is not None:
            import json
            with open(path, "w") as f:
                json.dump(bundle, f)
        return bundle

    def reset(self) -> None:
        """Test hygiene: drop stored exemplars and the counter baseline."""
        with self._lock:
            self._store.clear()
            self._last_counters = {}
            self._last_slow_capture = {}
