"""Unified observability for the repro stack: metrics, traces, logs.

One module-level :class:`~repro.obs.metrics.Registry` and one
:class:`~repro.obs.trace.Tracer` serve the whole process; every layer
(engine, scheduler, service, server) records into them through the
convenience functions here:

    from repro import obs

    obs.counter("service.requests").inc()
    obs.histogram("sched.engine_ms").observe(dt_ms)
    with obs.span("engine.bfs", trace=tid, batch=4):
        ...                       # children opened here nest automatically

    obs.dump_metrics()            # {"name": {"type": ..., ...}} snapshot
    obs.dump_metrics("prom")      # Prometheus text exposition
    obs.export_chrome_trace("trace.json")   # open in chrome://tracing

Both are **enabled by default** (overhead is benchmarked at <5% on the
fused service workload and gated in CI); set ``REPRO_OBS=0`` in the
environment — or call :func:`disable` — for the zero-cost path: counter
updates return on one attribute check, ``span()`` hands back a shared
no-op singleton, nothing allocates.  ``REPRO_OBS_LOG=<level>`` configures
the structured logger (:mod:`repro.obs.log`; default ``warning``).

Trace ids (:func:`new_trace_id`) are minted at the request edge and ride
the wire (``serve/wire.py``), so a remote client's id shows up on the
server's spans, on result provenance (``ProvRecord.meta``), and filters
:func:`export_chrome_trace` down to that client's own requests.

On top of the raw instruments sits the **judgment layer** (PR 10):

* :data:`SLO` (:class:`~repro.obs.slo.SLOTracker`) — rolling-window
  latency objectives, error budgets, burn rates; :func:`health` is the
  ``ok|degraded|breaching`` verdict, :func:`slo_report` the full window;
* :data:`FLIGHT` (:class:`~repro.obs.flight.FlightRecorder`) — exemplars
  of slow/errored/expired requests frozen at completion time (they
  survive trace-ring wrap) and :func:`debug_bundle` postmortem artifacts;
* :mod:`repro.obs.profile` — ``engine.profile.*`` instruments (compile vs
  execute per backend, frontier round phases, sharded halo traffic) and
  :func:`profile_report`; ``python -m repro.obs.report`` renders the
  dashboard against a live server or a saved bundle.
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional, Union

from . import log as _log
from .log import StructLogger, format_event, get_logger
from .metrics import (BYTE_BUCKETS, COUNT_BUCKETS, DEFAULT_BUCKETS_MS,
                      Counter, Gauge, Histogram, Registry,
                      quantile_from_snapshot)
from .trace import NOOP_SPAN, Span, Tracer
from .slo import Objective, SLOTracker
from .flight import FlightRecorder
from . import profile

__all__ = [
    "REGISTRY", "TRACER", "SLO", "FLIGHT",
    "Registry", "Tracer", "Span", "NOOP_SPAN",
    "Counter", "Gauge", "Histogram", "StructLogger",
    "Objective", "SLOTracker", "FlightRecorder", "profile",
    "DEFAULT_BUCKETS_MS", "COUNT_BUCKETS", "BYTE_BUCKETS",
    "enable", "disable", "enabled",
    "counter", "gauge", "histogram", "quantile_from_snapshot",
    "span", "instant", "add_complete", "new_trace_id", "current_trace",
    "dump_metrics", "export_chrome_trace", "reset",
    "health", "slo_report", "debug_bundle", "profile_report",
    "get_logger", "format_event", "log",
]


def _env_flag(name: str, default: bool = True) -> bool:
    v = os.environ.get(name)
    if v is None or v == "":
        return default
    return v.strip().lower() not in ("0", "false", "no", "off")


_ON = _env_flag("REPRO_OBS", True)

REGISTRY = Registry(enabled=_ON)
TRACER = Tracer(enabled=_ON)

#: process-global SLO tracker and flight recorder (PR 10's judgment layer);
#: both follow REGISTRY.enabled — no separate switch
SLO = SLOTracker(REGISTRY)
FLIGHT = FlightRecorder(TRACER, REGISTRY, slo=SLO)

# account trace-ring overflow in the metrics plane (wired here rather than
# inside trace.py to keep that module free of a metrics import)
TRACER.drop_hook = REGISTRY.counter("trace.dropped").inc

# bind the engine-profiling instruments to the global registry
profile.bind(REGISTRY)


def enable(*, metrics: bool = True, tracing: bool = True) -> None:
    if metrics:
        REGISTRY.enabled = True
    if tracing:
        TRACER.enabled = True


def disable(*, metrics: bool = True, tracing: bool = True) -> None:
    if metrics:
        REGISTRY.enabled = False
    if tracing:
        TRACER.enabled = False


def enabled() -> bool:
    return REGISTRY.enabled or TRACER.enabled


# bound-method shortcuts: obs.counter("x").inc() etc.
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram

span = TRACER.span
instant = TRACER.instant
add_complete = TRACER.add_complete
new_trace_id = TRACER.new_trace_id
current_trace = TRACER.current_trace


def dump_metrics(fmt: str = "json") -> Union[Dict[str, Any], str]:
    """Metrics snapshot: ``"json"`` -> plain dict (wire/JSON-friendly),
    ``"prom"`` -> Prometheus text exposition."""
    if fmt == "json":
        return REGISTRY.snapshot()
    if fmt == "prom":
        return REGISTRY.to_prometheus()
    raise ValueError(f"unknown metrics format {fmt!r}; want 'json' or 'prom'")


def export_chrome_trace(path: Optional[str] = None, *,
                        trace: Optional[str] = None) -> Dict[str, Any]:
    """Chrome trace-event JSON of the span ring buffer (see
    :meth:`repro.obs.trace.Tracer.export_chrome_trace`)."""
    return TRACER.export_chrome_trace(path, trace=trace)


def health() -> Dict[str, Any]:
    """Rolling-window SLO verdict: ``ok|degraded|breaching`` overall and
    per op (see :meth:`repro.obs.slo.SLOTracker.health`)."""
    return SLO.health()


def slo_report() -> Dict[str, Any]:
    """Full SLO window: per-op rates, burn, quantiles, objectives."""
    return SLO.report()


def debug_bundle(path: Optional[str] = None, *,
                 trace: Optional[str] = None) -> Dict[str, Any]:
    """Postmortem artifact: metrics, trace, exemplars, SLO state, profile
    report, log tail, config/versions (see
    :meth:`repro.obs.flight.FlightRecorder.debug_bundle`)."""
    return FLIGHT.debug_bundle(path, trace=trace)


def profile_report() -> str:
    """Text table of the ``engine.profile.*`` instruments."""
    return profile.profile_report(REGISTRY.snapshot())


def reset() -> None:
    """Zero all metric values, drop buffered spans, and clear SLO windows,
    flight-recorder exemplars, and the log tail (test hygiene)."""
    REGISTRY.reset()
    TRACER.clear()
    SLO.reset()
    FLIGHT.reset()
    _log.clear_tail()


#: module-level structured logger for ad-hoc events
log = get_logger("repro")
