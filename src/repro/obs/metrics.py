"""Low-overhead metrics registry: counters, gauges, fixed-bucket histograms.

Design constraints (this sits on the service/engine hot paths):

* **zero-cost when disabled** — every mutating method starts with a plain
  attribute check on the owning registry and returns before touching a lock
  or allocating anything (asserted by ``tests/test_obs.py`` with
  ``sys.getallocatedblocks``);
* **lock-cheap when enabled** — one tiny per-instrument lock held only for
  the couple of integer additions of one update; instrument *lookup*
  (:meth:`Registry.counter` etc.) is a lock-free dict hit after the first
  call, so call sites may either cache the instrument in a module global
  (the engine does) or just look it up each time;
* **snapshot isolation** — :meth:`Registry.snapshot` copies every value
  under its instrument's lock; later updates never mutate a snapshot.

Histograms use **fixed bucket edges** (Prometheus ``le`` semantics: bucket
``i`` counts observations ``<= edges[i]``, with a final +Inf bucket), so
merging/exporting never re-bins and :meth:`Histogram.quantile` can serve
p50/p99 directly from the counts with linear interpolation inside the
bucket — what ``benchmarks/bench_service.py`` reads instead of keeping
private sample lists.  Exposition: :meth:`Registry.snapshot` (plain dict,
wire-friendly) and :meth:`Registry.to_prometheus` (text format).
"""

from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

__all__ = ["Registry", "Counter", "Gauge", "Histogram",
           "DEFAULT_BUCKETS_MS", "COUNT_BUCKETS", "BYTE_BUCKETS",
           "quantile_from_snapshot"]

# latency-ish buckets (milliseconds): sub-0.1ms cache hits up to multi-second
# cold engine calls
DEFAULT_BUCKETS_MS: Tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0)

# size/iteration buckets (powers of two): frontier sizes, batch sizes,
# solver iteration counts
COUNT_BUCKETS: Tuple[float, ...] = tuple(
    float(1 << i) for i in range(0, 21))

# byte-size buckets (powers of four, 64 B .. 1 GiB): result-cache entry and
# plan-family sizes span five orders of magnitude, so quarter-decade steps
# keep the histogram small without flattening the distribution
BYTE_BUCKETS: Tuple[float, ...] = tuple(
    float(1 << i) for i in range(6, 31, 2))


class Counter:
    """Monotonically increasing integer (or float) counter."""

    __slots__ = ("name", "_reg", "_lock", "_v")
    kind = "counter"

    def __init__(self, name: str, reg: "Registry"):
        self.name = name
        self._reg = reg
        self._lock = threading.Lock()
        self._v = 0

    def inc(self, n: int = 1) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v

    def _snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"type": "counter", "value": self._v}

    def _reset(self) -> None:
        with self._lock:
            self._v = 0


class Gauge:
    """Last-written value (queue depth, deficit, resident entries)."""

    __slots__ = ("name", "_reg", "_lock", "_v")
    kind = "gauge"

    def __init__(self, name: str, reg: "Registry"):
        self.name = name
        self._reg = reg
        self._lock = threading.Lock()
        self._v = 0.0

    def set(self, v: float) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self._v = v

    def add(self, n: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v

    def _snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"type": "gauge", "value": self._v}

    def _reset(self) -> None:
        with self._lock:
            self._v = 0.0


class Histogram:
    """Fixed-bucket histogram with Prometheus ``le`` bucket semantics.

    ``counts[i]`` counts observations ``<= edges[i]``; ``counts[-1]`` is the
    +Inf overflow bucket.  Designed for non-negative measurements (latency
    ms, sizes, iteration counts): :meth:`quantile` interpolates from a lower
    edge of 0 for the first bucket.
    """

    __slots__ = ("name", "_reg", "_lock", "edges", "_counts", "_sum", "_n")
    kind = "histogram"

    def __init__(self, name: str, reg: "Registry",
                 buckets: Sequence[float] = DEFAULT_BUCKETS_MS):
        edges = tuple(float(b) for b in buckets)
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"histogram {name!r} needs sorted, non-empty "
                             f"bucket edges; got {buckets!r}")
        self.name = name
        self._reg = reg
        self._lock = threading.Lock()
        self.edges = edges
        self._counts = [0] * (len(edges) + 1)
        self._sum = 0.0
        self._n = 0

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        i = bisect_left(self.edges, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._n += 1

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> Optional[float]:
        """Interpolated quantile from bucket counts; None when degenerate.

        Returns None when the histogram is empty, when every observation
        sits in the zero-anchored first bucket, or when every observation
        overflowed into +Inf — in all three cases no real pair of edges
        brackets the data and any interpolated number (a misleading
        0-adjacent value, or the clamped last edge) would be fabricated.
        Callers fall back to their sample lists (``bench_service.py``) or
        report the absence.  Values in the +Inf bucket of an otherwise
        populated histogram still clamp to the last finite edge — pick the
        bucket layout so the tail you care about is inside it.
        """
        with self._lock:
            counts = list(self._counts)
            n = self._n
        return _quantile(self.edges, counts, n, q)

    def _snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"type": "histogram", "buckets": list(self.edges),
                    "counts": list(self._counts),
                    "sum": self._sum, "count": self._n}

    def _reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.edges) + 1)
            self._sum = 0.0
            self._n = 0


def _quantile(edges: Sequence[float], counts: Sequence[int], n: int,
              q: float) -> Optional[float]:
    if n <= 0:
        return None
    if counts[0] >= n or counts[-1] >= n:
        # Degenerate mass: everything in the zero-anchored first bucket or
        # everything in the +Inf overflow.  Neither has a real edge pair
        # around the data, so interpolation would fabricate a value (a
        # misleading near-zero, or the clamped last edge).  A single
        # interior bucket keeps interpolating — both its edges are real.
        return None
    target = max(min(float(q), 1.0), 0.0) * n
    cum = 0
    lo = 0.0
    for i, c in enumerate(counts):
        hi = edges[i] if i < len(edges) else edges[-1]
        if c and cum + c >= target:
            frac = (target - cum) / c
            return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        cum += c
        if i < len(edges):
            lo = edges[i]
    return float(edges[-1])


def quantile_from_snapshot(snap: Dict[str, Any], q: float
                           ) -> Optional[float]:
    """Quantile from one histogram entry of a :meth:`Registry.snapshot`.

    Lets a *remote* consumer (``bench_service.py`` reading a server's
    metrics over the wire) compute p50/p99 from the shipped bucket counts
    without holding the live instrument.
    """
    if snap.get("type") != "histogram":
        raise TypeError(f"not a histogram snapshot: {snap!r}")
    return _quantile(list(snap["buckets"]), list(snap["counts"]),
                     int(snap["count"]), q)


_KINDS: Dict[str, Type] = {"counter": Counter, "gauge": Gauge,
                           "histogram": Histogram}


class Registry:
    """Named instruments with one shared on/off switch.

    ``counter/gauge/histogram`` create-or-return by name (the same name
    always yields the same instrument; asking for a different kind under an
    existing name raises).  ``enabled`` is read unlocked on every update —
    flipping it mid-flight is safe, at worst an update lands a moment after
    ``disable()``.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._by_name: Dict[str, Any] = {}

    # -- switches -----------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    # -- instrument access --------------------------------------------------
    def _get(self, name: str, cls: Type, *args) -> Any:
        inst = self._by_name.get(name)
        if inst is None:
            with self._lock:
                inst = self._by_name.get(name)
                if inst is None:
                    inst = cls(name, self, *args)
                    self._by_name[name] = inst
        if type(inst) is not cls:
            raise TypeError(f"metric {name!r} is a {type(inst).__name__}, "
                            f"not a {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS_MS) -> Histogram:
        return self._get(name, Histogram, buckets)

    # -- exposition ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Isolated point-in-time copy: ``{name: {type, value|buckets...}}``.

        Flat dicts of scalars and lists only, so the wire codec ships it
        unchanged and JSON serialization is direct.
        """
        with self._lock:
            insts = list(self._by_name.items())
        return {name: inst._snapshot() for name, inst in sorted(insts)}

    def to_prometheus(self) -> str:
        """Prometheus text exposition of :meth:`snapshot` (stdlib only)."""
        lines: List[str] = []
        for name, snap in self.snapshot().items():
            mname = _prom_name(name)
            lines.append(f"# TYPE {mname} {snap['type']}")
            if snap["type"] == "histogram":
                cum = 0
                for edge, c in zip(snap["buckets"], snap["counts"]):
                    cum += c
                    lines.append(f'{mname}_bucket{{le="{edge:g}"}} {cum}')
                cum += snap["counts"][-1]
                lines.append(f'{mname}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{mname}_sum {snap['sum']:g}")
                lines.append(f"{mname}_count {snap['count']}")
            else:
                lines.append(f"{mname} {snap['value']:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        """Zero every instrument's value (instruments stay registered, so
        module-global references held by call sites remain valid)."""
        with self._lock:
            insts = list(self._by_name.values())
        for inst in insts:
            inst._reset()


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return ("repro_" + out) if not out.startswith("repro") else out
