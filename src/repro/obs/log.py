"""Structured logging for the repro stack: one event name + key=value fields.

Replaces the scattered bare ``logging.getLogger(__name__).info("...%s...",
x)`` calls with a uniform shape every consumer (a human tailing stderr, a
log shipper, a test asserting on records) can parse::

    _log = get_logger(__name__)
    _log.info("apply_delta.full_rebuild", new_nodes=3)
    # -> "apply_delta.full_rebuild new_nodes=3"

Configuration is module-level and env-driven: the first :func:`get_logger`
call installs one stderr handler on the ``"repro"`` root logger (unless the
embedding application already configured one) and sets its level from
``REPRO_OBS_LOG`` (``debug`` / ``info`` / ``warning`` / ``error``; default
``warning``, so routine fallback notices stay quiet in tests and benches).
The underlying stdlib loggers stay reachable via ``logging.getLogger`` for
tests and embedders who want their own handlers or levels.

The module also keeps a small in-process **tail buffer** (a bounded deque
fed by a dedicated handler on the ``"repro"`` root): the last few hundred
records that passed the configured level, as plain dicts.  Debug bundles
(:meth:`repro.obs.flight.FlightRecorder.debug_bundle`) embed this tail so a
postmortem artifact carries the log lines surrounding the incident without
anyone having had to redirect stderr in advance.
"""

from __future__ import annotations

import logging
import os
import threading
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["get_logger", "StructLogger", "format_event", "tail",
           "clear_tail"]

_LEVELS = {"debug": logging.DEBUG, "info": logging.INFO,
           "warning": logging.WARNING, "warn": logging.WARNING,
           "error": logging.ERROR, "critical": logging.CRITICAL}

_configured = False
_config_lock = threading.Lock()

# bounded in-process record tail for debug bundles; records that pass the
# configured "repro" level land here as plain dicts regardless of what
# stream/file handlers the embedder installed
_TAIL: deque = deque(maxlen=256)


class _TailHandler(logging.Handler):
    """Appends every record to the bounded module tail; never raises."""

    def emit(self, record: logging.LogRecord) -> None:
        try:
            _TAIL.append({"unix_ts": record.created,
                          "level": record.levelname,
                          "logger": record.name,
                          "message": record.getMessage()})
        except Exception:
            pass


def tail(n: Optional[int] = None) -> List[Dict[str, Any]]:
    """Last ``n`` (default: all buffered) structured-log records, oldest
    first, as plain JSON-ready dicts."""
    _ensure_configured()
    out = list(_TAIL)
    return out[-n:] if n is not None else out


def clear_tail() -> None:
    _TAIL.clear()


def _ensure_configured() -> None:
    global _configured
    if _configured:
        return
    with _config_lock:
        if _configured:
            return
        root = logging.getLogger("repro")
        if not root.handlers:
            h = logging.StreamHandler()
            h.setFormatter(logging.Formatter(
                "%(asctime)s %(levelname)s %(name)s :: %(message)s"))
            root.addHandler(h)
        # the tail handler is additive: installed even when the embedder
        # brought its own handlers, so debug bundles always have a log tail
        if not any(isinstance(h, _TailHandler) for h in root.handlers):
            root.addHandler(_TailHandler())
        lvl = os.environ.get("REPRO_OBS_LOG", "warning").strip().lower()
        root.setLevel(_LEVELS.get(lvl, logging.WARNING))
        _configured = True


def format_event(event: str, fields: Dict[str, Any]) -> str:
    if not fields:
        return event
    parts = []
    for k in sorted(fields):
        v = fields[k]
        parts.append(f"{k}={v!r}" if isinstance(v, str) else f"{k}={v}")
    return event + " " + " ".join(parts)


class StructLogger:
    """Thin structured facade over one stdlib logger."""

    __slots__ = ("_log",)

    def __init__(self, logger: logging.Logger):
        self._log = logger

    @property
    def stdlib(self) -> logging.Logger:
        return self._log

    def _emit(self, level: int, event: str, fields: Dict[str, Any],
              exc_info: bool = False) -> None:
        if self._log.isEnabledFor(level):
            self._log.log(level, format_event(event, fields),
                          exc_info=exc_info)

    def debug(self, event: str, **fields: Any) -> None:
        self._emit(logging.DEBUG, event, fields)

    def info(self, event: str, **fields: Any) -> None:
        self._emit(logging.INFO, event, fields)

    def warning(self, event: str, **fields: Any) -> None:
        self._emit(logging.WARNING, event, fields)

    def error(self, event: str, **fields: Any) -> None:
        self._emit(logging.ERROR, event, fields)

    def exception(self, event: str, **fields: Any) -> None:
        """Error-level event with the active exception's traceback."""
        self._emit(logging.ERROR, event, fields, exc_info=True)


def get_logger(name: str = "repro") -> StructLogger:
    """Structured logger under the ``"repro"`` hierarchy.

    ``get_logger(__name__)`` from inside the package lands on the module's
    natural logger; any other name is nested under ``repro.`` so one root
    handler/level governs everything.
    """
    _ensure_configured()
    if name != "repro" and not name.startswith("repro."):
        name = f"repro.{name}"
    return StructLogger(logging.getLogger(name))
