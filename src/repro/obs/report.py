"""Health + profile dashboard: ``python -m repro.obs.report``.

Renders the judgment layer's state as a terminal dashboard, from either a
**live server** (fetches one debug bundle over the existing tagged-value
wire — no extra protocol) or a **saved bundle** (the artifact
:func:`repro.obs.debug_bundle` wrote), so a postmortem reads identically
to a live health check::

    python -m repro.obs.report --port 7654            # live server
    python -m repro.obs.report --bundle bundle.json   # saved artifact
    python -m repro.obs.report --port 7654 --save bundle.json

Sections: overall verdict + reasons, the per-op SLO table (traffic, burn
rate, windowed quantiles vs objective), the engine profile table
(:func:`repro.obs.profile.profile_report`), flight-recorder exemplars
(most recent per op, with their captured queue depth and counter deltas),
trace-ring accounting (including dropped-span counts), and the log tail.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

from .profile import profile_report

__all__ = ["render_bundle", "main"]


def _fmt_ms(v: Optional[float]) -> str:
    return "-" if v is None else f"{v:.2f}"


def render_health(health: Optional[Dict[str, Any]]) -> List[str]:
    lines = []
    if not health:
        return ["health: unavailable (SLO tracker disabled or absent)"]
    lines.append(f"health: {health.get('status', '?').upper()}  "
                 f"(window {health.get('window_s', '?')}s)")
    for reason in health.get("reasons") or []:
        lines.append(f"  ! {reason}")
    return lines


def render_slo(report: Optional[Dict[str, Any]]) -> List[str]:
    lines = ["slo window"]
    ops = (report or {}).get("ops") or {}
    if not ops:
        lines.append("  (no completed requests in the window)")
        return lines
    w = max(len(op) for op in ops)
    lines.append(f"  {'op':<{w}}  {'n':>6}  {'bad':>5}  {'burn':>8}  "
                 f"{'p50ms':>9}  {'p99ms':>9}  {'objective':>12}")
    for op in sorted(ops):
        r = ops[op]
        bad = r.get("slow", 0) + r.get("errors", 0) + r.get("expired", 0)
        obj = r.get("objective") or {}
        lines.append(
            f"  {op:<{w}}  {r.get('n', 0):>6}  {bad:>5}  "
            f"{r.get('burn_rate', 0):>8.2f}  "
            f"{_fmt_ms(r.get('p50_ms')):>9}  "
            f"{_fmt_ms(r.get('p99_ms')):>9}  "
            f"{obj.get('latency_ms', 0):>10.0f}ms")
    return lines


def render_exemplars(exemplars: Optional[Dict[str, Any]],
                     per_op: int = 2) -> List[str]:
    lines = ["flight recorder"]
    if not exemplars:
        lines.append("  (no exemplars captured — nothing slow or failed)")
        return lines
    for op in sorted(exemplars):
        for ex in list(exemplars[op])[-per_op:]:
            why = ex.get("outcome")
            if why == "ok" and ex.get("slow"):
                why = "slow"
            lines.append(
                f"  {op}: {why}  latency={_fmt_ms(ex.get('latency_ms'))}ms"
                f"  queued={_fmt_ms(ex.get('queued_ms'))}ms"
                f"  engine={_fmt_ms(ex.get('engine_ms'))}ms"
                f"  depth={ex.get('queue_depth')}"
                f"  spans={len(ex.get('spans') or [])}"
                f"  trace={ex.get('trace')}")
            if ex.get("error"):
                lines.append(f"      error: {ex['error']}")
    return lines


def render_bundle(bundle: Dict[str, Any]) -> str:
    """The full dashboard for one debug bundle (live or loaded)."""
    lines: List[str] = []
    created = bundle.get("created_unix")
    lines.append(f"debug bundle v{bundle.get('version', '?')}  "
                 f"created_unix={created}")
    lines.extend(render_health(bundle.get("health")))
    lines.append("")
    lines.extend(render_slo(bundle.get("slo")))
    lines.append("")
    profile = bundle.get("profile")
    if profile is None and bundle.get("metrics"):
        profile = profile_report(bundle["metrics"])
    lines.append((profile or "engine profile\n  (unavailable)").rstrip())
    lines.append("")
    lines.extend(render_exemplars(bundle.get("exemplars")))
    tracer = bundle.get("tracer") or {}
    if tracer:
        lines.append("")
        lines.append(f"trace ring: buffered={tracer.get('buffered')}"
                     f"/{tracer.get('capacity')}  "
                     f"dropped={tracer.get('dropped')}")
    tail = bundle.get("log_tail") or []
    lines.append(f"log tail: {len(tail)} record(s)")
    for rec in tail[-5:]:
        lines.append(f"  [{rec.get('level')}] {rec.get('logger')}: "
                     f"{rec.get('message')}")
    return "\n".join(lines) + "\n"


def _fetch_live(host: str, port: int, save: Optional[str]
                ) -> Dict[str, Any]:
    from ..serve.client import RemoteService
    svc = RemoteService(host=host, port=port)
    try:
        return svc.debug_bundle(path=save)
    finally:
        svc.close()


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render the SLO health + engine profile dashboard "
                    "from a live server or a saved debug bundle.")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--port", type=int, help="live server port")
    src.add_argument("--bundle", help="path to a saved debug-bundle JSON")
    ap.add_argument("--host", default="127.0.0.1",
                    help="live server host (default 127.0.0.1)")
    ap.add_argument("--save", default=None,
                    help="with --port: also save the fetched bundle here")
    args = ap.parse_args(argv)

    if args.bundle:
        with open(args.bundle) as f:
            bundle = json.load(f)
        if bundle.get("kind") != "repro-debug-bundle":
            print(f"error: {args.bundle} is not a repro debug bundle",
                  file=sys.stderr)
            return 2
    else:
        bundle = _fetch_live(args.host, args.port, args.save)
    sys.stdout.write(render_bundle(bundle))
    return 0


if __name__ == "__main__":
    sys.exit(main())
