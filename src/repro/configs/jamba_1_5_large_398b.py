"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H GQA(kv=8) ff24576 v65536,
Mamba:attention 7:1 interleave, MoE 16e top-2 every other layer.
Runs long_500k (sub-quadratic: Mamba state decode + flash-decode attention).
[arXiv:2403.19887; hf]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_every=8,               # 7 mamba + 1 attention per period
    ssm_state_dim=16,
    ssm_expand=2,
    optimizer="adafactor",
    param_dtype="bfloat16",
    source="arXiv:2403.19887 (hf)",
))
