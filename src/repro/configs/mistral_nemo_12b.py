"""mistral-nemo-12b [dense]: 40L d5120 32H GQA(kv=8) ff14336 v131072, 128k ctx.
head_dim 128 (explicit — 5120/32=160 but Nemo uses 128).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407 (hf)",
))
