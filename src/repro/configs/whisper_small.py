"""whisper-small [audio]: enc-dec, conv frontend stubbed (precomputed frame
embeddings per assignment spec).  12 encoder + 12 decoder layers.
[arXiv:2212.04356; unverified]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,              # decoder layers; encoder below
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=52224,           # 51865 padded to 256k alignment for TP
    vocab_unpadded=51865,
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    is_encoder_decoder=True,
    n_enc_layers=12,
    enc_seq_len=1536,         # whisper's 1500 frames padded to the 512-chunk grid
    source="arXiv:2212.04356 (unverified)",
))
