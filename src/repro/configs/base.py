"""Architecture config schema + registry + assigned input shapes.

Every assigned architecture is a frozen :class:`ArchConfig`; the registry
maps ``--arch <id>`` to it.  Each arch carries its own shape set (the
assignment pairs archs with shapes), with family-driven skips:

* ``long_500k`` runs only for sub-quadratic families (ssm / hybrid) — full
  attention at 524 288 context is out of scope per the assignment spec;
* decode shapes are skipped for encoder-only models (none assigned; whisper
  is enc-dec and DOES decode).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "register", "get_config", "list_archs",
           "SHAPES", "runnable_shapes"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned LM shape set (identical across the 10 archs).
SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    # transformer backbone
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "swiglu"            # swiglu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1             # MoE FFN on every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # hybrid / ssm
    attn_every: int = 0            # jamba: one attention layer per this many
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    block_pattern: Tuple[str, ...] = ()   # xlstm: ("mlstm","slstm",...) cycle
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 0           # fixed encoder frames (whisper: 1500)
    # vlm
    n_patches: int = 0             # patch-embedding prefix length
    # vocab padding (vocab_size is padded to a multiple of 256 for TP
    # divisibility; logits past vocab_unpadded are never targeted)
    vocab_unpadded: int = 0
    # MoE implementation: "sorted" (global sort-based routing, baseline) or
    # "expert_tp" (shard_map local bucketing + psum combine — see §Perf)
    moe_impl: str = "sorted"
    # training defaults
    optimizer: str = "adamw"       # adamw | adafactor (giant models)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, h = self.d_model, self.resolved_head_dim
        qkv = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h)
        o = self.n_heads * h * d
        attn = qkv + o
        ffn_mult = 3 if self.act == "swiglu" else 2
        dense_ffn = ffn_mult * d * self.d_ff if self.d_ff else 0
        total = 0
        if self.family == "ssm":  # xlstm blocks
            di = d * self.ssm_expand
            per = 2 * d * di + 2 * di * d  # in/out projections + gates approx
            total += self.n_layers * per
        else:
            for layer in range(self.n_layers):
                is_attn = (self.attn_every == 0) or ((layer % self.attn_every)
                                                     == self.attn_every - 1)
                if is_attn:
                    total += attn
                else:  # mamba mixer
                    di = d * self.ssm_expand
                    total += 2 * d * di + di * d + di * (2 * self.ssm_state_dim + 2)
                use_moe = self.n_experts > 0 and (layer % self.moe_every == 0)
                if use_moe:
                    e_ff = self.d_ff
                    total += self.n_experts * ffn_mult * d * e_ff + d * self.n_experts
                elif self.d_ff:
                    total += dense_ffn
        if self.is_encoder_decoder:
            total += self.n_enc_layers * (attn + dense_ffn)       # encoder
            total += self.n_layers * attn                         # cross-attn
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: experts_per_token of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        ffn_mult = 3 if self.act == "swiglu" else 2
        moe_layers = len([l for l in range(self.n_layers)
                          if l % self.moe_every == 0])
        all_experts = moe_layers * self.n_experts * ffn_mult * d * self.d_ff
        active = moe_layers * self.experts_per_token * ffn_mult * d * self.d_ff
        return full - all_experts + active


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def runnable_shapes(cfg: ArchConfig) -> Dict[str, ShapeSpec]:
    """Shapes this arch runs; skips recorded in DESIGN.md §Arch-applicability."""
    out = {}
    for name, s in SHAPES.items():
        if name == "long_500k" and not cfg.is_subquadratic:
            continue  # full attention at 500k ctx: assignment says skip
        out[name] = s
    return out


def _ensure_loaded() -> None:
    """Import all config modules once so registration side-effects run."""
    from . import (whisper_small, qwen1_5_4b, qwen2_5_3b, starcoder2_15b,      # noqa: F401
                   mistral_nemo_12b, grok_1_314b, qwen3_moe_235b_a22b,
                   jamba_1_5_large_398b, xlstm_350m, internvl2_26b, ringo_graph)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    shrink = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.attn_every or cfg.block_pattern else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq_len=min(cfg.enc_seq_len, 16) if cfg.enc_seq_len else 0,
        n_patches=min(cfg.n_patches, 4) if cfg.n_patches else 0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    if cfg.attn_every:
        shrink["attn_every"] = min(cfg.attn_every, 4)
        shrink["n_layers"] = 2 * shrink["attn_every"]
        shrink["moe_every"] = cfg.moe_every
    shrink.update(overrides)
    return dataclasses.replace(cfg, **shrink)
