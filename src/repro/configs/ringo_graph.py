"""The paper's own workload as a dry-runnable config: distributed PageRank
over a Twitter2010-scale graph (42 M nodes, 1.5 B edges) on the production
mesh — the graph engine's cells next to the LM cells."""

from .base import ArchConfig, register

# Encoded via the generic ArchConfig so the registry/dry-run machinery is
# uniform; the graph fields are carried in `source` and interpreted by
# launch/ringo_cells.py.
CONFIG = register(ArchConfig(
    name="ringo-graph",
    family="graph",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    source="twitter2010: n=41.7M nodes, e=1.47B edges (paper Table 2)",
))
