"""starcoder2-15b [dense]: 40L d6144 48H GQA(kv=4) ff24576 v49152, RoPE.
[arXiv:2402.19173; hf]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    act="gelu",
    norm="layernorm",
    qkv_bias=True,
    source="arXiv:2402.19173 (hf)",
))
