"""internvl2-26b [vlm]: InternLM2-20B backbone 48L d6144 48H GQA(kv=8)
ff16384 v92553 + InternViT frontend STUB (input_specs provides 256
precomputed patch embeddings per the assignment spec).
[arXiv:2404.16821; hf]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92672,           # 92553 padded to 256-multiple for TP
    vocab_unpadded=92553,
    act="swiglu",
    norm="rmsnorm",
    n_patches=256,
    source="arXiv:2404.16821 (hf)",
))
