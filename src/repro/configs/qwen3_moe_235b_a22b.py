"""qwen3-moe-235b-a22b [moe]: 94L d4096 64H GQA(kv=4) per-expert ff1536
v151936, MoE 128e top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,                  # per-expert FFN width
    vocab_size=151936,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    n_experts=128,
    experts_per_token=8,
    optimizer="adafactor",
    param_dtype="bfloat16",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B (hf)",
))
