"""xlstm-350m [ssm]: 24L d1024 4H ff0 v50304 — alternating mLSTM/sLSTM blocks
(paper's 1:1 simplification of the 7:1 placement; DESIGN.md).  Runs
long_500k (O(1) recurrent state decode).  [arXiv:2405.04517; unverified]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                     # xLSTM blocks have no separate FFN
    vocab_size=50304,
    act="gelu",
    norm="layernorm",
    ssm_expand=2,
    block_pattern=("mlstm", "slstm"),
    source="arXiv:2405.04517 (unverified)",
))
