"""grok-1-314b [moe]: 64L d6144 48H GQA(kv=8) ff32768 v131072, MoE 8e top-2.
Adafactor + bf16 params (Adam states would exceed single-pod HBM; see
DESIGN.md §5).  [hf:xai-org/grok-1; unverified]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    act="swiglu",               # grok uses GeGLU: gated 3-matrix FFN
    norm="rmsnorm",
    n_experts=8,
    experts_per_token=2,
    optimizer="adafactor",
    param_dtype="bfloat16",
    source="hf:xai-org/grok-1 (unverified)",
))
