"""Load-aware request scheduler for the interactive service.

The queue between :class:`~repro.serve.graph_service.GraphService` and the
engine — and, per the ROADMAP, the seam where a wire protocol attaches for
cross-process serving later.  Everything that decides *when* and *in what
order* a declarative request reaches the engine lives here; everything that
decides *what the request computes* (input resolution, result cache, fusion
semantics, provenance) stays in the service, which hands this module an
already-prepared :class:`QueuedRequest` and exposes three callbacks
(`_cache_lookup`, `_finish_cached`, `_run_group`).

Three mechanisms, configured by :class:`~repro.serve.policy.SchedulerPolicy`:

* **Admission control** — :meth:`Scheduler.submit` rejects a request whose
  session is at its in-flight quota, or when the global backlog hits the
  queue-depth bound, raising :class:`~repro.serve.policy.RejectedError` with
  a ``retry_after`` derived from the EMA of observed per-request engine
  time.  Requests carrying a deadline are dropped at dispatch (never
  reaching the engine) once it has passed.
* **Fair share** — deficit round robin across sessions, denominated in
  *measured engine milliseconds*.  Each pick tops every waiting session up
  by ``quantum_ms * weight`` and serves the first session in rotation whose
  deficit is in credit; executed work is charged back at its actual cost
  (a coalesced batch splits its cost across the member requests'
  sessions).  A session that recently burned lots of engine time is deep in
  debt and waits it out, so a scan-heavy flood cannot starve interactive
  sessions — yet with the machine otherwise idle the flood runs at full
  speed (top-ups fast-forward when nobody else is waiting; the scheduler is
  work-conserving).
* **Batching windows** — when the popped request is coalescible, compatible
  requests are gathered from *every* session's queue into one engine call.
  In the worker loop (``allow_wait=True``) a loaded scheduler additionally
  holds the batch open for a bounded window so near-simultaneous arrivals
  coalesce too; the window scales with backlog and is exactly zero when the
  queue is empty, leaving idle latency untouched.

Synchronous use (:meth:`drain`, what ``GraphService.flush`` calls) runs the
same decision loop inline with windows disabled — everything fusable is
already queued, so waiting could only lose.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

from .. import obs
from .policy import DeadlineExpired, RejectedError, SchedulerPolicy

__all__ = ["QueuedRequest", "Scheduler"]

# scheduler-level instruments: admission outcomes, backlog depth, the
# queued-vs-engine split every request pays, and batch composition
_C_ADMIT = obs.counter("sched.admitted")
_C_REJECT = obs.counter("sched.rejected")
_C_EXPIRE = obs.counter("sched.expired")
_C_WINDOWS = obs.counter("sched.batch_windows")
_G_DEPTH = obs.gauge("sched.queue_depth")
_H_QUEUED = obs.histogram("sched.queued_ms")
_H_ENGINE = obs.histogram("sched.engine_ms")
_H_BATCH = obs.histogram("sched.batch_size", buckets=obs.COUNT_BUCKETS)


@dataclass
class QueuedRequest:
    """A prepared request waiting for dispatch.

    The service resolves names, canonicalizes params and computes the fusion
    / cache keys at submit time (pinning the object versions the request
    names); the scheduler only ever compares keys and moves these records
    between queues.
    """

    pending: Any                      # graph_service.Pending
    session: str
    op: str
    cache_key: Optional[Tuple] = None
    fuse_key: Optional[Tuple] = None  # None: never coalesced
    payload: Dict[str, Any] = field(default_factory=dict)
    deadline: Optional[float] = None  # absolute perf_counter seconds
    seq: int = 0                      # global arrival order (FIFO mode)


class _SessionState:
    """Queue + deficit + accounting for one session."""

    __slots__ = ("name", "queue", "inflight", "deficit_ms", "recent_ms",
                 "completed", "engine_ms", "rejected", "expired")

    def __init__(self, name: str):
        self.name = name
        self.queue: Deque[QueuedRequest] = deque()
        self.inflight = 0          # queued + executing, admission-bounded
        self.deficit_ms = 0.0      # DRR credit (+) / debt (-)
        self.recent_ms = 0.0       # decayed engine-ms consumption
        self.completed = 0
        self.engine_ms = 0.0
        self.rejected = 0
        self.expired = 0

    def snapshot(self) -> Dict[str, Any]:
        return {"queued": len(self.queue), "inflight": self.inflight,
                "deficit_ms": round(self.deficit_ms, 3),
                "recent_ms": round(self.recent_ms, 3),
                "completed": self.completed,
                "engine_ms": round(self.engine_ms, 3),
                "rejected": self.rejected, "expired": self.expired}


class Scheduler:
    """Admission, ordering and coalescing between submit and the engine."""

    def __init__(self, service: Any, policy: SchedulerPolicy):
        self.service = service
        self.policy = policy
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._sessions: Dict[str, _SessionState] = {}
        self._order: List[str] = []       # session insertion order (RR ring)
        self._ended: set = set()          # forgotten-while-busy, reap later
        self._rr_last: Optional[str] = None
        self._seq = 0
        self._total_queued = 0
        self._est_ms = 50.0               # EMA of per-request engine ms

    # -- introspection ------------------------------------------------------
    def _state(self, name: str) -> _SessionState:
        st = self._sessions.get(name)
        if st is None:
            st = self._sessions[name] = _SessionState(name)
            self._order.append(name)
        return st

    def queued_count(self, session: Optional[str] = None) -> int:
        with self._lock:
            if session is None:
                return self._total_queued
            st = self._sessions.get(session)
            return len(st.queue) if st else 0

    def session_stats(self, session: str) -> Dict[str, Any]:
        """Accounting snapshot; never *creates* state (an unknown name —
        e.g. a remote client probing — must not grow the DRR ring)."""
        with self._lock:
            st = self._sessions.get(session)
            return st.snapshot() if st is not None \
                else _SessionState(session).snapshot()

    def forget_session(self, session: str) -> bool:
        """Drop a session's scheduler state (connection teardown).

        Returns False while the session still has queued or executing work
        — accounting for in-flight requests must survive until
        :meth:`_done` runs for them; the state is marked ended and reaped
        by the final :meth:`_done` instead, so churned connections never
        leak ring entries.
        """
        with self._lock:
            return self._forget_locked(session, mark=True)

    def _forget_locked(self, session: str, mark: bool = False) -> bool:
        st = self._sessions.get(session)
        if st is None:
            self._ended.discard(session)
            return True
        if st.queue or st.inflight:
            if mark:
                self._ended.add(session)
            return False
        del self._sessions[session]
        self._order.remove(session)
        self._ended.discard(session)
        if self._rr_last == session:
            self._rr_last = None
        return True

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until nothing is queued *or executing*; False on timeout.

        :meth:`drain` only runs queued work inline — in worker mode a
        request may be mid-engine on another thread when the queue empties.
        Graceful server shutdown needs both gone before closing sockets,
        so streamed results are never cut off.
        """
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        with self._cond:
            while True:
                busy = self._total_queued or any(
                    st.inflight for st in self._sessions.values())
                if not busy:
                    return True
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(timeout=0.1 if remaining is None
                                else min(remaining, 0.1))

    # -- admission ----------------------------------------------------------
    def submit(self, q: QueuedRequest) -> None:
        """Enqueue or reject; rejection raises before the queue is touched."""
        adm = self.policy.admission
        # opt-in SLO shedding: while the op (or the service overall) is
        # breaching its burn-rate threshold, tighten both admission bounds
        # so backlog drains instead of growing — observability closing the
        # loop into serving.  should_shed() is a cached verdict read, not a
        # health computation, so the common healthy case stays cheap.
        shed = adm.slo_shed and obs.SLO.should_shed(q.op)
        quota = adm.quota_for(q.session)
        depth_bound = adm.max_queue_depth
        if shed:
            quota = max(1, int(quota * adm.shed_factor))
            depth_bound = max(1, int(depth_bound * adm.shed_factor))
        with self._cond:
            st = self._state(q.session)
            if st.inflight >= quota:
                st.rejected += 1
                retry = max(adm.min_retry_after_s,
                            st.inflight * self._est_ms / 1e3)
                self._reject(q, "slo_shed" if shed else "quota", retry)
                raise RejectedError(
                    f"session {q.session!r} is at its in-flight quota "
                    f"({quota})" + (" [slo shedding active]" if shed
                                    else ""), retry)
            if self._total_queued >= depth_bound:
                st.rejected += 1
                retry = max(adm.min_retry_after_s,
                            self._total_queued * self._est_ms / 1e3)
                self._reject(q, "slo_shed" if shed else "queue_depth",
                             retry)
                raise RejectedError(
                    f"service backlog is at its queue-depth bound "
                    f"({depth_bound})" + (" [slo shedding active]" if shed
                                          else ""), retry)
            q.seq = self._seq
            self._seq += 1
            st.inflight += 1
            st.queue.append(q)
            self._total_queued += 1
            _C_ADMIT.inc()
            _G_DEPTH.set(self._total_queued)
            self._cond.notify_all()

    def _reject(self, q: QueuedRequest, reason: str, retry: float) -> None:
        """Admission-reject accounting: service counter + trace instant."""
        self.service._bump("rejected")
        _C_REJECT.inc()
        obs.TRACER.instant("sched.reject", trace=q.pending.trace,
                           op=q.op, session=q.session, reason=reason,
                           retry_after=round(retry, 3))

    # -- selection ----------------------------------------------------------
    def _waiting_locked(self) -> List[_SessionState]:
        return [self._sessions[n] for n in self._order
                if self._sessions[n].queue]

    def _pick_locked(self) -> Optional[QueuedRequest]:
        waiting = self._waiting_locked()
        if not waiting:
            return None
        if self.policy.mode == "fifo":
            st = min(waiting, key=lambda s: s.queue[0].seq)
        else:
            st = self._pick_fair_locked(waiting)
        q = st.queue.popleft()
        self._total_queued -= 1
        _G_DEPTH.set(self._total_queued)
        self._rr_last = st.name
        return q

    def _pick_fair_locked(self, waiting: List[_SessionState]) -> _SessionState:
        """Deficit round robin over the sessions that have queued work."""
        fair = self.policy.fair
        names = [s.name for s in waiting]
        if self._rr_last in names:         # resume rotation after last pick
            i = names.index(self._rr_last)
            waiting = waiting[i + 1:] + waiting[:i + 1]
        # one top-up per pick (≈ one DRR visit of every waiting session)...
        for s in waiting:
            w = max(fair.weight_for(s.name), 1e-6)
            s.deficit_ms = min(s.deficit_ms + fair.quantum_ms * w,
                               fair.burst_ms)
        for s in waiting:
            if s.deficit_ms > 0:
                return s
        # ...and when every session is in debt (nothing dispatchable), fast-
        # forward the idle top-up rounds in closed form instead of spinning:
        # the scheduler stays work-conserving without a busy loop.
        passes = []
        for s in waiting:
            w = max(fair.weight_for(s.name), 1e-6)
            passes.append(int(-s.deficit_ms // (fair.quantum_ms * w)) + 1)
        k = max(1, min(passes))
        for s in waiting:
            w = max(fair.weight_for(s.name), 1e-6)
            s.deficit_ms = min(s.deficit_ms + k * fair.quantum_ms * w,
                               fair.burst_ms)
        for s in waiting:
            if s.deficit_ms > 0:
                return s
        return waiting[0]                  # float-fuzz fallback

    # -- coalescing ---------------------------------------------------------
    def _collect_locked(self, q: QueuedRequest, cap: int
                        ) -> List[QueuedRequest]:
        """Pull every queued request sharing ``q.fuse_key`` (up to cap)."""
        out: List[QueuedRequest] = []
        for name in self._order:
            st = self._sessions[name]
            if not st.queue:
                continue
            kept: Deque[QueuedRequest] = deque()
            while st.queue:
                item = st.queue.popleft()
                if len(out) < cap and item.fuse_key == q.fuse_key:
                    out.append(item)
                    self._total_queued -= 1
                else:
                    kept.append(item)
            st.queue = kept
        if out:
            with self._lock:
                _G_DEPTH.set(self._total_queued)
        return out

    def _gather(self, q: QueuedRequest, allow_wait: bool
                ) -> List[QueuedRequest]:
        """Coalesce compatible requests; optionally hold a batching window.

        The window only opens from the worker loop (``allow_wait``) and only
        under load: with an empty residual queue it is zero, so an idle
        single request executes immediately.  Synchronous drains never wait
        — every coalescible request is already queued.
        """
        bp = self.policy.batch
        group = [q]
        with self._cond:
            group += self._collect_locked(q, bp.max_batch - len(group))
            if allow_wait and len(group) < bp.max_batch:
                window = bp.effective_window_s(self._total_queued)
                if window > 0:
                    self.service._bump("batch_windows")
                    _C_WINDOWS.inc()
                    deadline = time.perf_counter() + window
                    while True:
                        remaining = deadline - time.perf_counter()
                        if remaining <= 0 or len(group) >= bp.max_batch:
                            break
                        self._cond.wait(timeout=remaining)
                        group += self._collect_locked(
                            q, bp.max_batch - len(group))
        return group

    # -- accounting ---------------------------------------------------------
    def _done(self, q: QueuedRequest, engine_ms: float,
              completed: bool = True) -> None:
        # every caller resolves q.pending before calling _done (cache hits,
        # group execution, error paths, expiry), so this is the single
        # completion seam: the flight recorder sees each request exactly
        # once with its final latency/outcome, feeds the SLO window, and
        # captures an exemplar if the request was slow, errored, or expired
        obs.FLIGHT.record_completion(q, engine_ms=engine_ms,
                                     expired=not completed)
        fair = self.policy.fair
        with self._cond:
            st = self._state(q.session)
            st.inflight -= 1
            if completed:            # expired drops resolve but don't count
                st.completed += 1
            st.engine_ms += engine_ms
            st.recent_ms = st.recent_ms * fair.decay + engine_ms
            if engine_ms > 0:
                st.deficit_ms = max(st.deficit_ms - engine_ms, -fair.floor_ms)
                self._est_ms = 0.8 * self._est_ms + 0.2 * engine_ms
            if q.session in self._ended:   # connection gone: reap when idle
                self._forget_locked(q.session)
            self._cond.notify_all()

    def _expire(self, q: QueuedRequest) -> None:
        with self._lock:
            self._state(q.session).expired += 1
        self.service._bump("expired")
        _C_EXPIRE.inc()
        obs.TRACER.instant("sched.expired", trace=q.pending.trace,
                           op=q.op, session=q.session)
        q.pending._resolve(error=DeadlineExpired(
            f"request {q.op!r} from session {q.session!r} spent its "
            f"deadline in the queue; dropped before execution"))
        self._done(q, 0.0, completed=False)

    # -- the decision loop --------------------------------------------------
    def step(self, *, allow_wait: bool = False) -> bool:
        """Dispatch one scheduling decision; False when nothing is queued.

        One decision is one of: an expired request dropped, a cache hit
        served, or one engine call (single request or coalesced batch).
        """
        with self._cond:
            q = self._pick_locked()
        if q is None:
            return False
        self._process(q, allow_wait)
        return True

    def _process(self, q: QueuedRequest, allow_wait: bool) -> None:
        now = time.perf_counter()
        if q.deadline is not None and now > q.deadline:
            self._expire(q)
            return
        q.pending.dispatched_at = now
        self._queued_span(q)
        hit, found = self.service._cache_lookup(q)
        if found:
            self.service._finish_cached(q, hit)
            self._done(q, 0.0)
            return
        group = [q]
        if q.fuse_key is not None:
            group = self._filter_group(self._gather(q, allow_wait))
        if not group:
            return                       # every member expired or hit cache
        _H_BATCH.observe(len(group))
        with self._lock:                 # DRR state that won this pick
            deficit_ms = round(self._state(q.session).deficit_ms, 3)
        t0 = time.perf_counter()
        sp = obs.TRACER.span(
            "sched.execute", trace=q.pending.trace,
            traces=[m.pending.trace for m in group
                    if m.pending.trace is not None],
            op=q.op, batch=len(group),
            sessions=sorted({m.session for m in group}),
            deficit_ms=deficit_ms)
        # memory-manager bracket: the group's graphs are pinned against plan
        # eviction while the engine call is in flight (an evicted member
        # re-derives transparently, but never out from under a running
        # batch); the end hook runs the accounting/eviction pass.
        self.service._mem_begin(group)
        try:
            with sp:
                engine_ms = self.service._run_group(group)
                sp.set(engine_ms=round(engine_ms, 3))
        except Exception as e:           # resolve, don't poison the loop
            engine_ms = (time.perf_counter() - t0) * 1e3
            for m in group:
                if not m.pending.done:
                    m.pending._resolve(error=e)
        finally:
            self.service._mem_end(group)
        _H_ENGINE.observe(engine_ms)
        for m in group:
            self._done(m, engine_ms / max(len(group), 1))

    def _queued_span(self, q: QueuedRequest) -> None:
        """Record the dispatch wait retroactively from the two stamps the
        Pending already keeps (submit happened on another thread)."""
        p = q.pending
        if p.dispatched_at is None:
            return
        _H_QUEUED.observe((p.dispatched_at - p.submitted_at) * 1e3)
        obs.TRACER.add_complete("sched.queued", p.submitted_at,
                                p.dispatched_at, trace=p.trace, op=q.op,
                                session=q.session)

    def _filter_group(self, group: List[QueuedRequest]
                      ) -> List[QueuedRequest]:
        """Deadline + cache screening for gathered batch members."""
        now = time.perf_counter()
        out = []
        for m in group:
            if m.deadline is not None and now > m.deadline:
                self._expire(m)
                continue
            if m is not group[0]:
                m.pending.dispatched_at = now
                self._queued_span(m)
                hit, found = self.service._cache_lookup(m)
                if found:
                    self.service._finish_cached(m, hit)
                    self._done(m, 0.0)
                    continue
            out.append(m)
        return out

    def drain(self) -> None:
        """Run queued work to completion, inline, windows closed."""
        while self.step(allow_wait=False):
            pass

    def run_loop(self, stop: threading.Event) -> None:
        """Worker loop: serve until ``stop`` is set, sleeping when idle."""
        while not stop.is_set():
            if not self.step(allow_wait=True):
                with self._cond:
                    if self._total_queued == 0 and not stop.is_set():
                        self._cond.wait(timeout=0.02)
