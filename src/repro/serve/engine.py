"""Batched serving loop: prefill + decode with a static KV budget.

A minimal continuous-batching engine: requests are packed into a fixed
(batch, max_seq) budget; finished slots are refilled from the queue.  The
decode step is the jitted ``model.decode_step`` (same function the dry-run
lowers at production shapes).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as model

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass
class ServeConfig:
    batch: int = 8
    max_seq: int = 256
    temperature: float = 0.0
    eos_token: int = -1         # -1: run to max_new_tokens


class Engine:
    def __init__(self, cfg, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, cfg, c, t, pos))

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 32
                 ) -> List[List[int]]:
        """Greedy (or sampled) continuation for a batch of prompts."""
        cfg, scfg = self.cfg, self.scfg
        b = len(prompts)
        assert b <= scfg.batch
        plen = max(len(p) for p in prompts)
        toks = np.zeros((scfg.batch, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = model.prefill(self.params, cfg, batch, scfg.max_seq)
        out = [list(p) for p in prompts]
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        pos = plen
        key = jax.random.PRNGKey(0)
        for step in range(max_new_tokens):
            for i in range(b):
                out[i].append(int(cur[i, 0]))
            logits, cache = self._decode(self.params, cache, cur,
                                         jnp.int32(pos))
            if scfg.temperature > 0:
                key, sub = jax.random.split(key)
                cur = jax.random.categorical(
                    sub, logits[:, -1] / scfg.temperature)[:, None].astype(jnp.int32)
            else:
                cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            pos += 1
            if pos >= scfg.max_seq:
                break
        return out
