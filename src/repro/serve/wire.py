"""Wire protocol for cross-process serving — versioned, length-prefixed
binary frames, no pickle anywhere.

Ringo's front door (§2.1): many analyst *processes* share one big-memory
engine, so declarative requests and their results must cross a socket.  The
codec here is deliberately small and explicit:

* **Frames.**  Every message is one frame: a fixed 16-byte header
  (magic ``RW``, protocol version, frame type, request id, payload length)
  followed by the payload.  Request ids tie responses to requests — the
  server streams :class:`~repro.serve.graph_service.Pending` resolutions
  back in *completion* order, not call order.  A bad magic or an unknown
  protocol version raises :class:`WireError` immediately (the reader never
  guesses at misaligned bytes).
* **Values.**  The payload is one tagged value tree: None/bool/int/float/
  str/bytes, lists, tuples, string-keyed dicts, numeric ndarrays, and the
  two workspace object kinds (:class:`~repro.core.table.Table`,
  :class:`~repro.core.graph.Graph`).  There is no executable content and no
  pickle: an array is ``dtype + shape`` header plus raw bytes, and decoding
  wraps the received buffer **zero-copy** (``np.frombuffer`` on a memoryview
  of the frame; the returned arrays are marked read-only because they alias
  it).  On the send side, array buffers above a threshold are emitted as
  separate scatter-gather chunks (``sendmsg``) instead of being copied into
  the stream.
* **Typed errors.**  Error frames carry the payload produced by
  :func:`repro.serve.policy.error_to_wire`, so admission control crosses the
  wire intact: a rejected submit raises :class:`RejectedError` with its
  ``retry_after`` on the client, a queue-expired request raises
  :class:`DeadlineExpired`.
* **Provenance.**  :func:`pack_object` ships a result with its version
  token and :class:`~repro.core.provenance.ProvRecord` chain (as plain
  data); :func:`unpack_object` rebuilds the object and *adopts* the chain
  (:func:`~repro.core.provenance.adopt_records`), so ``export_script`` works
  on remotely computed objects exactly as on local ones.
"""

from __future__ import annotations

import socket
import struct
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "PROTOCOL_VERSION",
    "MAX_FRAME_BYTES",
    "FrameType",
    "WireError",
    "encode_value",
    "decode_value",
    "encode_frame",
    "decode_frame",
    "send_frame",
    "read_frame",
    "pack_object",
    "unpack_object",
    "TRACE_KEY",
    "attach_trace",
    "extract_trace",
]

PROTOCOL_VERSION = 1
_MAGIC = 0x5257  # "RW"
_HEADER = struct.Struct("!HBBQI")  # magic, version, frame type, req id, len
#: refuse frames above this size (runaway / hostile peers), 1 GiB
MAX_FRAME_BYTES = 1 << 30
#: array buffers at least this large are sent as their own scatter-gather
#: chunk (zero-copy) instead of being copied into the byte stream
_ZERO_COPY_MIN = 4096


class WireError(RuntimeError):
    """Malformed, truncated, oversized or version-incompatible frame."""


#: reserved key carrying a request's trace id inside REQUEST payload dicts —
#: rides the existing value encoding, so the frame header (and the protocol
#: version) is unchanged and peers that ignore it interoperate
TRACE_KEY = "_trace"


def attach_trace(msg: Dict[str, Any], trace: Optional[str]) -> Dict[str, Any]:
    """Stamp ``trace`` into an RPC payload dict (no-op when None)."""
    if trace is not None:
        msg[TRACE_KEY] = trace
    return msg


def extract_trace(msg: Any) -> Optional[str]:
    """Pop and return the trace id of an RPC payload dict, if any."""
    if isinstance(msg, dict):
        t = msg.pop(TRACE_KEY, None)
        if isinstance(t, str):
            return t
    return None


class FrameType:
    """One byte in the header; every frame carries a request id."""

    REQUEST = 1   # client -> server RPC ({"kind": ..., ...})
    OK = 2        # server -> client RPC reply
    ERROR = 3     # server -> client typed error (policy.error_to_wire)
    RESULT = 4    # server -> client streamed Pending resolution


# ---------------------------------------------------------------------------
# value encoding
# ---------------------------------------------------------------------------

_U32 = struct.Struct("!I")
_I64 = struct.Struct("!q")
_F64 = struct.Struct("!d")
_U64 = struct.Struct("!Q")


def _is_arraylike(v: Any) -> bool:
    return hasattr(v, "dtype") and hasattr(v, "shape") and hasattr(v, "__array__")


class _Encoder:
    """Accumulates small writes into a buffer, big array payloads as
    standalone zero-copy chunks."""

    def __init__(self):
        self._chunks: List[Any] = []      # bytes / memoryview
        self._buf = bytearray()

    def _flush(self) -> None:
        if self._buf:
            self._chunks.append(bytes(self._buf))
            self._buf = bytearray()

    def chunks(self) -> List[Any]:
        self._flush()
        return self._chunks

    # -- primitives ---------------------------------------------------------
    def raw(self, b: Any) -> None:
        if len(b) >= _ZERO_COPY_MIN:
            self._flush()
            self._chunks.append(b if isinstance(b, (bytes, memoryview))
                                else memoryview(b))
        else:
            self._buf += b

    def tag(self, t: bytes) -> None:
        self._buf += t

    def u32(self, n: int) -> None:
        self._buf += _U32.pack(n)

    def string(self, s: str) -> None:
        b = s.encode("utf-8")
        self.u32(len(b))
        self.raw(b)

    # -- values -------------------------------------------------------------
    def value(self, v: Any) -> None:
        # local imports: core types are needed only when such a value occurs
        from ..core.graph import Graph
        from ..core.table import Table

        if v is None:
            self.tag(b"Z")
        elif v is True:
            self.tag(b"T")
        elif v is False:
            self.tag(b"F")
        elif isinstance(v, (int, np.integer)):
            self.tag(b"I")
            try:
                self._buf += _I64.pack(int(v))
            except struct.error:
                raise WireError(f"integer {v!r} exceeds the wire's int64")
        elif isinstance(v, (float, np.floating)):
            self.tag(b"f")
            self._buf += _F64.pack(float(v))
        elif isinstance(v, str):
            self.tag(b"S")
            self.string(v)
        elif isinstance(v, (bytes, bytearray, memoryview)):
            self.tag(b"B")
            self.u32(len(v))
            self.raw(v)
        elif isinstance(v, Table):
            self.tag(b"t")
            self.value(_table_to_tree(v))
        elif isinstance(v, Graph):
            self.tag(b"G")
            self.value(_graph_to_tree(v))
        elif isinstance(v, np.ndarray) or _is_arraylike(v):
            self.tag(b"A")
            self.array(np.asarray(v))
        elif isinstance(v, tuple):
            self.tag(b"U")
            self.u32(len(v))
            for x in v:
                self.value(x)
        elif isinstance(v, list):
            self.tag(b"L")
            self.u32(len(v))
            for x in v:
                self.value(x)
        elif isinstance(v, dict):
            self.tag(b"D")
            self.u32(len(v))
            for k, x in v.items():
                if not isinstance(k, str):
                    raise WireError(f"dict keys must be str, got {type(k)}")
                self.string(k)
                self.value(x)
        else:
            raise WireError(
                f"value of type {type(v).__name__} has no wire form")

    def array(self, arr: np.ndarray) -> None:
        if arr.dtype.kind not in "biuf":
            raise WireError(f"dtype {arr.dtype} has no wire form "
                            f"(numeric/bool arrays only)")
        if not arr.flags.c_contiguous:   # ascontiguousarray would turn 0-d
            arr = np.ascontiguousarray(arr)  # into 1-d, so only when needed
        dt = arr.dtype.str.encode("ascii")  # includes byte order, e.g. "<f4"
        self._buf += bytes([len(dt)])
        self._buf += dt
        self._buf += bytes([arr.ndim])
        for d in arr.shape:
            self.u32(d)
        self._buf += _U64.pack(arr.nbytes)
        if arr.nbytes:
            self.raw(memoryview(arr).cast("B"))


class _Decoder:
    def __init__(self, mv: memoryview):
        self.mv = mv
        self.off = 0

    def _take(self, n: int) -> memoryview:
        if self.off + n > len(self.mv):
            raise WireError("truncated frame: value runs past payload end")
        out = self.mv[self.off:self.off + n]
        self.off += n
        return out

    def u32(self) -> int:
        return _U32.unpack(self._take(4))[0]

    def string(self) -> str:
        return bytes(self._take(self.u32())).decode("utf-8")

    def value(self) -> Any:
        tag = bytes(self._take(1))
        if tag == b"Z":
            return None
        if tag == b"T":
            return True
        if tag == b"F":
            return False
        if tag == b"I":
            return _I64.unpack(self._take(8))[0]
        if tag == b"f":
            return _F64.unpack(self._take(8))[0]
        if tag == b"S":
            return self.string()
        if tag == b"B":
            return bytes(self._take(self.u32()))
        if tag == b"A":
            return self.array()
        if tag == b"t":
            return _table_from_tree(self.value())
        if tag == b"G":
            return _graph_from_tree(self.value())
        if tag == b"U":
            return tuple(self.value() for _ in range(self.u32()))
        if tag == b"L":
            return [self.value() for _ in range(self.u32())]
        if tag == b"D":
            return {self.string(): self.value() for _ in range(self.u32())}
        raise WireError(f"unknown value tag {tag!r}")

    def array(self) -> np.ndarray:
        dt_len = self._take(1)[0]
        try:
            dtype = np.dtype(bytes(self._take(dt_len)).decode("ascii"))
        except TypeError as e:
            raise WireError(f"bad dtype on wire: {e}")
        if dtype.kind not in "biuf":
            raise WireError(f"dtype {dtype} refused (numeric/bool only)")
        ndim = self._take(1)[0]
        shape = tuple(self.u32() for _ in range(ndim))
        nbytes = _U64.unpack(self._take(8))[0]
        expect = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        if nbytes != expect:
            raise WireError(f"array header mismatch: {nbytes} bytes for "
                            f"shape {shape} dtype {dtype}")
        buf = self._take(nbytes)
        # zero-copy: the array aliases the received frame buffer
        arr = np.frombuffer(buf, dtype=dtype).reshape(shape)
        if arr.flags.writeable:
            arr.flags.writeable = False
        return arr


# ---------------------------------------------------------------------------
# Table / Graph wire trees (exact rebuild, not pydict round-trips)
# ---------------------------------------------------------------------------


def _table_to_tree(t: Any) -> Dict[str, Any]:
    fields = [(n, ty) for n, ty in t.schema.fields]
    cols = {n: np.asarray(t.column(n)) for n, _ in fields}
    return {"fields": fields, "n_valid": t.n_valid,
            "next_row_id": t.next_row_id,
            "row_ids": np.asarray(t.row_ids[:t.n_valid]),
            "cols": cols,
            "dicts": {n: list(v) for n, v in t.dicts.items()}}


def _table_from_tree(d: Dict[str, Any]) -> Any:
    import jax.numpy as jnp

    from ..core.table import Schema, Table, next_capacity

    fields = tuple((n, ty) for n, ty in d["fields"])
    n = int(d["n_valid"])
    cap = next_capacity(n)

    def pad(a: np.ndarray, fill) -> Any:
        out = np.full((cap,), fill, dtype=a.dtype)
        out[:n] = a
        return jnp.asarray(out)

    cols = {name: pad(d["cols"][name], 0) for name, _ in fields}
    row_ids = pad(np.asarray(d["row_ids"], dtype=np.int32), -1)
    return Table(schema=Schema(fields), columns=cols, row_ids=row_ids,
                 n_valid=n, dicts={k: list(v) for k, v in d["dicts"].items()},
                 next_row_id=int(d["next_row_id"]))


def _graph_to_tree(g: Any) -> Dict[str, Any]:
    src, dst = g.out_edges()
    return {"n_nodes": g.n_nodes,
            "node_ids": np.asarray(g.node_ids[:g.n_nodes]),
            "src": np.asarray(src), "dst": np.asarray(dst)}


def _graph_from_tree(d: Dict[str, Any]) -> Any:
    import jax.numpy as jnp

    from ..core.graph import Graph

    n = int(d["n_nodes"])
    return Graph.from_dense_edges(
        jnp.asarray(np.asarray(d["src"], np.int32)),
        jnp.asarray(np.asarray(d["dst"], np.int32)), n,
        node_ids=jnp.asarray(np.asarray(d["node_ids"], np.int32))
        if n else None)


# ---------------------------------------------------------------------------
# frames
# ---------------------------------------------------------------------------


def encode_value(v: Any) -> List[Any]:
    """Value tree -> list of byte chunks (large arrays stay un-copied)."""
    enc = _Encoder()
    enc.value(v)
    return enc.chunks()


def decode_value(buf: Any) -> Any:
    dec = _Decoder(memoryview(buf))
    v = dec.value()
    if dec.off != len(dec.mv):
        raise WireError(f"{len(dec.mv) - dec.off} trailing bytes after value")
    return v


def encode_frame(ftype: int, req_id: int, value: Any) -> List[Any]:
    """Full frame as chunks: header + payload (ready for ``sendmsg``)."""
    chunks = encode_value(value)
    total = sum(len(c) for c in chunks)
    if total > MAX_FRAME_BYTES:
        raise WireError(f"frame payload {total} bytes exceeds "
                        f"MAX_FRAME_BYTES ({MAX_FRAME_BYTES})")
    header = _HEADER.pack(_MAGIC, PROTOCOL_VERSION, ftype, req_id, total)
    return [header] + chunks

def decode_frame(buf: Any) -> Tuple[int, int, Any]:
    """One complete frame (header + payload) -> (ftype, req_id, value)."""
    mv = memoryview(buf)
    if len(mv) < _HEADER.size:
        raise WireError("truncated frame: short header")
    magic, ver, ftype, req_id, length = _HEADER.unpack(mv[:_HEADER.size])
    if magic != _MAGIC:
        raise WireError(f"bad frame magic {magic:#06x}")
    if ver != PROTOCOL_VERSION:
        raise WireError(f"unsupported protocol version {ver} "
                        f"(speaking {PROTOCOL_VERSION})")
    payload = mv[_HEADER.size:]
    if len(payload) != length:
        raise WireError(f"truncated frame: header says {length} payload "
                        f"bytes, got {len(payload)}")
    return ftype, req_id, decode_value(payload)


# -- socket helpers ----------------------------------------------------------


def send_frame(sock: socket.socket, ftype: int, req_id: int,
               value: Any) -> None:
    """Write one frame; scatter-gather, so big arrays are never copied."""
    chunks = encode_frame(ftype, req_id, value)
    try:
        sent_chunks = 0
        while sent_chunks < len(chunks):
            # stay under IOV_MAX (1024 on Linux) per sendmsg call
            n = sock.sendmsg(chunks[sent_chunks:sent_chunks + 512])
            # advance past fully-sent chunks; re-slice a partial one
            while sent_chunks < len(chunks) and n >= len(chunks[sent_chunks]):
                n -= len(chunks[sent_chunks])
                sent_chunks += 1
            if n:
                part = chunks[sent_chunks]
                chunks[sent_chunks] = memoryview(part)[n:]
    except AttributeError:  # pragma: no cover - platforms without sendmsg
        sock.sendall(b"".join(bytes(c) for c in chunks))


def _recv_exact(sock: socket.socket, n: int) -> Optional[memoryview]:
    """Read exactly n bytes; None on clean EOF at a frame boundary."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:])
        if r == 0:
            if got == 0:
                return None
            raise WireError(f"truncated frame: peer closed after {got} of "
                            f"{n} bytes")
        got += r
    return memoryview(buf)


def read_frame(sock: socket.socket,
               max_bytes: int = MAX_FRAME_BYTES
               ) -> Optional[Tuple[int, int, Any]]:
    """Read one frame; None on clean EOF before a header starts."""
    head = _recv_exact(sock, _HEADER.size)
    if head is None:
        return None
    magic, ver, ftype, req_id, length = _HEADER.unpack(head)
    if magic != _MAGIC:
        raise WireError(f"bad frame magic {magic:#06x}")
    if ver != PROTOCOL_VERSION:
        raise WireError(f"unsupported protocol version {ver} "
                        f"(speaking {PROTOCOL_VERSION})")
    if length > max_bytes:
        raise WireError(f"frame payload {length} bytes exceeds limit "
                        f"{max_bytes}")
    payload = _recv_exact(sock, length) if length else memoryview(b"")
    if length and payload is None:
        raise WireError("truncated frame: EOF before payload")
    return ftype, req_id, decode_value(payload)


# ---------------------------------------------------------------------------
# objects + provenance (results, workspace puts/gets)
# ---------------------------------------------------------------------------


def _versionable(v: Any) -> bool:
    """Only objects with stable identity get wire version tokens; plain
    python scalars would alias small-int/str interning."""
    from ..core.graph import Graph
    from ..core.table import Table
    from ..core import provenance as prov
    if isinstance(v, (Table, Graph)) or _is_arraylike(v) \
            or isinstance(v, np.ndarray):
        return True
    return bool(prov.records_of(v))


def pack_object(v: Any) -> Dict[str, Any]:
    """Value + provenance chain + version token(s), wire-encodable.

    Tuples (multi-output ops like ``hits``) ship one chain and token per
    element, since records attach to the elements.  Tokens are *peeked*,
    never minted: an object that was never versioned here (a fresh client
    root) ships token-less, and the receiving side assigns one — a
    locally-minted token could collide with the peer's existing tokens.
    """
    from ..core import provenance as prov
    if isinstance(v, tuple):
        return {"multi": True, "value": v,
                "records": [prov.records_to_wire(prov.records_of(x))
                            for x in v],
                "tokens": [prov.peek_version(x) if _versionable(x) else None
                           for x in v]}
    return {"multi": False, "value": v,
            "records": prov.records_to_wire(prov.records_of(v)),
            "token": prov.peek_version(v) if _versionable(v) else None}


def unpack_object(payload: Dict[str, Any]) -> Any:
    """Rebuild a packed value and adopt its provenance into this process."""
    from ..core import provenance as prov
    if payload.get("multi"):
        vals = tuple(payload["value"])
        for x, recs, tok in zip(vals, payload["records"], payload["tokens"]):
            if tok is not None or recs:
                prov.adopt_records(x, prov.records_from_wire(recs), token=tok)
        return vals
    v = payload["value"]
    tok = payload.get("token")
    recs = payload.get("records") or []
    if tok is not None or recs:
        prov.adopt_records(v, prov.records_from_wire(recs), token=tok)
    return v
