"""Scheduling policy objects for the interactive service (Ringo §2.1/§4).

Ringo's contract is *interactivity under sharing*: many analysts iterate
trial-and-error on one big-memory machine, and the system must stay
responsive when one of them floods it — not just be fast when idle.  The
policies here parameterize the three levers the scheduler
(:mod:`repro.serve.scheduler`) pulls:

* **admission control** (:class:`AdmissionPolicy`) — bounded per-session
  in-flight quota and global queue-depth backpressure.  Over-quota submits
  raise :class:`RejectedError` carrying a ``retry_after`` estimate derived
  from the observed service rate, so a well-behaved client backs off for
  about as long as the queue needs to drain its share.
* **fair share** (:class:`FairSharePolicy`) — deficit-round-robin across
  sessions, charged in *measured engine milliseconds*.  Every scheduling
  pass tops each waiting session up by ``quantum_ms * weight``; an executed
  request (or a session's slice of a coalesced batch) is charged back at its
  actual cost.  A scan-heavy session therefore overdraws its deficit and
  waits out the debt while interactive sessions, whose cheap queries barely
  dent theirs, keep flowing.  ``floor_ms`` bounds the debt (old sins decay),
  ``burst_ms`` bounds the credit (idle sessions cannot hoard a burst).
* **batching windows** (:class:`BatchPolicy`) — the generalized fusion
  scheduler.  Under load, compatible single-source requests accumulate for a
  bounded window before one coalesced engine call; with an empty queue the
  window collapses to zero so idle latency is unchanged.

:class:`SchedulerPolicy` bundles the three plus the scheduling ``mode``
(``"fair"`` deficit-round-robin vs ``"fifo"`` global arrival order — the
baseline the overload benchmark compares against) and an optional default
request deadline.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

__all__ = [
    "ServiceError",
    "RejectedError",
    "DeadlineExpired",
    "RemoteError",
    "error_to_wire",
    "error_from_wire",
    "AdmissionPolicy",
    "FairSharePolicy",
    "BatchPolicy",
    "MemoryPolicy",
    "SchedulerPolicy",
]


class ServiceError(RuntimeError):
    """Base error for declarative-request execution."""


class RejectedError(ServiceError):
    """Admission control refused the request (quota or queue depth).

    ``retry_after`` (seconds) estimates when capacity frees up: the
    session's queued share divided by the scheduler's observed service
    rate.  Clients should back off at least that long before resubmitting.
    """

    def __init__(self, msg: str, retry_after: float):
        super().__init__(f"{msg} (retry after {retry_after:.3f}s)")
        self.retry_after = float(retry_after)


class DeadlineExpired(ServiceError):
    """The request's deadline passed while it sat in the queue.

    Stale interactive work is dropped *before* reaching the engine — by the
    time it would run, the analyst has moved on, and executing it anyway
    only delays everyone else's fresh queries.
    """


class RemoteError(ServiceError):
    """A server-side failure of a type the wire cannot reconstruct.

    The original exception type name is preserved in the message; the
    client-visible contract is only that the request failed server-side.
    """


# ---------------------------------------------------------------------------
# typed error frames: the service's error vocabulary knows its own wire form,
# so admission-control semantics (RejectedError.retry_after, DeadlineExpired)
# survive a cross-process hop intact and clients back off exactly as an
# in-process caller would.
# ---------------------------------------------------------------------------


def error_to_wire(exc: BaseException) -> Dict[str, Any]:
    """Typed-error payload for an exception crossing the wire."""
    if isinstance(exc, KeyError) and len(exc.args) == 1 \
            and isinstance(exc.args[0], str):
        # str(KeyError) is the repr of its argument; ship the argument
        # itself so the client-side KeyError has identical args
        message = exc.args[0]
    else:
        message = str(exc)
    payload: Dict[str, Any] = {"etype": type(exc).__name__,
                               "message": message}
    if isinstance(exc, RejectedError):
        payload["retry_after"] = exc.retry_after
    return payload


def error_from_wire(payload: Dict[str, Any]) -> BaseException:
    """Rebuild the client-side exception for a typed error payload.

    Service errors come back as their own types (``RejectedError`` keeps its
    ``retry_after``; ``DeadlineExpired`` stays catchable as such); lookup
    failures stay ``KeyError`` so remote sessions mirror in-process ones.
    Anything else becomes :class:`RemoteError` with the original type name
    in the message.
    """
    etype = payload.get("etype", "Exception")
    msg = str(payload.get("message", ""))
    if etype == "RejectedError":
        exc = RejectedError.__new__(RejectedError)
        ServiceError.__init__(exc, msg)
        exc.retry_after = float(payload.get("retry_after", 0.01))
        return exc
    if etype == "DeadlineExpired":
        return DeadlineExpired(msg)
    if etype == "ServiceError":
        return ServiceError(msg)
    if etype == "KeyError":
        return KeyError(msg)   # error_to_wire shipped args[0] verbatim
    if etype == "TimeoutError":
        return TimeoutError(msg)
    return RemoteError(f"{etype}: {msg}")


@dataclass
class AdmissionPolicy:
    """Per-session in-flight quota + global queue-depth backpressure."""

    #: queued + executing requests a session may have before submits reject
    max_inflight: int = 64
    #: per-session overrides of :attr:`max_inflight` (session name -> quota)
    inflight_overrides: Dict[str, int] = field(default_factory=dict)
    #: total queued requests across all sessions before any submit rejects
    max_queue_depth: int = 1024
    #: floor for the retry-after estimate (seconds)
    min_retry_after_s: float = 0.01
    #: opt-in SLO-aware shedding: while ``obs.SLO`` reports the submitted
    #: op (or the service overall) as *breaching*, both admission bounds
    #: shrink by :attr:`shed_factor` so backlog drains instead of piling up
    #: behind an objective that is already blown.  Off by default — turning
    #: observability into admission behavior is a deliberate choice.
    slo_shed: bool = False
    #: multiplier applied to ``max_inflight``/``max_queue_depth`` while
    #: shedding (floored at 1 so the service never fully closes)
    shed_factor: float = 0.5

    def quota_for(self, session: str) -> int:
        return int(self.inflight_overrides.get(session, self.max_inflight))


@dataclass
class FairSharePolicy:
    """Deficit-round-robin parameters, denominated in engine milliseconds."""

    #: per-pass top-up: engine-ms of service each waiting session earns
    quantum_ms: float = 5.0
    #: per-session weight overrides (session name -> relative share)
    weights: Dict[str, float] = field(default_factory=dict)
    default_weight: float = 1.0
    #: deficit floor — the deepest debt a session can carry; bounds how long
    #: a formerly-greedy session is locked out once it turns interactive
    floor_ms: float = 2000.0
    #: deficit ceiling — unspent credit an idle session can bank
    burst_ms: float = 50.0
    #: EMA factor for the per-session recent-engine-ms consumption stat
    decay: float = 0.9

    def weight_for(self, session: str) -> float:
        return float(self.weights.get(session, self.default_weight))


@dataclass
class BatchPolicy:
    """Load-tiered coalescing window for compatible single-source requests."""

    #: longest a dequeued fusable request waits for companions (milliseconds)
    window_ms: float = 5.0
    #: widest coalesced batch (one vmapped engine call)
    max_batch: int = 64
    #: queued requests (beyond the dequeued one) at which the window opens
    #: fully; below it the window scales down, reaching zero on an empty
    #: queue — idle single requests never wait
    load_full_at: int = 8

    def effective_window_s(self, queued_behind: int) -> float:
        """Seconds to hold a fusable request open, given current load.

        Zero when nothing else is queued (the idle path executes
        immediately); scales linearly up to :attr:`window_ms` as the backlog
        approaches :attr:`load_full_at`.
        """
        if queued_behind <= 0 or self.window_ms <= 0:
            return 0.0
        frac = min(1.0, queued_behind / max(1, self.load_full_at))
        return (self.window_ms * frac) / 1e3


@dataclass
class MemoryPolicy:
    """Byte budget + eviction knobs for a long-lived serving process.

    The budget governs the service's *tracked* bytes: the result cache plus
    the re-derivable plan families of every live graph the service has
    served (see ``GraphPlan.nbytes_by_family``).  When tracked bytes exceed
    ``budget_bytes`` the service evicts, cheapest-to-restore first:

    1. **result-cache entries**, LRU order — recomputing a query is the
       ordinary cache-miss path and costs one engine call;
    2. **plan families** of graphs with no in-flight batch, largest first —
       re-deriving sorted/blocked arrays is cheaper than an engine call but
       dearer than nothing, so these go only when the result cache alone
       cannot get under budget.

    The base CSR of a live graph (and the plan's eager sorted-edge arrays)
    is never evicted: it is the object the workspace serves, not a cache.
    """

    #: tracked-bytes ceiling (result cache + evictable plan members);
    #: None = unbounded, the pre-budget behavior
    budget_bytes: Optional[int] = None
    #: delta-ancestry links kept per live graph for retention/warm starts;
    #: ancestors beyond this are cut so a delta stream cannot pin every
    #: historical graph version (see ``Graph.prune_lineage``)
    max_lineage_depth: int = 4
    #: capacity of the provenance strong-pin ring for weakref-less objects
    max_provenance_pins: int = 4096

    def __post_init__(self):
        if self.budget_bytes is not None and self.budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0 or None, "
                             f"got {self.budget_bytes}")
        if self.max_lineage_depth < 1:
            raise ValueError(f"max_lineage_depth must be >= 1, "
                             f"got {self.max_lineage_depth}")
        if self.max_provenance_pins < 1:
            raise ValueError(f"max_provenance_pins must be >= 1, "
                             f"got {self.max_provenance_pins}")


@dataclass
class SchedulerPolicy:
    """Everything the request scheduler needs to make its decisions."""

    #: "fair" = deficit-round-robin across sessions; "fifo" = global
    #: arrival order (the baseline the overload benchmark measures against)
    mode: str = "fair"
    admission: AdmissionPolicy = field(default_factory=AdmissionPolicy)
    fair: FairSharePolicy = field(default_factory=FairSharePolicy)
    batch: BatchPolicy = field(default_factory=BatchPolicy)
    memory: MemoryPolicy = field(default_factory=MemoryPolicy)
    #: deadline applied to requests that don't carry their own
    #: ``"deadline_ms"``; None = requests never expire by default
    default_deadline_ms: Optional[float] = None

    def __post_init__(self):
        if self.mode not in ("fair", "fifo"):
            raise ValueError(f"unknown scheduler mode {self.mode!r}; "
                             f"expected 'fair' or 'fifo'")
