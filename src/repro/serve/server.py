"""Threaded socket server: the cross-process front door to one GraphService.

Ringo's §2.1 deployment is many analysts sharing one big-memory machine;
until now every "session" lived inside the caller's interpreter.  This
module puts the PR 4 scheduler seam on a TCP socket: decoded requests feed
straight into :meth:`GraphService.submit` — admission control (quota /
queue-depth :class:`RejectedError` with ``retry_after``), deadline drops,
deficit-round-robin fair share and batching windows all apply unchanged to
remote clients, which for the first time are *genuinely concurrent
independent processes*.

Design:

* one **accept thread**; per connection one **reader thread** (decodes
  frames, dispatches RPCs — all cheap: admission, namespace ops; never an
  engine call) and one **writer thread** draining an outbox queue, so a
  slow client can't block the scheduler and results stream the moment they
  resolve;
* each connection gets its own session namespace: client session ``name``
  maps to service session ``"c<N>/name"``, so two client processes using
  the same session name stay isolated and fair-share treats them as
  distinct principals.  The workspace, result cache and fusion scheduler
  are shared — that's the point;
* **out-of-order streaming**: ``submit`` replies immediately (admission
  verdict), and the result arrives later as a RESULT frame carrying the
  submit's request id — whichever order the scheduler resolves them;
* **graceful shutdown** drains the scheduler (flush + wait-idle) before
  closing sockets, so accepted work is never dropped mid-stream.

``python -m repro.serve.server`` runs a standalone server; ``--rmat-scale``
pre-publishes a shared RMAT graph (the benchmark/CI workload), and the
process prints ``RINGO-SERVE LISTENING <port>`` once ready so parents can
spawn it on an ephemeral port.
"""

from __future__ import annotations

import argparse
import itertools
import os
import queue
import socket
import subprocess
import sys
import threading
from typing import Any, Dict, Optional, Tuple

from .. import obs
from . import wire
from .graph_service import EdgeDelta, GraphService, Session
from .policy import SchedulerPolicy, error_to_wire

__all__ = ["GraphServer", "spawn_server", "main"]


class _Connection:
    """One client socket: reader dispatch + writer queue."""

    def __init__(self, server: "GraphServer", sock: socket.socket,
                 conn_id: str):
        self.server = server
        self.sock = sock
        self.conn_id = conn_id
        self.outbox: "queue.Queue[Optional[Tuple[int, int, Any]]]" = \
            queue.Queue()
        self.closed = threading.Event()
        self.sessions: Dict[str, Session] = {}
        # trace id of the frame currently being dispatched; only the one
        # reader thread of this connection ever touches it
        self._trace: Optional[str] = None
        self.reader = threading.Thread(target=self._read_loop, daemon=True,
                                       name=f"serve-read-{conn_id}")
        self.writer = threading.Thread(target=self._write_loop, daemon=True,
                                       name=f"serve-write-{conn_id}")

    def start(self) -> None:
        self.reader.start()
        self.writer.start()

    # -- session mapping -----------------------------------------------------
    def _session(self, name: str) -> Session:
        key = f"{self.conn_id}/{name}"
        if key not in self.sessions:
            self.sessions[key] = self.server.service.session(key)
        return self.sessions[key]

    # -- outbound ------------------------------------------------------------
    def send(self, ftype: int, req_id: int, payload: Any) -> None:
        if not self.closed.is_set():
            self.outbox.put((ftype, req_id, payload))

    def _write_loop(self) -> None:
        while True:
            item = self.outbox.get()
            if item is None:
                break
            ftype, req_id, payload = item
            try:
                wire.send_frame(self.sock, ftype, req_id, payload)
            except (OSError, wire.WireError):
                break
        self._teardown()

    # -- inbound -------------------------------------------------------------
    def _read_loop(self) -> None:
        try:
            while not self.server._stop.is_set():
                frame = wire.read_frame(self.sock,
                                        self.server.max_frame_bytes)
                if frame is None:
                    break                      # clean EOF
                ftype, req_id, msg = frame
                if ftype != wire.FrameType.REQUEST:
                    raise wire.WireError(
                        f"client sent non-request frame type {ftype}")
                self._dispatch(req_id, msg)
        except wire.WireError as e:
            # a peer speaking garbage gets one typed error, then the door
            self.send(wire.FrameType.ERROR, 0, error_to_wire(e))
        except OSError:
            pass
        finally:
            # normal disconnect: stop the writer once the queue drains.
            # During server shutdown the writer must OUTLIVE the reader —
            # the drain phase still streams RESULT frames — so shutdown()
            # enqueues the sentinel itself, after draining.
            if not self.server._stop.is_set():
                self.outbox.put(None)          # stop writer -> teardown

    def _teardown(self) -> None:
        if self.closed.is_set():
            return
        self.closed.set()
        try:
            self.sock.close()
        except OSError:
            pass
        for key in list(self.sessions):
            self.server.service.end_session(key)
        self.server._forget(self)

    # -- dispatch ------------------------------------------------------------
    def _dispatch(self, req_id: int, msg: Any) -> None:
        if not isinstance(msg, dict):
            raise wire.WireError("request payload must be a dict")
        self._trace = wire.extract_trace(msg)
        kind = msg.get("kind")
        handler = getattr(self, f"_op_{kind}", None)
        if handler is None:
            self.send(wire.FrameType.ERROR, req_id, {
                "etype": "ServiceError",
                "message": f"unknown request kind {kind!r}"})
            return
        try:
            with obs.TRACER.span(f"rpc.{kind}", trace=self._trace,
                                 conn=self.conn_id, cat="rpc"):
                reply = handler(req_id, msg)
        except Exception as e:
            self.send(wire.FrameType.ERROR, req_id, error_to_wire(e))
            return
        if reply is not None:
            self.send(wire.FrameType.OK, req_id, reply)

    # -- RPC handlers --------------------------------------------------------
    def _op_hello(self, req_id: int, msg: dict) -> dict:
        peer = int(msg.get("protocol", -1))
        if peer != wire.PROTOCOL_VERSION:
            raise wire.WireError(
                f"client speaks protocol {peer}, server speaks "
                f"{wire.PROTOCOL_VERSION}")
        return {"protocol": wire.PROTOCOL_VERSION, "conn": self.conn_id,
                "workers": len(self.server.service._worker_threads),
                "pid": os.getpid()}

    def _op_ws_put(self, req_id: int, msg: dict) -> dict:
        obj = wire.unpack_object(msg["obj"])
        return {"version": self.server.service.workspace.put(
            msg["name"], obj)}

    def _op_ws_get(self, req_id: int, msg: dict) -> dict:
        obj = self.server.service.workspace.get(msg["name"])
        return {"obj": wire.pack_object(obj)}

    def _op_ws_names(self, req_id: int, msg: dict) -> dict:
        return {"names": self.server.service.workspace.names()}

    def _op_ws_version(self, req_id: int, msg: dict) -> dict:
        return {"version": self.server.service.workspace.version(
            msg["name"])}

    def _op_ws_apply_delta(self, req_id: int, msg: dict) -> dict:
        # the only functional update that CAN cross the wire: the delta is
        # plain data, and the server applies it on the CAS update path so
        # the child graph keeps its lineage (plan patching, cache retention
        # and warm starts all engage exactly as for an in-process update)
        delta = EdgeDelta(add_src=msg.get("add_src", ()),
                          add_dst=msg.get("add_dst", ()),
                          del_src=msg.get("del_src", ()),
                          del_dst=msg.get("del_dst", ()))
        return {"version": self.server.service.workspace.apply_delta(
            msg["name"], delta)}

    def _op_sess_put(self, req_id: int, msg: dict) -> dict:
        obj = wire.unpack_object(msg["obj"])
        return {"version": self._session(msg["session"]).put(
            msg["name"], obj)}

    def _op_sess_get(self, req_id: int, msg: dict) -> dict:
        obj = self._session(msg["session"]).get(msg["name"])
        return {"obj": wire.pack_object(obj)}

    def _op_publish(self, req_id: int, msg: dict) -> dict:
        return {"version": self._session(msg["session"]).publish(
            msg["name"])}

    def _op_local_names(self, req_id: int, msg: dict) -> dict:
        return {"names": self._session(msg["session"]).local_names()}

    def _op_submit(self, req_id: int, msg: dict) -> Optional[dict]:
        sess = self._session(msg["session"])
        # raises RejectedError / ServiceError -> typed ERROR frame; the
        # client's submit() sees the same admission verdict an in-process
        # caller would, retry_after included
        pending = self.server.service.submit(sess, dict(msg["request"]),
                                             trace=self._trace)
        self.send(wire.FrameType.OK, req_id, {"submitted": True})
        pending.add_done_callback(
            lambda p, rid=req_id: self._stream_result(rid, p))
        return None                      # OK already sent, ordered first

    def _stream_result(self, req_id: int, p: Any) -> None:
        """Pending resolution -> RESULT frame (runs on the resolver)."""
        if p.error is not None:
            payload: Dict[str, Any] = {"error": error_to_wire(p.error)}
        else:
            payload = {"result": wire.pack_object(p.value)}
        payload.update(cached=p.cached, fused=p.fused,
                       queued_ms=p.queued_ms)
        self.send(wire.FrameType.RESULT, req_id, payload)

    def _op_flush(self, req_id: int, msg: dict) -> dict:
        self.server.service.flush()
        return {}

    def _op_stats(self, req_id: int, msg: dict) -> dict:
        with self.server.service._stats_lock:
            return {"stats": dict(self.server.service.stats)}

    def _op_obs_metrics(self, req_id: int, msg: dict) -> dict:
        """Server-side metrics snapshot: ``fmt="json"`` (default) ships the
        registry snapshot dict, ``fmt="prom"`` the Prometheus text."""
        if msg.get("fmt") == "prom":
            return {"text": obs.dump_metrics("prom")}
        return {"metrics": obs.dump_metrics("json")}

    def _op_obs_trace(self, req_id: int, msg: dict) -> dict:
        """Chrome trace-event JSON of the server's span buffer; ``trace``
        filters to one trace id (how a client fetches its own requests)."""
        return {"trace_events":
                obs.export_chrome_trace(trace=msg.get("trace"))}

    def _op_health(self, req_id: int, msg: dict) -> dict:
        """Rolling-window SLO verdict (``ok|degraded|breaching`` overall
        and per op, with machine-readable reasons)."""
        return {"health": obs.health()}

    def _op_slo_report(self, req_id: int, msg: dict) -> dict:
        """Full SLO window: per-op rates, burn, quantiles, objectives."""
        return {"report": obs.slo_report()}

    def _op_debug_bundle(self, req_id: int, msg: dict) -> dict:
        """Postmortem bundle: metrics, trace (optionally filtered to
        ``trace``), flight-recorder exemplars, SLO state, profile report,
        log tail, config/versions — one plain JSON-safe tree."""
        return {"bundle": obs.debug_bundle(trace=msg.get("trace"))}

    def _op_session_stats(self, req_id: int, msg: dict) -> dict:
        key = f"{self.conn_id}/{msg['session']}"
        return {"stats": self.server.service.session_stats(key)}

    def _op_shutdown(self, req_id: int, msg: dict) -> Optional[dict]:
        if not self.server.allow_remote_shutdown:
            raise PermissionError("remote shutdown disabled on this server")
        # reply BEFORE spawning the shutdown thread: it will stop this
        # connection's writer, and the ack must already be in its queue
        self.send(wire.FrameType.OK, req_id, {"stopping": True})
        threading.Thread(target=self.server.shutdown, daemon=True,
                         name="serve-shutdown").start()
        return None


class GraphServer:
    """Accepts connections and serves one shared :class:`GraphService`."""

    def __init__(self, service: Optional[GraphService] = None,
                 host: str = "127.0.0.1", port: int = 0, *,
                 max_frame_bytes: int = wire.MAX_FRAME_BYTES,
                 allow_remote_shutdown: bool = True,
                 drain_timeout_s: float = 30.0):
        self.service = service if service is not None \
            else GraphService(workers=2)
        self.max_frame_bytes = max_frame_bytes
        self.allow_remote_shutdown = allow_remote_shutdown
        self.drain_timeout_s = drain_timeout_s
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.host, self.port = self._sock.getsockname()[:2]
        self._stop = threading.Event()
        self._done = threading.Event()
        self._conn_seq = itertools.count(1)
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "GraphServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="serve-accept")
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        """Start (if needed) and block until :meth:`shutdown`."""
        if self._accept_thread is None:
            self.start()
        self._done.wait()

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, _ = self._sock.accept()
            except OSError:
                break                       # listening socket closed
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Connection(self, sock, f"c{next(self._conn_seq)}")
            with self._conns_lock:
                self._conns.add(conn)
            conn.start()

    def _forget(self, conn: _Connection) -> None:
        with self._conns_lock:
            self._conns.discard(conn)

    def shutdown(self, *, drain: bool = True) -> None:
        """Stop accepting, drain the scheduler, close everything.

        ``drain=True`` (the default) is the graceful path: every admitted
        request executes and its RESULT frame is flushed before sockets
        close.  Idempotent.
        """
        if self._stop.is_set():
            self._done.set()
            return
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns)
        me = threading.current_thread()
        # stop readers FIRST (no new submits can slip in behind the drain):
        # SHUT_RD unblocks read_frame with EOF; readers see _stop set and
        # exit without stopping their writers
        for conn in conns:
            try:
                conn.sock.shutdown(socket.SHUT_RD)
            except OSError:
                pass
        for conn in conns:
            if conn.reader.is_alive() and conn.reader is not me:
                conn.reader.join(timeout=5.0)
        if drain:
            self.service.flush()
            self.service.scheduler.wait_idle(timeout=self.drain_timeout_s)
        for conn in conns:
            conn.outbox.put(None)           # writer flushes queue, then dies
        for conn in conns:
            if conn.writer.is_alive() and conn.writer is not me:
                conn.writer.join(timeout=5.0)
            try:
                conn.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.sock.close()
            except OSError:
                pass
        self.service.close()
        self._done.set()


# ---------------------------------------------------------------------------
# subprocess helper + CLI
# ---------------------------------------------------------------------------

_READY = "RINGO-SERVE LISTENING"


def spawn_server(extra_args: Tuple[str, ...] = (), *,
                 timeout: float = 120.0) -> Tuple[Any, int]:
    """Spawn ``python -m repro.serve.server`` and wait for its port.

    Returns ``(Popen, port)``; the child prints ``RINGO-SERVE LISTENING
    <port>`` once its accept loop is live.  Used by the benchmark, the CI
    smoke stage and the remote example — anything that needs a genuinely
    separate server process on an ephemeral port.
    """
    src_dir = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve.server", "--port", "0",
         *extra_args],
        stdout=subprocess.PIPE, text=True, env=env)
    import select
    import time as _time
    deadline = _time.monotonic() + timeout
    assert proc.stdout is not None
    while True:
        if _time.monotonic() > deadline:
            proc.kill()
            raise TimeoutError("server subprocess never reported its port")
        # poll the pipe so a child hanging *without printing* still fails
        # at the deadline instead of blocking readline() forever
        ready, _, _ = select.select([proc.stdout], [], [], 1.0)
        if not ready:
            if proc.poll() is not None:
                raise RuntimeError(
                    f"server subprocess exited early (rc={proc.poll()})")
            continue
        line = proc.stdout.readline()
        if not line:
            raise RuntimeError(
                f"server subprocess exited early (rc={proc.poll()})")
        if line.startswith(_READY):
            port = int(line.split()[-1])
            break
    # keep draining the child's stdout so its prints never block it
    def _drain(out):
        for _ in out:
            pass
    threading.Thread(target=_drain, args=(proc.stdout,), daemon=True).start()
    return proc, port


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Standalone Ringo graph-analytics server")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral; the bound port is printed")
    ap.add_argument("--workers", type=int, default=2,
                    help="scheduler worker threads (>=1 so results stream "
                         "without client flushes)")
    ap.add_argument("--mode", choices=("fair", "fifo"), default="fair")
    ap.add_argument("--rmat-scale", type=int, default=None,
                    help="pre-publish an RMAT graph of 2^SCALE nodes")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--publish", default="g",
                    help="workspace name for the pre-published graph")
    ap.add_argument("--no-remote-shutdown", action="store_true")
    args = ap.parse_args(argv)

    service = GraphService(policy=SchedulerPolicy(mode=args.mode),
                           workers=max(args.workers, 0))
    if args.rmat_scale is not None:
        from ..core.graph import Graph
        from ..data.rmat import rmat_edges
        src, dst = rmat_edges(args.rmat_scale, edge_factor=args.edge_factor,
                              seed=args.seed)
        g = Graph.from_edges(src, dst)
        g.plan()                         # warm the shared plan once
        service.workspace.put(args.publish, g)
        print(f"published {args.publish!r}: {g.n_nodes} nodes "
              f"{g.n_edges} edges", flush=True)

    server = GraphServer(
        service, host=args.host, port=args.port,
        allow_remote_shutdown=not args.no_remote_shutdown).start()
    print(f"{_READY} {server.port}", flush=True)

    import signal

    def _stop(signum, frame):
        threading.Thread(target=server.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, _stop)
    signal.signal(signal.SIGINT, _stop)
    server.serve_forever()
    print("server drained and stopped", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
