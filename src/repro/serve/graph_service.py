"""Interactive graph-analytics service (Ringo §2.1/§4) over the engine.

Ringo's defining claim is not just fast algorithms but an *interactive
system*: many analysts iterate trial-and-error over named tables and graphs
held in one big shared memory, and the front end keeps the whole thing
responsive.  This module is that front end for the repro stack, the layer the
ROADMAP's "serve heavy multi-user traffic" north star grows from:

    Workspace        named, versioned tables/graphs shared across sessions.
                     Objects are immutable; ``update`` applies a functional
                     update and publishes the fresh object (fresh version
                     token), so the identity-memoized ``Graph.plan()`` cache
                     and the service result cache invalidate by construction.
    Session          one analyst's namespace, layered over the workspace.
                     Local writes (results bound via ``"as"``) never leak to
                     other sessions until explicitly ``publish``-ed.
    GraphService     executes declarative requests such as
                     ``{"op": "pagerank", "graph": "qa", "params": {...}}``
                     from many concurrent sessions, with two throughput
                     multipliers:

    * a **fusion scheduler**: concurrent single-source ``bfs`` / ``sssp`` /
      ``personalized_pagerank`` requests against the same graph version with
      the same parameters coalesce into ONE vmapped multi-source engine call
      (the batched fixpoint the algorithms already expose), and the rows
      scatter back to the individual requests — each with the provenance of
      the equivalent single-source call, so export/replay are oblivious to
      fusion;
    * a **result cache** keyed by ``(object version, op, canonicalized
      params)``: repeated trial-and-error queries are free until the object
      changes.  Version tokens come from :mod:`repro.core.provenance`;
      because updates are functional, a stale hit is impossible;
    * **delta-aware incremental maintenance**: after
      :meth:`Workspace.apply_delta` publishes a graph's insert-only child,
      cache entries the delta provably cannot change are re-bound to the
      new version (retention — the query never re-executes), and queries
      that must re-execute warm-start from the parent version's cached
      result (frontier re-seeding for traversals/labels, warm power
      iteration for pagerank) instead of running cold.

Requests are submitted with :meth:`GraphService.submit` (returns a
:class:`Pending`) and flow through the load-aware scheduler
(:mod:`repro.serve.scheduler`): per-session admission control (bounded
in-flight quota and queue-depth backpressure raise
:class:`~repro.serve.policy.RejectedError` with a retry-after hint; requests
carrying a ``"deadline_ms"`` are dropped unexecuted once stale), deficit-
round-robin fair share charged in measured engine milliseconds, and load-
tiered batching windows that generalize the fusion scheduler.  With
``workers=0`` (the default) execution happens inline at
:meth:`GraphService.flush` — the synchronous drain that gives concurrent
requests the chance to fuse; with ``workers>0`` background worker threads
run the same loop continuously and :meth:`Pending.result` simply waits.
:meth:`GraphService.execute` is the submit+flush+result convenience for
sequential use.  All entry points are thread-safe.
"""

from __future__ import annotations

import threading
import time
import weakref
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core import algorithms as A
from ..core import convert as C
from ..core import provenance as prov
from ..core import relational as R
from ..core.graph import EdgeDelta, Graph
from ..core.plan import EVICTABLE_FAMILIES
from ..core.table import Table
from .policy import (DeadlineExpired, MemoryPolicy, RejectedError,
                     SchedulerPolicy, ServiceError)
from .scheduler import QueuedRequest, Scheduler

__all__ = ["Workspace", "Session", "GraphService", "Pending", "EdgeDelta",
           "ServiceError", "RejectedError", "DeadlineExpired",
           "SchedulerPolicy", "MemoryPolicy"]

_log = obs.get_logger(__name__)

# memory telemetry: what the serving process is holding, and for whom.
# Gauges are set by the memory manager on every accounting pass; they flow
# to remote clients through the existing ``metrics`` RPC unchanged.
_G_PLAN_BYTES = obs.gauge("mem.plan_bytes")
_G_PLAN_EVICTABLE = obs.gauge("mem.plan_evictable_bytes")
_G_CACHE_BYTES = obs.gauge("mem.result_cache_bytes")
_G_TRACKED = obs.gauge("mem.tracked_bytes")
_G_BUDGET = obs.gauge("mem.budget_bytes")
_G_PINS = obs.gauge("mem.provenance_pins")
_H_ENTRY_BYTES = obs.histogram("mem.entry_bytes", buckets=obs.BYTE_BUCKETS)


# ---------------------------------------------------------------------------
# request vocabulary: op name -> (callable, {request_key: param_name})
# ---------------------------------------------------------------------------

_OPS: Dict[str, Tuple[Callable, Dict[str, str]]] = {
    # relational (named inputs: "table" or "left"/"right")
    "select": (R.select, {"table": "t"}),
    "select_inplace": (R.select_inplace, {"table": "t"}),
    "project": (R.project, {"table": "t"}),
    "order": (R.order, {"table": "t"}),
    "group_by": (R.group_by, {"table": "t"}),
    "unique": (R.unique, {"table": "t"}),
    "join": (R.join, {"left": "lt", "right": "rt"}),
    "union": (R.union, {"left": "lt", "right": "rt"}),
    "intersect": (R.intersect, {"left": "lt", "right": "rt"}),
    "difference": (R.difference, {"left": "lt", "right": "rt"}),
    "sim_join": (R.sim_join, {"left": "lt", "right": "rt"}),
    "next_k": (R.next_k, {"table": "t"}),
    # conversions
    "to_graph": (C.to_graph, {"table": "t"}),
    "graph_to_edge_table": (C.graph_to_edge_table, {"graph": "g"}),
    "graph_to_node_table": (C.graph_to_node_table, {"graph": "g"}),
    "table_from_map": (C.table_from_map, {"graph": "g", "scores": "scores"}),
    # algorithms
    "pagerank": (A.pagerank, {"graph": "g"}),
    "personalized_pagerank": (A.personalized_pagerank, {"graph": "g"}),
    "sssp": (A.sssp, {"graph": "g"}),
    "bfs": (A.bfs, {"graph": "g"}),
    "hits": (A.hits, {"graph": "g"}),
    "connected_components": (A.connected_components, {"graph": "g"}),
    "strongly_connected_components": (A.strongly_connected_components,
                                      {"graph": "g"}),
    "k_core": (A.k_core, {"graph": "g"}),
    "core_numbers": (A.core_numbers, {"graph": "g"}),
    "label_propagation": (A.label_propagation, {"graph": "g"}),
    "eigenvector_centrality": (A.eigenvector_centrality, {"graph": "g"}),
    "closeness_centrality": (A.closeness_centrality, {"graph": "g"}),
    "triangle_count": (A.triangle_count, {"graph": "g"}),
    "per_node_triangles": (A.per_node_triangles, {"graph": "g"}),
    "clustering_coefficient": (A.clustering_coefficient, {"graph": "g"}),
}

# ops whose callable accepts ``backend=`` (engine backend dispatch): a
# service-level ``engine_backend`` is injected into their params before
# canonicalization, so cache/fuse keys distinguish backends and every
# algorithm inherits e.g. the multi-device "sharded" engine unmodified
_BACKEND_OPS = {
    "pagerank", "personalized_pagerank", "sssp", "bfs", "hits",
    "connected_components", "strongly_connected_components", "k_core",
    "core_numbers", "label_propagation", "eigenvector_centrality",
    "closeness_centrality", "triangle_count",
}

# single-source traversals the scheduler may coalesce into one vmapped call;
# value = the parameter holding the source vertex
_FUSABLE: Dict[str, str] = {
    "bfs": "source",
    "sssp": "source",
    "personalized_pagerank": "source",
}
_PROV_OP = {"bfs": "algorithms.bfs", "sssp": "algorithms.sssp",
            "personalized_pagerank": "algorithms.personalized_pagerank"}
# cross-n_iter fusion: requests differing only in n_iter coalesce; the batch
# runs to the max cap and each row freezes at its own (the capped fixpoint
# bodies in core/algorithms.py).  Value = the cap standing in for an absent
# n_iter: ppr's iterative default; None for the traversals, resolved per
# graph to |V| (that many relaxation rounds always converge BFS/SSSP).
_FUSE_DEPTH_DEFAULT: Dict[str, Optional[int]] = {
    "bfs": None, "sssp": None, "personalized_pagerank": 10,
}

# --- incremental maintenance (delta-aware serving) -------------------------
# Ops whose cached result can provably survive an insert-only delta
# (see _retention_safe), and ops the service can warm-start from the
# parent version's cached result after a delta.
_RETAINABLE = {"bfs", "sssp", "connected_components", "label_propagation"}
_WARM_OPS = {"pagerank", "personalized_pagerank", "bfs", "sssp",
             "connected_components", "label_propagation"}
# provenance op names for results whose chain the service rewrites (fusion
# scatter rows, warm-started recomputations): the recorded call is always
# the equivalent standalone cold call
_PROV_ANY = dict(_PROV_OP,
                 pagerank="algorithms.pagerank",
                 connected_components="algorithms.connected_components",
                 label_propagation="algorithms.label_propagation")


def _retention_safe(op: str, g: Graph, info: Any, parent_val: Any,
                    params: Dict[str, Any]) -> bool:
    """True when ``parent_val`` provably equals the child-version result.

    ``info`` is the child's ``Graph._delta`` (insert-only, same node
    numbering as the parent by construction of the fast apply path), so the
    parent's cached array indexes the child's vertices directly.  Per-op
    predicates over the inserted dense edges ``(u, v)``:

    * ``bfs`` / unweighted ``sssp`` — ``D[u] + 1 >= D[v]`` (unreachable as
      +inf): the new edge cannot shorten any path.  Sound even for a capped
      ``n_iter``: round-``t`` values are exact <=t-hop distances, and an
      edge satisfying the predicate creates no shorter path of any length.
      Weighted ``sssp`` never retains (the cached weights keying cannot be
      re-verified against the patched edge order).
    * ``connected_components`` — ``label[u] == label[v]``: an
      intra-component edge changes no component.  Sound because cc always
      runs to fixpoint (no round cap in its API).
    * ``label_propagation`` — same equality test, but only when
      ``n_iter >= |V|`` (a capped run is not a fixpoint: equal labels at
      radius ``t`` do not pin the labels interior vertices see through the
      new shortcut).

    Everything else (pagerank, hits, triangles, ...) is never retained —
    any new edge perturbs the value.
    """
    u, v = info.add_src, info.add_dst
    if u.size == 0:
        return True
    val = np.asarray(parent_val)
    if op in ("bfs", "sssp"):
        if op == "sssp" and params.get("weights") is not None:
            return False
        D = val.astype(np.float64)
        if op == "bfs":
            D = np.where(D < 0, np.inf, D)
        return bool(np.all(D[..., u] + 1.0 >= D[..., v]))
    if op == "label_propagation":
        n_iter = params.get("n_iter", 20)
        if not isinstance(n_iter, (int, np.integer)) or n_iter < g.n_nodes:
            return False
    return bool(np.all(val[u] == val[v]))


def _sssp_weights_block_fusion(canon: Tuple[Tuple[str, Any], ...]) -> bool:
    """True when an ``sssp`` request's weights bar it from coalescing.

    Any negative weight voids the |V|-round convergence bound the fused
    mixed-depth batch uses for its unbounded members (ROADMAP open item),
    so such requests never coalesce — each runs standalone.  The check
    reads the already-canonicalized literal (at most 256 embedded values,
    no device transfer); an :class:`~repro.core.provenance.Opaque` weights
    array could never share a fusion key anyway (identity-hashed), so it is
    unfusable too rather than worth an O(|E|) scan.
    """
    for k, v in canon:
        if k != "weights":
            continue
        if v is None:
            return False
        if isinstance(v, tuple) and len(v) == 4 and v[0] == "array":
            return any(x < 0 for x in v[3])
        return True          # opaque / non-array literal: stay unfused
    return False


_MISS = object()        # _cache_get sentinel: None is a valid cached value


def _block(out: Any) -> Any:
    """Wait for device work so measured engine-ms is real, not dispatch."""
    try:
        return jax.block_until_ready(out)
    except Exception:
        return out


# ---------------------------------------------------------------------------
# memory accounting — byte-costed result cache + plan-member eviction
# ---------------------------------------------------------------------------

#: flat per-entry charge covering the key tuple, OrderedDict slot and cost
#: map; keeps zero-byte payloads (scalars, empty tables) from being free
_ENTRY_OVERHEAD = 512


def _payload_bytes(v: Any) -> int:
    """Array bytes held by a cached result value (0 for scalars)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return 0
    if isinstance(v, (Graph, Table)):
        return int(v.nbytes())
    if hasattr(v, "dtype") and hasattr(v, "size"):
        return int(v.size) * int(np.dtype(v.dtype).itemsize)
    if isinstance(v, (tuple, list)):
        return sum(_payload_bytes(x) for x in v)
    if isinstance(v, dict):
        return sum(_payload_bytes(x) for x in v.values())
    return 0


def _value_nbytes(v: Any) -> int:
    return _ENTRY_OVERHEAD + _payload_bytes(v)


class _MemoryManager:
    """Keeps the service's tracked bytes under :class:`MemoryPolicy`'s budget.

    Tracked bytes = result-cache bytes + the re-derivable plan families of
    every live graph the service has served.  Eviction order is fixed:
    result-cache entries first (LRU — recomputing is the ordinary miss
    path), then plan families of graphs with no in-flight batch, largest
    first (re-deriving is cheaper than an engine call but not free).  The
    base CSR of a live graph and the plan's eager arrays are never touched.

    Lock order (outermost → innermost): ``self._lock`` → the service's
    ``_lock`` → ``_stats_lock``.  Nothing may call into this class while
    holding the service lock.
    """

    def __init__(self, service: "GraphService", policy: MemoryPolicy):
        self.service = service
        self.policy = policy
        self._lock = threading.RLock()
        # id(graph) -> weakref; a graph that dies simply drops out of
        # accounting (its plan died with it)
        self._graphs: Dict[int, Any] = {}
        # id(graph) -> in-flight batch refcount; a busy graph's plan members
        # are mid-use by an engine call and are skipped by eviction
        self._busy: Dict[int, int] = {}
        # test/debug probe: recent eviction actions ("result"|"plan", bytes)
        self.evlog: "deque" = deque(maxlen=256)

    # -- graph registry -----------------------------------------------------
    def _drop(self, key: int) -> None:
        with self._lock:
            self._graphs.pop(key, None)
            self._busy.pop(key, None)

    def note_graph(self, g: Graph) -> None:
        key = id(g)
        with self._lock:
            if key not in self._graphs:
                self._graphs[key] = weakref.ref(
                    g, lambda r, key=key: self._drop(key))

    def _live_graphs_locked(self) -> List[Graph]:
        out = []
        for key, ref in list(self._graphs.items()):
            g = ref()
            if g is None:
                self._graphs.pop(key, None)
                self._busy.pop(key, None)
            else:
                out.append(g)
        return out

    # -- in-flight pinning (scheduler brackets every engine call) -----------
    def begin_group(self, graphs: List[Graph]) -> None:
        with self._lock:
            for g in graphs:
                key = id(g)
                self._busy[key] = self._busy.get(key, 0) + 1

    def end_group(self, graphs: List[Graph]) -> None:
        with self._lock:
            for g in graphs:
                key = id(g)
                n = self._busy.get(key, 0) - 1
                if n <= 0:
                    self._busy.pop(key, None)
                else:
                    self._busy[key] = n
        self.maybe_evict()

    # -- accounting ---------------------------------------------------------
    def _plan_totals_locked(self) -> Tuple[int, int, List[Tuple[int, str, Any]]]:
        """(total plan bytes, evictable plan bytes, evictable candidates).

        Candidates — ``(bytes, family, plan)`` — cover only graphs with no
        in-flight batch; busy graphs' evictable bytes still count toward the
        total (they are tracked, just momentarily unevictable).
        """
        total = evictable = 0
        candidates: List[Tuple[int, str, Any]] = []
        for g in self._live_graphs_locked():
            p = g._plan
            if p is None:
                continue
            fams = p.nbytes_by_family()
            total += sum(fams.values())
            busy = self._busy.get(id(g), 0) > 0
            for f in EVICTABLE_FAMILIES:
                b = fams[f]
                evictable += b
                if b > 0 and not busy:
                    candidates.append((b, f, p))
        return total, evictable, candidates

    def _prune_lineage_locked(self) -> None:
        cuts = 0
        for g in self._live_graphs_locked():
            cuts += g.prune_lineage(self.policy.max_lineage_depth)
        if cuts:
            self.service._bump("lineage_cuts", cuts)

    def tracked_bytes(self) -> int:
        with self._lock:
            _, evictable, _ = self._plan_totals_locked()
            with self.service._lock:
                return self.service._cache_bytes + evictable

    def on_cache_change(self) -> None:
        """Cheap hook after every ``_cache_put``: O(1) gauge refresh when
        unbudgeted, full eviction pass when a budget is set (a retention put
        at submit time can push past the budget between engine calls)."""
        if self.policy.budget_bytes is None:
            with self.service._lock:
                _G_CACHE_BYTES.set(self.service._cache_bytes)
            return
        self.maybe_evict()

    def maybe_evict(self) -> None:
        """One full accounting pass: prune lineage, evict to budget, gauge."""
        svc = self.service
        with self._lock:
            self._prune_lineage_locked()
            budget = self.policy.budget_bytes
            plan_total, plan_ev, candidates = self._plan_totals_locked()
            n_results = n_plans = freed = 0
            if budget is not None:
                # 1) result cache, LRU order — cheapest to restore
                with svc._lock:
                    while svc._cache_bytes + plan_ev > budget and svc._cache:
                        key, _ = svc._cache.popitem(last=False)
                        cost = svc._cache_cost.pop(key, 0)
                        svc._cache_bytes -= cost
                        n_results += 1
                        freed += cost
                        self.evlog.append(("result", cost))
                    cache_bytes = svc._cache_bytes
                # 2) plan families of idle graphs, largest first
                if cache_bytes + plan_ev > budget:
                    for b, fam, p in sorted(candidates, key=lambda c: -c[0]):
                        if cache_bytes + plan_ev <= budget:
                            break
                        got = p.evict(fam)
                        plan_ev = max(plan_ev - got, 0)
                        plan_total = max(plan_total - got, 0)
                        n_plans += 1
                        freed += got
                        self.evlog.append(("plan", got))
            with svc._lock:
                cache_bytes = svc._cache_bytes
            _G_PLAN_BYTES.set(plan_total)
            _G_PLAN_EVICTABLE.set(plan_ev)
            _G_CACHE_BYTES.set(cache_bytes)
            _G_TRACKED.set(cache_bytes + plan_ev)
            _G_BUDGET.set(0 if budget is None else budget)
            _G_PINS.set(prov.pin_stats()["pinned"])
        if n_results:
            svc._bump("evicted_results", n_results)
        if n_plans:
            svc._bump("evicted_plan_families", n_plans)
        if freed:
            svc._bump("evicted_bytes", freed)

    def stats(self) -> Dict[str, int]:
        """Point-in-time memory accounting (also the session_stats payload)."""
        with self._lock:
            plan_total, plan_ev, _ = self._plan_totals_locked()
            with self.service._lock:
                cache_bytes = self.service._cache_bytes
                entries = len(self.service._cache)
        pins = prov.pin_stats()
        budget = self.policy.budget_bytes
        return {"tracked_bytes": cache_bytes + plan_ev,
                "budget_bytes": 0 if budget is None else int(budget),
                "result_cache_bytes": cache_bytes,
                "result_cache_entries": entries,
                "plan_bytes": plan_total,
                "plan_evictable_bytes": plan_ev,
                "provenance_pins": pins["pinned"],
                "provenance_pin_bytes": pins["bytes"]}


# ---------------------------------------------------------------------------
# Workspace — shared named/versioned objects (Ringo's big-memory heap)
# ---------------------------------------------------------------------------


class Workspace:
    """Named, versioned tables/graphs shared across sessions.

    The workspace owns the long-lived references, which is what makes the
    identity-memoized caches effective: as long as a graph stays in the
    workspace, its ``GraphPlan`` (sorted edges, BSR tiles, chunk layouts) and
    every service-cache entry keyed by its version token stay warm.
    """

    def __init__(self):
        self._objs: Dict[str, Any] = {}
        # name -> version token, written in the same critical section as
        # _objs: reads of (object, version) pairs are always consistent,
        # and update()'s CAS compares against it.
        self._versions: Dict[str, str] = {}
        self._lock = threading.RLock()

    def put(self, name: str, obj: Any) -> str:
        """Bind ``name`` to ``obj``; returns the object's version token."""
        with self._lock:
            self._objs[name] = obj
            v = prov.version_of(obj)
            self._versions[name] = v
            return v

    def get(self, name: str) -> Any:
        with self._lock:
            if name not in self._objs:
                raise KeyError(f"no workspace object {name!r}; "
                               f"have {sorted(self._objs)}")
            return self._objs[name]

    def version(self, name: str) -> str:
        with self._lock:
            if name in self._versions:
                return self._versions[name]
        return prov.version_of(self.get(name))

    def update(self, name: str, fn: Callable[[Any], Any]) -> str:
        """Functional update: bind ``name`` to ``fn(current)``.

        The result is a fresh object with a fresh version token — downstream
        plan caches and service result caches keyed by the old token simply
        stop matching (invalidation by construction, never by broadcast).

        ``fn`` still runs *outside* the workspace lock (a big-graph rebuild
        must not stall every other session's reads), but the read-modify-
        write of the name→version map is a compare-and-swap: the new binding
        only lands if ``name`` still holds the version the update read.
        When a concurrent update (another thread, or another server
        connection) won the race, ``fn`` re-runs against the fresh object —
        no update is ever silently lost.  ``fn`` must therefore be pure.
        """
        while True:
            with self._lock:
                cur = self.get(name)
                cur_ver = self._versions.get(name)
            new = fn(cur)
            with self._lock:
                if self._versions.get(name) != cur_ver \
                        or self._objs.get(name) is not cur:
                    continue          # lost the race; redo against fresh
                self._objs[name] = new
                v = prov.version_of(new)
                self._versions[name] = v
                return v

    def apply_delta(self, name: str, delta: EdgeDelta) -> str:
        """Publish ``name``'s graph with ``delta`` applied; returns the new
        version token.

        A convenience over :meth:`update` that keeps the delta on the
        functional-update path: the child graph carries its ``_delta``
        lineage, so downstream plan builds patch instead of rebuilding and
        the service's delta-aware cache retention / warm starts engage.
        Like any ``update``, a lost CAS race re-applies the delta against
        the fresh object — deltas from concurrent writers all land.
        """
        return self.update(name, lambda g: g.apply_delta(delta))

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._objs)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._objs


# ---------------------------------------------------------------------------
# Session — one analyst's namespace over the workspace
# ---------------------------------------------------------------------------


class Session:
    """Per-analyst namespace layered over a shared :class:`Workspace`.

    Reads fall through to the workspace; writes (``put`` and request
    ``"as"`` bindings) stay session-local until :meth:`publish` — the
    isolation contract that lets many analysts iterate on the same shared
    graphs without trampling each other's intermediates.
    """

    def __init__(self, service: "GraphService", name: str):
        self.service = service
        self.name = name
        self._local: Dict[str, Any] = {}
        self._lock = threading.RLock()

    # -- namespace ----------------------------------------------------------
    def put(self, name: str, obj: Any) -> str:
        with self._lock:
            self._local[name] = obj
            return prov.version_of(obj)

    def get(self, name: str) -> Any:
        with self._lock:
            if name in self._local:
                return self._local[name]
        return self.service.workspace.get(name)

    def publish(self, name: str) -> str:
        """Promote a session-local object into the shared workspace."""
        with self._lock:
            if name not in self._local:
                raise KeyError(f"session {self.name!r} has no local object "
                               f"{name!r}")
            obj = self._local.pop(name)
        return self.service.workspace.put(name, obj)

    def local_names(self) -> List[str]:
        with self._lock:
            return sorted(self._local)

    # -- execution ----------------------------------------------------------
    def submit(self, request: Dict[str, Any]) -> "Pending":
        return self.service.submit(self, request)

    def execute(self, request: Dict[str, Any]) -> Any:
        return self.service.execute(self, request)


# ---------------------------------------------------------------------------
# Pending — a submitted request's future result
# ---------------------------------------------------------------------------


class Pending:
    """Handle for a submitted request; resolved by the scheduler."""

    def __init__(self, session: Session, request: Dict[str, Any]):
        self.session = session
        self.request = request
        #: trace id this request rides under (set from the request body or
        #: the submit call; lands on provenance meta and every span)
        self.trace: Optional[str] = request.get("trace")
        self.done = False
        self.value: Any = None
        self.error: Optional[BaseException] = None
        self.cached = False
        self.fused = False
        self.submitted_at = time.perf_counter()
        self.dispatched_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        self._event = threading.Event()
        self._cb_lock = threading.Lock()
        self._callbacks: Optional[List[Callable[["Pending"], None]]] = []

    @property
    def latency_ms(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return (self.completed_at - self.submitted_at) * 1e3

    @property
    def queued_ms(self) -> Optional[float]:
        """Time spent waiting for the scheduler to dispatch this request."""
        if self.dispatched_at is None:
            return None
        return (self.dispatched_at - self.submitted_at) * 1e3

    def _resolve(self, value: Any = None,
                 error: Optional[BaseException] = None,
                 cached: bool = False, fused: bool = False) -> None:
        self.value, self.error = value, error
        self.cached, self.fused = cached, fused
        self.completed_at = time.perf_counter()
        self.done = True
        self._event.set()
        with self._cb_lock:
            cbs, self._callbacks = self._callbacks, None
        for fn in cbs or ():
            try:
                fn(self)
            except Exception:        # a dead callback must not poison the
                pass                 # scheduler thread resolving us

    def add_done_callback(self, fn: Callable[["Pending"], None]) -> None:
        """Run ``fn(self)`` when resolved (immediately if already done).

        This is the server's streaming hook: a socket connection registers a
        callback that frames the result back to the client the moment the
        scheduler resolves it — completion order, not submission order.
        Callbacks run on the resolving thread; exceptions are swallowed.
        """
        with self._cb_lock:
            if self._callbacks is not None:
                self._callbacks.append(fn)
                return
        try:
            fn(self)
        except Exception:
            pass

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self.done:
            # sync services drain inline; worker-backed ones just wait
            # (another thread's drain may have claimed this request mid-run)
            self.session.service._ensure_progress()
            if not self._event.wait(timeout):
                raise TimeoutError(
                    f"request {self.request.get('op')!r} still pending "
                    f"after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.value


# ---------------------------------------------------------------------------
# GraphService — declarative execution, fusion scheduling, result caching
# ---------------------------------------------------------------------------


class GraphService:
    """Front end executing declarative requests from concurrent sessions.

    Request shape::

        {"op": "pagerank", "graph": "qa", "params": {"n_iter": 20},
         "as": "pr"}                    # optional session-local binding

    Named-object slots are op-specific: ``"table"`` / ``"left"`` + ``"right"``
    for relational ops, ``"graph"`` for conversions and algorithms, plus
    ``"scores"`` for ``table_from_map``.  Slots resolve session-first, then
    workspace.  ``params`` holds the remaining literal keyword arguments of
    the underlying function.  A request may additionally carry
    ``"deadline_ms"``: if the scheduler cannot dispatch it within that
    budget it resolves with :class:`DeadlineExpired` instead of reaching
    the engine.

    Named inputs resolve at **submit** time, pinning the object versions
    the session named (a concurrent workspace update cannot change what an
    already-submitted request computes).  Consequently a request that
    consumes another request's ``"as"`` binding must be submitted after
    the producer has *resolved* (``execute`` or ``result()``), not merely
    after it was submitted — the binding does not exist before then.

    ``policy`` configures admission control, fair share and batching
    windows (:class:`~repro.serve.policy.SchedulerPolicy`); over-quota
    submits raise :class:`RejectedError` with a ``retry_after`` hint.
    ``workers`` starts that many background scheduler threads — the serving
    mode the overload benchmark measures; with ``workers=0`` the scheduler
    runs inline at :meth:`flush` (deterministic, test-friendly).
    """

    def __init__(self, workspace: Optional[Workspace] = None, *,
                 fuse: bool = True, cache: bool = True, incremental: bool = True,
                 max_cache_entries: int = 1024,
                 policy: Optional[SchedulerPolicy] = None,
                 memory: Optional[MemoryPolicy] = None,
                 workers: int = 0,
                 engine_backend: Optional[str] = None):
        self.workspace = workspace if workspace is not None else Workspace()
        self.fuse = fuse
        # default engine backend for every _BACKEND_OPS request that does
        # not name one explicitly ("sharded" turns the whole service
        # multi-device); injected before canonicalization in _prepare so
        # result-cache and fusion keys never mix backends
        self.engine_backend = engine_backend
        self.cache_enabled = cache
        # delta-aware serving: retain provably-unaffected cache entries
        # across Workspace.apply_delta and warm-start recomputation from the
        # parent version's cached result (``incremental=False`` restores
        # cold-only behavior, e.g. for differential testing)
        self.incremental = incremental
        self._cache: "OrderedDict[Tuple, Any]" = OrderedDict()
        self._cache_cost: Dict[Tuple, int] = {}
        self._cache_bytes = 0
        self._max_cache = max_cache_entries
        self._lock = threading.RLock()
        self._sessions: Dict[str, Session] = {}
        # per-session result-cache accounting, exposed via session_stats
        self._session_counters: Dict[str, Dict[str, int]] = {}
        self.stats = {"requests": 0, "cache_hits": 0, "cache_misses": 0,
                      "fused_calls": 0, "fused_requests": 0,
                      "engine_calls": 0, "rejected": 0, "expired": 0,
                      "batch_windows": 0, "retained": 0, "warm_starts": 0,
                      "incremental_fallbacks": 0,
                      "evicted_results": 0, "evicted_plan_families": 0,
                      "evicted_bytes": 0, "lineage_cuts": 0}
        # dedicated innermost lock for the ``stats`` dict: it is bumped from
        # submitters (under self._lock), scheduler workers (under the
        # scheduler's lock) and drain callers — a bare ``+=`` under two
        # *different* outer locks is a lost-update race.  Every mutation
        # goes through _bump; nothing else is ever taken while holding it.
        self._stats_lock = threading.Lock()
        self.policy = policy if policy is not None else SchedulerPolicy()
        # memory budget: explicit ``memory=`` beats the policy's; the pin
        # ring is process-global, so the most recent service's cap applies
        self.memory = memory if memory is not None else self.policy.memory
        prov.set_pin_capacity(self.memory.max_provenance_pins)
        self._mem = _MemoryManager(self, self.memory)
        self.scheduler = Scheduler(self, self.policy)
        self._stop = threading.Event()
        self._worker_threads: List[threading.Thread] = []
        for i in range(workers):
            t = threading.Thread(target=self.scheduler.run_loop,
                                 args=(self._stop,), daemon=True,
                                 name=f"graph-service-worker-{i}")
            t.start()
            self._worker_threads.append(t)

    def _bump(self, key: str, n: int = 1) -> None:
        """Increment a service counter (thread-safe) and mirror it to the
        observability registry as ``service.<key>``."""
        with self._stats_lock:
            self.stats[key] += n
        obs.counter(f"service.{key}").inc(n)

    def close(self) -> None:
        """Stop background workers, then drain whatever they left queued.

        Without the drain, a thread already blocked in ``Pending.result()``
        on a request the dying workers never reached would wait forever —
        worker-backed services skip the inline drain in ``_ensure_progress``.
        """
        self._stop.set()
        with self.scheduler._cond:
            self.scheduler._cond.notify_all()
        for t in self._worker_threads:
            t.join(timeout=5.0)
        self._worker_threads = []
        self.scheduler.drain()

    # -- sessions -----------------------------------------------------------
    def session(self, name: str) -> Session:
        with self._lock:
            if name not in self._sessions:
                self._sessions[name] = Session(self, name)
            return self._sessions[name]

    def session_stats(self, name: str) -> Dict[str, Any]:
        """Accounting for one session: the scheduler snapshot (queue,
        deficit, engine-ms consumed, completions, rejections, expiries)
        merged with the service's result-cache counters — ``cache_hits``,
        ``cache_misses`` and ``retained`` (hits served by a cache entry
        re-bound across a delta).  Flat scalars, so the wire codec ships
        the dict unchanged."""
        out = self.scheduler.session_stats(name)
        with self._lock:
            c = self._session_counters.get(name)
            out.update(c if c is not None
                       else {"cache_hits": 0, "cache_misses": 0,
                             "retained": 0})
        # service-wide memory accounting (same for every session): what the
        # server is holding on clients' behalf, visible over the wire
        out.update({f"mem_{k}": v for k, v in self._mem.stats().items()})
        return out

    def end_session(self, name: str) -> None:
        """Drop a session's namespace and (if idle) its scheduler state.

        Called by the socket server when a connection closes: without it,
        every connection would leak a session namespace and a deficit-
        round-robin ring entry for the life of the service.  Scheduler
        state with queued or in-flight work survives until it drains.
        """
        with self._lock:
            self._sessions.pop(name, None)
            self._session_counters.pop(name, None)
        self.scheduler.forget_session(name)

    # -- submission ---------------------------------------------------------
    def submit(self, session: Session, request: Dict[str, Any],
               trace: Optional[str] = None) -> Pending:
        """Validate, prepare and enqueue a request.

        Raises :class:`RejectedError` (with ``retry_after``) when the
        session is over its in-flight quota or the service backlog is at
        its depth bound.  Preparation errors (unknown names, missing slots)
        resolve the returned :class:`Pending` instead of raising here.

        ``trace`` attaches a trace id (e.g. one extracted from the wire) to
        the request's spans and result provenance; without one the request
        inherits the submitting thread's active trace, or mints a fresh id
        (so flight-recorder exemplars always carry span evidence — the
        remote client does the same on its side of the wire).
        """
        op = request.get("op")
        if op not in _OPS:
            raise ServiceError(f"unknown op {op!r}; have {sorted(_OPS)}")
        p = Pending(session, dict(request))
        if trace is not None:
            p.trace = trace
        elif p.trace is None:
            p.trace = obs.current_trace()
        if p.trace is None and obs.TRACER.enabled:
            p.trace = obs.new_trace_id()
        self._bump("requests")
        with obs.TRACER.span("service.submit", trace=p.trace, op=op,
                             session=session.name):
            q = self._prepare(p)
            if q is None:
                # preparation error resolved p without touching the
                # scheduler, so its completion seam never fires — feed the
                # flight recorder here for error-exemplar completeness
                obs.FLIGHT.record_pending(p, op=op, session=session.name)
                return p
            # cache fast path: a repeated trial-and-error query resolves at
            # submit, skipping admission and the scheduler round trip — it
            # consumes no engine time, so there is nothing to admission-
            # control or charge, and the serving path (local or wire) sees
            # memory-speed latency.  The speculative probe must not count a
            # miss: the authoritative lookup happens again at dispatch.
            # Delta retention runs first so a provably-unaffected query
            # against a freshly-updated graph also resolves at submit.
            self._try_retain(q)
            hit, found = self._cache_get(q.cache_key, count_miss=False,
                                         session=p.session.name)
            if found:
                obs.TRACER.instant("service.cache_hit_submit", trace=p.trace,
                                   op=op, session=session.name)
                self._finish(p, hit, cached=True)
                # submit-time cache hits also bypass the scheduler's
                # completion seam; record so SLO windows count every request
                obs.FLIGHT.record_pending(p, op=op, session=session.name)
                return p
            self.scheduler.submit(q)
        return p

    def execute(self, session: Session, request: Dict[str, Any]) -> Any:
        p = self.submit(session, request)
        self.flush()
        return p.result()

    # -- request resolution -------------------------------------------------
    def _resolve_inputs(self, p: Pending) -> List[Tuple[str, Any]]:
        """(param_name, object) pairs for the request's named-object slots."""
        _, slots = _OPS[p.request["op"]]
        out = []
        for slot, param in slots.items():
            if slot not in p.request:
                raise ServiceError(
                    f"op {p.request['op']!r} needs a {slot!r} name")
            out.append((param, p.session.get(p.request[slot])))
        return out

    def _cache_key(self, op: str, inputs: List[Tuple[str, Any]],
                   canon: Tuple) -> Optional[Tuple]:
        if not self.cache_enabled or prov.contains_opaque(canon):
            return None
        versions = tuple((name, prov.version_of(obj)) for name, obj in inputs)
        # order-insensitive: {"a":1,"b":2} and {"b":2,"a":1} are one key
        return (op, versions, tuple(sorted(canon, key=lambda kv: kv[0])))

    def _sess_counter(self, session: str) -> Dict[str, int]:
        """Per-session cache counters; caller holds ``self._lock``."""
        c = self._session_counters.get(session)
        if c is None:
            c = self._session_counters[session] = {
                "cache_hits": 0, "cache_misses": 0, "retained": 0}
        return c

    def _cache_get(self, key: Optional[Tuple], count_miss: bool = True,
                   session: Optional[str] = None):
        if key is None:
            return None, False
        with self._lock:
            if key in self._cache:
                self._cache.move_to_end(key)
                if session is not None:
                    self._sess_counter(session)["cache_hits"] += 1
                hit = self._cache[key]
            else:
                if count_miss and session is not None:
                    self._sess_counter(session)["cache_misses"] += 1
                hit = _MISS
        if hit is not _MISS:
            self._bump("cache_hits")
            return hit, True
        if count_miss:
            self._bump("cache_misses")
        return None, False

    def _cache_put(self, key: Optional[Tuple], value: Any) -> None:
        """Insert under byte accounting; evict LRU-first past any bound.

        Every entry carries its byte cost (payload arrays + a flat
        overhead); the running total feeds the memory manager, which brings
        tracked bytes back under :class:`MemoryPolicy`'s budget after the
        insert — result entries before plan members, never mid-batch.
        """
        if key is None:
            return
        cost = _value_nbytes(value)
        _H_ENTRY_BYTES.observe(cost)
        with self._lock:
            old = self._cache_cost.pop(key, None)
            if old is not None:
                self._cache_bytes -= old
            self._cache[key] = value
            self._cache.move_to_end(key)
            self._cache_cost[key] = cost
            self._cache_bytes += cost
            while len(self._cache) > self._max_cache:
                k, _ = self._cache.popitem(last=False)
                self._cache_bytes -= self._cache_cost.pop(k, 0)
        self._mem.on_cache_change()

    # -- preparation (submit-time resolution) -------------------------------
    def _prepare(self, p: Pending) -> Optional[QueuedRequest]:
        """Resolve names and compute fusion/cache keys at submit time.

        Resolving here pins the object versions the session named at
        submission — coalescing and caching later must not observe a
        concurrent workspace update.  A resolution error resolves the
        :class:`Pending` (the submitter sees it at ``result()``) and
        returns None so nothing is enqueued.
        """
        op = p.request["op"]
        try:
            inputs = self._resolve_inputs(p)
            params = dict(p.request.get("params") or {})
            if (self.engine_backend is not None and op in _BACKEND_OPS
                    and params.get("backend") is None):
                params["backend"] = self.engine_backend
            canon = prov.canonical_params(params)
            key = self._cache_key(op, inputs, canon)
        except Exception as e:
            p._resolve(error=e)
            return None
        for _, o in inputs:
            if isinstance(o, Graph):
                self._mem.note_graph(o)
        payload: Dict[str, Any] = {"inputs": inputs, "params": params}
        fuse_key = None
        src_param = _FUSABLE.get(op)
        source = params.get(src_param) if src_param else None
        n_iter = params.get("n_iter")
        if (self.fuse and src_param
                and isinstance(source, (int, np.integer))
                and not isinstance(source, bool)
                and (n_iter is None or (isinstance(n_iter, (int, np.integer))
                                        and not isinstance(n_iter, bool)))
                and not (op == "sssp"
                         and _sssp_weights_block_fusion(canon))):
            # n_iter joins source as a per-request coordinate: requests that
            # differ only in depth still share one fused engine call
            rest = tuple(sorted(((k, v) for k, v in canon
                                 if k not in (src_param, "n_iter")),
                                key=lambda kv: kv[0]))
            fuse_key = (op, prov.version_of(inputs[0][1]), rest)
            payload.update(graph=inputs[0][1], source=int(source),
                           n_iter=None if n_iter is None else int(n_iter))
        deadline_ms = p.request.get("deadline_ms",
                                    self.policy.default_deadline_ms)
        deadline = (None if deadline_ms is None
                    else p.submitted_at + float(deadline_ms) / 1e3)
        return QueuedRequest(pending=p, session=p.session.name, op=op,
                             cache_key=key, fuse_key=fuse_key,
                             payload=payload, deadline=deadline)

    # -- scheduler callbacks ------------------------------------------------
    @staticmethod
    def _group_graphs(group: List[QueuedRequest]) -> List[Graph]:
        """Distinct input graphs an engine call for ``group`` will touch."""
        out: List[Graph] = []
        seen: set = set()
        for q in group:
            for o in ([q.payload.get("graph")]
                      + [x for _, x in q.payload.get("inputs", ())]):
                if isinstance(o, Graph) and id(o) not in seen:
                    seen.add(id(o))
                    out.append(o)
        return out

    def _mem_begin(self, group: List[QueuedRequest]) -> None:
        """Scheduler bracket: pin the group's graphs against plan eviction
        for the duration of the engine call (eviction must never race an
        in-flight batch's plan arrays)."""
        self._mem.begin_group(self._group_graphs(group))

    def _mem_end(self, group: List[QueuedRequest]) -> None:
        """Unpin + run an accounting/eviction pass (plans likely grew)."""
        self._mem.end_group(self._group_graphs(group))

    def memory_stats(self) -> Dict[str, int]:
        """Tracked-bytes accounting: budget, result cache, plan families,
        provenance pins.  Flat scalars — ships over the wire unchanged."""
        return self._mem.stats()

    def _cache_lookup(self, q: QueuedRequest) -> Tuple[Any, bool]:
        self._try_retain(q)
        return self._cache_get(q.cache_key, session=q.session)

    def _finish_cached(self, q: QueuedRequest, value: Any) -> None:
        obs.TRACER.instant("service.cache_hit", trace=q.pending.trace,
                           op=q.op, session=q.session)
        self._finish(q.pending, value, cached=True)

    def _sched_meta(self, q: QueuedRequest, batch: int
                    ) -> Dict[str, Any]:
        """Queueing/coalescing metadata recorded on result provenance."""
        queued = q.pending.queued_ms
        meta = {"queued_ms": 0.0 if queued is None else round(queued, 3),
                "batch": batch, "sched_mode": self.policy.mode}
        if q.pending.trace is not None:
            meta["trace"] = q.pending.trace
        return meta

    # -- incremental maintenance (delta-aware serving) ----------------------
    def _delta_of(self, q: QueuedRequest):
        """(graph, delta-info) when the request's sole input is a graph
        produced by the insert-only ``apply_delta`` fast path, else None."""
        inputs = q.payload["inputs"]
        if len(inputs) != 1 or not isinstance(inputs[0][1], Graph):
            return None
        g = inputs[0][1]
        info = g._delta
        if info is None:
            return None
        return g, info

    def _parent_key(self, q: QueuedRequest, parent: Graph
                    ) -> Optional[Tuple]:
        """``q.cache_key`` re-pointed at the parent graph's version."""
        if q.cache_key is None:
            return None
        op, versions, canon = q.cache_key
        if len(versions) != 1:
            return None
        (name, _), = versions
        return (op, ((name, prov.version_of(parent)),), canon)

    def _parent_cached(self, q: QueuedRequest, parent: Graph):
        """Parent-version cache entry without touching hit/miss counters."""
        pkey = self._parent_key(q, parent)
        if pkey is None:
            return None, False
        with self._lock:
            if pkey in self._cache:
                return self._cache[pkey], True
        return None, False

    def _try_retain(self, q: QueuedRequest) -> bool:
        """Re-bind the parent version's cached result to ``q``'s key when
        the delta provably cannot change it (see :func:`_retention_safe`).

        The retained entry then serves this and every future identical
        query against the child version as an ordinary cache hit — the
        query never reaches the engine even though the graph changed.
        """
        if not self.incremental or q.op not in _RETAINABLE \
                or q.cache_key is None:
            return False
        with self._lock:
            if q.cache_key in self._cache:
                return False          # already resident; nothing to retain
        gi = self._delta_of(q)
        if gi is None:
            return False
        g, info = gi
        if not info.insert_only:
            return False              # deletions can affect any result
        parent_val, found = self._parent_cached(q, info.parent)
        if not found:
            return False
        try:
            if not _retention_safe(q.op, g, info, parent_val,
                                   q.payload["params"]):
                return False
        except Exception:
            _log.exception("retention.predicate_failed", op=q.op,
                           session=q.session, action="running cold")
            return False
        self._cache_put(q.cache_key, parent_val)
        self._bump("retained")
        with self._lock:
            self._sess_counter(q.session)["retained"] += 1
        return True

    def _try_warm(self, q: QueuedRequest) -> Optional[Any]:
        """Warm-start ``q`` from the parent version's cached result.

        Returns the (blocked) result, or None to run cold.  Soundness
        gates mirror the incremental helpers in :mod:`repro.core.algorithms`:
        traversals/labels need an insert-only delta, an uncapped run and the
        exact parent result; pagerank/PPR warm from any delta but only
        under ``tol`` semantics (a warm fixed-``n_iter`` run would be a
        *different* iterate than the cold one, so it never substitutes).
        The result's provenance is rewritten to the equivalent cold call —
        export/replay are oblivious to the warm start, exactly as they are
        to fusion.
        """
        if not self.incremental or q.op not in _WARM_OPS:
            return None
        gi = self._delta_of(q)
        if gi is None:
            return None
        g, info = gi
        op = q.op
        params = dict(q.payload["params"])
        parent_val, found = self._parent_cached(q, info.parent)
        out = None
        try:
            if not found:
                pass                  # no parent result to warm from
            elif op == "pagerank":
                if params.get("tol") is not None and "init" not in params:
                    out = A.pagerank(g, init=parent_val, **params)
            elif op == "personalized_pagerank":
                source = params.pop("source", None)
                if (params.get("tol") is not None and "init" not in params
                        and isinstance(source, (int, np.integer))
                        and not isinstance(source, bool)):
                    out = A.personalized_pagerank(g, int(source),
                                                  init=parent_val, **params)
            elif op in ("bfs", "sssp"):
                source = params.pop("source", None)
                # "backend" is neutral to warm soundness: every backend is
                # value-identical (the sharded engine bit-identically so),
                # so the default-backend warm helpers substitute for any
                extra = set(params) - {"n_iter", "weights", "backend"}
                if (not extra and params.get("n_iter") is None
                        and params.get("weights") is None
                        and isinstance(source, (int, np.integer))
                        and not isinstance(source, bool)):
                    warm = A.incremental_bfs if op == "bfs" \
                        else A.incremental_sssp
                    out = warm(g, int(source), parent_val)
            elif op == "connected_components":
                if not set(params) - {"backend"}:
                    out = A.incremental_connected_components(g, parent_val)
            else:                     # label_propagation
                if not set(params) - {"n_iter", "backend"}:
                    out = A.incremental_label_propagation(
                        g, parent_val, n_iter=params.get("n_iter", 20))
        except Exception:
            _log.exception("warm_start.failed", op=op, session=q.session,
                           action="running cold")
            out = None
        if out is None:
            self._bump("incremental_fallbacks")
            _log.info("incremental_fallback", op=op, session=q.session)
        else:
            self._bump("warm_starts")
        return None if out is None else _block(out)

    def _run_group(self, group: List[QueuedRequest]) -> float:
        """Execute one engine call for ``group``; returns measured engine ms.

        A singleton non-fusable request calls its op directly.  A fused
        group shares every parameter except ``source`` and ``n_iter``:
        mixed depths run as ONE batch to the max cap with each row frozen
        at its own — bit-identical to running every request sequentially at
        its own depth — and rows scatter back per request.
        """
        if not group:
            return 0.0
        q0 = group[0]
        op = q0.op
        fn, _ = _OPS[op]
        self._bump("engine_calls")
        if len(group) > 1:
            self._bump("fused_calls")
            self._bump("fused_requests", len(group))
        if q0.fuse_key is None:
            t0 = time.perf_counter()
            with obs.TRACER.span(f"engine.{op}", trace=q0.pending.trace,
                                 op=op, batch=1, session=q0.session) as esp:
                out = self._try_warm(q0)
                if out is None:
                    esp.set(warm=False)
                    out = _block(fn(**dict(q0.payload["inputs"]),
                                    **q0.payload["params"]))
                    dt = (time.perf_counter() - t0) * 1e3
                    prov.annotate_last(out, self._sched_meta(q0, 1))
                else:
                    # warm-started: the recorded provenance is the equivalent
                    # cold call (the warm init would be an opaque array), with
                    # the warm start visible only as metadata
                    esp.set(warm=True)
                    dt = (time.perf_counter() - t0) * 1e3
                    meta = dict(self._sched_meta(q0, 1), incremental=True)
                    prov.record_call(_PROV_ANY[op], q0.payload["inputs"],
                                     q0.payload["params"], out, meta=meta)
            self._cache_put(q0.cache_key, out)
            self._finish(q0.pending, out)
            return dt
        src_param = _FUSABLE[op]
        g = q0.payload["graph"]   # pinned at submit: the version keys name
        params = dict(q0.payload["params"])
        params.pop(src_param, None)
        params.pop("n_iter", None)
        sources = [m.payload["source"] for m in group]
        n_iters = [m.payload["n_iter"] for m in group]
        if len(group) == 1:
            kw = dict(params)
            if n_iters[0] is not None:
                kw["n_iter"] = n_iters[0]
            t0 = time.perf_counter()
            with obs.TRACER.span(f"engine.{op}", trace=q0.pending.trace,
                                 op=op, batch=1, session=q0.session) as esp:
                out = self._try_warm(q0)
                if out is None:
                    esp.set(warm=False)
                    out = _block(fn(g, sources[0], **kw))
                    dt = (time.perf_counter() - t0) * 1e3
                    prov.annotate_last(out, self._sched_meta(q0, 1))
                else:
                    esp.set(warm=True)
                    dt = (time.perf_counter() - t0) * 1e3
                    meta = dict(self._sched_meta(q0, 1), incremental=True)
                    prov.record_call(_PROV_ANY[op], [("g", g)],
                                     {**kw, src_param: sources[0]}, out,
                                     meta=meta)
            self._cache_put(q0.cache_key, out)
            self._finish(q0.pending, out)
            return dt
        default = _FUSE_DEPTH_DEFAULT[op]
        if default is None:
            default = g.n_nodes            # convergence bound for bfs/sssp
        uniform = len(set(n_iters)) == 1
        if uniform and n_iters[0] is None:
            kw = dict(params)              # all-unbounded: plain fused call
        elif uniform:
            kw = dict(params, n_iter=n_iters[0])
        else:
            caps = [default if ni is None else int(ni) for ni in n_iters]
            kw = dict(params, n_iter=np.asarray(caps, np.int32))
        t0 = time.perf_counter()
        with obs.TRACER.span(
                f"engine.{op}", trace=q0.pending.trace,
                traces=[m.pending.trace for m in group
                        if m.pending.trace is not None],
                op=op, batch=len(group),
                sources=sources if len(sources) <= 16 else len(sources)):
            rows = _block(fn(g, jnp.asarray(sources, dtype=jnp.int32), **kw))
        dt = (time.perf_counter() - t0) * 1e3
        for i, m in enumerate(group):
            row = rows[i]
            # the row's provenance is the *single-source* call it stands
            # for — export/replay must not see the fusion batch; the batch
            # shows up only as scheduling metadata on the record
            req_params = {**params, src_param: m.payload["source"]}
            if m.payload["n_iter"] is not None:
                req_params["n_iter"] = int(m.payload["n_iter"])
            prov.record_call(_PROV_OP[op], [("g", g)], req_params, row,
                             meta=self._sched_meta(m, len(group)))
            self._cache_put(m.cache_key, row)
            self._finish(m.pending, row, fused=True)
        return dt

    # -- draining -----------------------------------------------------------
    def flush(self) -> None:
        """Drain the scheduler inline: admission-passed requests execute in
        fair-share (or FIFO) order, coalescing whatever is compatible."""
        self.scheduler.drain()

    def _ensure_progress(self) -> None:
        """Called by :meth:`Pending.result`: inline services drain; worker-
        backed ones rely on their threads."""
        if not self._worker_threads:
            self.scheduler.drain()

    def _finish(self, p: Pending, value: Any, cached: bool = False,
                fused: bool = False) -> None:
        bind = p.request.get("as")
        if bind:
            p.session.put(bind, value)
        p._resolve(value=value, cached=cached, fused=fused)
