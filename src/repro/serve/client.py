"""Remote client: the in-process Workspace API over a socket.

:class:`RemoteService` / :class:`RemoteWorkspace` / :class:`RemoteSession` /
:class:`RemotePending` mirror :class:`~repro.serve.graph_service.
GraphService` / ``Workspace`` / ``Session`` / ``Pending`` closely enough
that the §4.1 expert-finding workload (``examples/stackoverflow_experts.
py``) runs unchanged against either transport:

* ``submit`` is synchronous admission — a server-side quota or queue-depth
  rejection raises :class:`~repro.serve.policy.RejectedError` *at the call
  site* with its ``retry_after``, exactly like the in-process path;
* results stream back **out of order** (request ids, not call order); a
  background reader demultiplexes RESULT frames into the right
  :class:`RemotePending`;
* every object crossing the wire carries its provenance chain and version
  token; the client *adopts* them (:func:`repro.core.provenance.
  adopt_records`), so ``records_of``/``export_script`` on a remotely
  computed table behave as if the computation had happened here.  Roots the
  client itself ``put`` are bound to the server-assigned token, which is
  what lets ``export_script(embed_roots=True)`` embed the local copy;
* errors arrive as typed frames: ``DeadlineExpired``, ``ServiceError``,
  ``KeyError`` (missing names) come back as those exceptions.

The client is thread-safe: many threads may submit/await on one connection
(the benchmark's closed-loop workers do).  It never imports the engine —
decoding arrays is numpy-only, so a thin CLI process stays thin.
"""

from __future__ import annotations

import itertools
import socket
import threading
import time
from typing import Any, Dict, List, Optional

from .. import obs
from . import wire
from .policy import ServiceError, error_from_wire

__all__ = ["RemoteService", "RemoteWorkspace", "RemoteSession",
           "RemotePending", "connect"]


class RemotePending:
    """Client-side handle for a submitted request (mirrors ``Pending``)."""

    def __init__(self, service: "RemoteService", request: Dict[str, Any],
                 trace: Optional[str] = None):
        self.service = service
        self.request = request
        #: trace id this submit rode the wire under; pass it to
        #: ``RemoteService.chrome_trace`` to fetch the server-side spans of
        #: exactly this request
        self.trace = trace
        self.done = False
        self.value: Any = None
        self.error: Optional[BaseException] = None
        self.cached = False
        self.fused = False
        self.queued_ms: Optional[float] = None
        self.submitted_at = time.perf_counter()
        self.completed_at: Optional[float] = None
        self._event = threading.Event()

    @property
    def latency_ms(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return (self.completed_at - self.submitted_at) * 1e3

    def _resolve(self, value: Any = None,
                 error: Optional[BaseException] = None,
                 cached: bool = False, fused: bool = False,
                 queued_ms: Optional[float] = None) -> None:
        self.value, self.error = value, error
        self.cached, self.fused, self.queued_ms = cached, fused, queued_ms
        self.completed_at = time.perf_counter()
        self.done = True
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self.done:
            self.service._ensure_progress()
            if not self._event.wait(timeout):
                raise TimeoutError(
                    f"request {self.request.get('op')!r} still pending "
                    f"after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.value


class _RpcWaiter:
    __slots__ = ("event", "ftype", "payload")

    def __init__(self):
        self.event = threading.Event()
        self.ftype: Optional[int] = None
        self.payload: Any = None


class RemoteService:
    """One socket connection to a :class:`~repro.serve.server.GraphServer`.

    Mirrors the ``GraphService`` surface the examples and benchmarks use:
    ``.workspace``, ``.session(name)``, ``.submit/.execute`` (via sessions),
    ``.flush()``, ``.stats``, ``.close()``.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 timeout: float = 120.0):
        self.host, self.port = host, port
        self.rpc_timeout = timeout
        self._sock = socket.create_connection((host, port), timeout=30.0)
        self._sock.settimeout(None)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._send_lock = threading.Lock()
        self._lock = threading.Lock()
        self._req_seq = itertools.count(1)
        self._rpcs: Dict[int, _RpcWaiter] = {}
        self._pendings: Dict[int, RemotePending] = {}
        self._sessions: Dict[str, RemoteSession] = {}
        self._closed = threading.Event()
        self._conn_error: Optional[BaseException] = None
        self._reader = threading.Thread(target=self._read_loop, daemon=True,
                                        name="remote-service-reader")
        self._reader.start()
        try:
            hello = self._rpc("hello", protocol=wire.PROTOCOL_VERSION)
        except BaseException:
            self.close()         # don't leak the socket + reader thread on
            raise                # a failed handshake (retry loops reconnect)
        self.conn_id = hello["conn"]
        self.server_workers = int(hello.get("workers", 0))
        self.server_pid = hello.get("pid")
        self.workspace = RemoteWorkspace(self)

    # -- plumbing ------------------------------------------------------------
    def _next_id(self) -> int:
        return next(self._req_seq)

    def _send(self, req_id: int, msg: Dict[str, Any]) -> None:
        if self._closed.is_set():
            raise ServiceError("remote service connection is closed")
        with self._send_lock:
            wire.send_frame(self._sock, wire.FrameType.REQUEST, req_id, msg)

    def _rpc(self, kind: str, **fields: Any) -> Dict[str, Any]:
        req_id = self._next_id()
        waiter = _RpcWaiter()
        with self._lock:
            self._rpcs[req_id] = waiter
        try:
            self._send(req_id, wire.attach_trace({"kind": kind, **fields},
                                                 obs.current_trace()))
            if not waiter.event.wait(self.rpc_timeout):
                raise TimeoutError(f"rpc {kind!r} timed out after "
                                   f"{self.rpc_timeout}s")
        finally:
            with self._lock:
                self._rpcs.pop(req_id, None)
        if waiter.ftype == wire.FrameType.ERROR:
            raise error_from_wire(waiter.payload)
        return waiter.payload

    def _read_loop(self) -> None:
        try:
            while not self._closed.is_set():
                frame = wire.read_frame(self._sock)
                if frame is None:
                    break
                ftype, req_id, payload = frame
                if ftype in (wire.FrameType.OK, wire.FrameType.ERROR):
                    with self._lock:
                        waiter = self._rpcs.get(req_id)
                        pending = (self._pendings.pop(req_id, None)
                                   if ftype == wire.FrameType.ERROR else None)
                    if waiter is not None:
                        waiter.ftype, waiter.payload = ftype, payload
                        waiter.event.set()
                    # a submit rejected server-side also kills its pending
                    if pending is not None and waiter is None:
                        pending._resolve(error=error_from_wire(payload))
                elif ftype == wire.FrameType.RESULT:
                    with self._lock:
                        pending = self._pendings.pop(req_id, None)
                    if pending is not None:
                        self._deliver(pending, payload)
        except (OSError, wire.WireError) as e:
            self._conn_error = e
        finally:
            self._fail_all(self._conn_error
                           or ServiceError("connection closed"))

    def _deliver(self, pending: RemotePending, payload: Dict[str, Any]
                 ) -> None:
        if "error" in payload:
            pending._resolve(error=error_from_wire(payload["error"]),
                             queued_ms=payload.get("queued_ms"))
            return
        try:
            value = wire.unpack_object(payload["result"])
        except Exception as e:
            pending._resolve(error=e)
            return
        pending._resolve(value=value, cached=bool(payload.get("cached")),
                         fused=bool(payload.get("fused")),
                         queued_ms=payload.get("queued_ms"))

    def _fail_all(self, exc: BaseException) -> None:
        self._closed.set()
        with self._lock:
            rpcs, self._rpcs = dict(self._rpcs), {}
            pendings, self._pendings = dict(self._pendings), {}
        for waiter in rpcs.values():
            waiter.ftype = wire.FrameType.ERROR
            waiter.payload = {"etype": "ServiceError", "message": str(exc)}
            waiter.event.set()
        for p in pendings.values():
            if not p.done:
                p._resolve(error=exc)

    def _ensure_progress(self) -> None:
        """Mirror of ``GraphService._ensure_progress``: against a worker-less
        (inline) server, an un-flushed result would wait forever — nudge the
        server to drain.  Worker-backed servers stream on their own."""
        if self.server_workers == 0 and not self._closed.is_set():
            try:
                self._rpc("flush")
            except Exception:
                pass

    # -- GraphService mirror -------------------------------------------------
    def session(self, name: str) -> "RemoteSession":
        with self._lock:
            if name not in self._sessions:
                self._sessions[name] = RemoteSession(self, name)
            return self._sessions[name]

    def submit(self, session: "RemoteSession",
               request: Dict[str, Any]) -> RemotePending:
        req_id = self._next_id()
        # every remote submit rides under a trace id: an explicit one in the
        # request, the calling thread's active trace, or a fresh mint — the
        # id the server's spans and the result's provenance meta carry
        trace = (request.get("trace") or obs.current_trace()
                 or obs.new_trace_id())
        pending = RemotePending(self, dict(request), trace=trace)
        with self._lock:
            self._pendings[req_id] = pending
        waiter = _RpcWaiter()
        with self._lock:
            self._rpcs[req_id] = waiter
        try:
            self._send(req_id, wire.attach_trace(
                {"kind": "submit", "session": session.name,
                 "request": request}, trace))
            if not waiter.event.wait(self.rpc_timeout):
                raise TimeoutError("submit rpc timed out")
        except BaseException:
            with self._lock:           # don't leak the orphaned pending
                self._pendings.pop(req_id, None)
            raise
        finally:
            with self._lock:
                self._rpcs.pop(req_id, None)
        if waiter.ftype == wire.FrameType.ERROR:
            with self._lock:
                self._pendings.pop(req_id, None)
            raise error_from_wire(waiter.payload)
        return pending

    def execute(self, session: "RemoteSession",
                request: Dict[str, Any]) -> Any:
        p = self.submit(session, request)
        self.flush()
        return p.result(timeout=self.rpc_timeout)

    def flush(self) -> None:
        """Drain an inline (worker-less) server; no-op when the server runs
        scheduler workers — results stream on their own there, and an
        inline drain would occupy the server's reader thread with engine
        work, head-of-line blocking this connection's other RPCs."""
        if self.server_workers == 0:
            self._rpc("flush")

    @property
    def stats(self) -> Dict[str, Any]:
        return self._rpc("stats")["stats"]

    def session_stats(self, name: str) -> Dict[str, Any]:
        return self._rpc("session_stats", session=name)["stats"]

    # -- observability -------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        """Server-side metrics snapshot (``repro.obs`` registry dict)."""
        return self._rpc("obs_metrics")["metrics"]

    def metrics_text(self) -> str:
        """Server-side metrics in Prometheus text exposition format."""
        return self._rpc("obs_metrics", fmt="prom")["text"]

    def chrome_trace(self, trace: Optional[str] = None,
                     path: Optional[str] = None) -> Dict[str, Any]:
        """Server-side Chrome trace-event JSON (``chrome://tracing``).

        ``trace`` filters to one trace id — pass a ``RemotePending.trace``
        to see exactly that request's journey through admission, queueing,
        batching and the engine.  ``path`` writes the JSON to a local file.
        """
        doc = self._rpc("obs_trace", trace=trace)["trace_events"]
        if path is not None:
            import json
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc

    def health(self) -> Dict[str, Any]:
        """Server-side SLO verdict: ``{"status": "ok|degraded|breaching",
        "ops": {...}, "reasons": [...]}`` (see ``repro.obs.slo``)."""
        return self._rpc("health")["health"]

    def slo_report(self) -> Dict[str, Any]:
        """Server-side SLO window report: per-op rates, burn rate,
        windowed quantiles, configured objectives."""
        return self._rpc("slo_report")["report"]

    def debug_bundle(self, path: Optional[str] = None, *,
                     trace: Optional[str] = None) -> Dict[str, Any]:
        """Fetch the server's postmortem bundle (metrics, Chrome trace,
        flight-recorder exemplars, SLO state, profile report, log tail).

        ``trace`` narrows the embedded Chrome trace to one trace id;
        ``path`` writes the bundle JSON to a local file — the artifact
        ``python -m repro.obs.report --bundle <path>`` renders.
        """
        bundle = self._rpc("debug_bundle", trace=trace)["bundle"]
        if path is not None:
            import json
            with open(path, "w") as f:
                json.dump(bundle, f)
        return bundle

    def profile_report(self) -> str:
        """Text table of the server's ``engine.profile.*`` instruments,
        rendered locally from the shipped metrics snapshot."""
        from ..obs.profile import profile_report
        return profile_report(self.metrics())

    def shutdown_server(self) -> None:
        """Ask the server process to drain and exit (if it allows it).

        The ack inherently races the teardown it requests; losing the
        connection after the request was sent counts as success.  Genuine
        refusals (shutdown disabled) still raise.
        """
        try:
            self._rpc("shutdown")
        except ServiceError as e:
            if "connection closed" not in str(e):
                raise

    def close(self) -> None:
        self._closed.set()
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._reader.join(timeout=5.0)

    def __enter__(self) -> "RemoteService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


class RemoteWorkspace:
    """Mirror of :class:`~repro.serve.graph_service.Workspace` over RPC.

    ``put`` keeps a local mirror reference and binds the local object to the
    server-assigned version token — the client-side root registry that lets
    ``export_script`` embed roots of remotely computed results.
    """

    def __init__(self, service: RemoteService):
        self.service = service
        self._mirror: Dict[str, Any] = {}

    def put(self, name: str, obj: Any) -> str:
        from ..core import provenance as prov
        reply = self.service._rpc("ws_put", name=name,
                                  obj=wire.pack_object(obj))
        version = reply["version"]
        prov.bind_version(obj, version)
        self._mirror[name] = obj
        return version

    def get(self, name: str) -> Any:
        return wire.unpack_object(self.service._rpc("ws_get",
                                                    name=name)["obj"])

    def version(self, name: str) -> str:
        return self.service._rpc("ws_version", name=name)["version"]

    def names(self) -> List[str]:
        return list(self.service._rpc("ws_names")["names"])

    def update(self, name: str, fn: Any) -> str:
        raise ServiceError(
            "functional updates cannot cross the wire (callables have no "
            "wire form); run updates server-side, put() a fresh object, or "
            "apply_delta() for edge inserts/deletes")

    def apply_delta(self, name: str, delta: Any) -> str:
        """Apply an :class:`~repro.core.graph.EdgeDelta` to a workspace
        graph server-side; returns the new version token.

        The one functional update with a wire form: the delta ships as four
        plain arrays and the server runs ``Workspace.apply_delta``, so the
        published child keeps its delta lineage — plan patching, cache
        retention and warm-start recomputation behave exactly as for an
        in-process update.  The local mirror (if any) is refreshed too, so
        ``export_script`` root embedding keeps working after updates.
        """
        import numpy as np
        reply = self.service._rpc(
            "ws_apply_delta", name=name,
            add_src=np.asarray(delta.add_src, np.int32),
            add_dst=np.asarray(delta.add_dst, np.int32),
            del_src=np.asarray(delta.del_src, np.int32),
            del_dst=np.asarray(delta.del_dst, np.int32))
        version = reply["version"]
        if name in self._mirror:
            from ..core import provenance as prov
            new = self._mirror[name].apply_delta(delta)
            prov.bind_version(new, version)
            self._mirror[name] = new
        return version

    def __contains__(self, name: str) -> bool:
        return name in self.names()


class RemoteSession:
    """Mirror of :class:`~repro.serve.graph_service.Session` over RPC."""

    def __init__(self, service: RemoteService, name: str):
        self.service = service
        self.name = name
        self._mirror: Dict[str, Any] = {}

    def put(self, name: str, obj: Any) -> str:
        from ..core import provenance as prov
        reply = self.service._rpc("sess_put", session=self.name, name=name,
                                  obj=wire.pack_object(obj))
        version = reply["version"]
        prov.bind_version(obj, version)
        self._mirror[name] = obj
        return version

    def get(self, name: str) -> Any:
        return wire.unpack_object(
            self.service._rpc("sess_get", session=self.name,
                              name=name)["obj"])

    def publish(self, name: str) -> str:
        reply = self.service._rpc("publish", session=self.name, name=name)
        if name in self._mirror:
            self.service.workspace._mirror[name] = self._mirror.pop(name)
        return reply["version"]

    def local_names(self) -> List[str]:
        return list(self.service._rpc("local_names",
                                      session=self.name)["names"])

    def submit(self, request: Dict[str, Any]) -> RemotePending:
        return self.service.submit(self, request)

    def execute(self, request: Dict[str, Any]) -> Any:
        return self.service.execute(self, request)


def connect(host: str = "127.0.0.1", port: int = 0, *,
            timeout: float = 120.0) -> RemoteService:
    """``connect(host, port)`` — the one-call client entry point."""
    return RemoteService(host, port, timeout=timeout)
