"""Tables + relational ops vs Python oracles, incl. hypothesis properties."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.table import Table, Schema, INT, FLOAT, STR, next_capacity
from repro.core import relational as R


def make(ids, scores, tags):
    return Table.from_columns(
        {"id": INT, "score": FLOAT, "tag": STR},
        {"id": ids, "score": scores, "tag": tags})


T0 = make([3, 1, 2, 5, 4], [0.5, 0.1, 0.9, 0.3, 0.7],
          ["java", "py", "java", "c", "py"])


def test_schema_validation():
    with pytest.raises(ValueError):
        Schema.of([("a", INT), ("a", FLOAT)])
    with pytest.raises(ValueError):
        Schema.of([("a", "bogus")])


def test_capacity_bucketing():
    assert next_capacity(0) == 8
    assert next_capacity(8) == 8
    assert next_capacity(9) == 16
    assert next_capacity(1000) == 1024


def test_int_columns_are_int32_and_round_trip():
    """INT declares int32 explicitly (x64 is disabled; an int64 declaration
    would silently truncate) and full-range int32 values must round-trip."""
    from repro.core import table as table_mod
    assert table_mod._DTYPE_FOR[INT] == np.int32

    hi, lo = np.int32(2**31 - 1), np.int32(-(2**31))
    vals = [int(hi), int(lo), 0, -1, 123456789]
    t = Table.from_columns({"x": INT}, {"x": vals})
    assert t.column("x").dtype == np.int32
    assert t.column_np("x").tolist() == vals
    # survives a structural op (gather pads/copies through the same dtype)
    t2 = t.gathered(np.arange(len(vals), dtype=np.int32), len(vals))
    assert t2.column("x").dtype == np.int32
    assert t2.column_np("x").tolist() == vals
    # with_column_added takes the same canonical dtype
    t3 = t.with_column_added("y", INT, vals)
    assert t3.column("y").dtype == np.int32
    assert t3.column_np("y").tolist() == vals


def test_select_eq_string():
    s = R.select(T0, "tag", "==", "java")
    d = s.to_pydict()
    assert d["id"] == [3, 2] and d["tag"] == ["java", "java"]
    assert d["score"] == pytest.approx([0.5, 0.9])
    # select keeps the same capacity bucket (paper's "in place")
    assert s.capacity == T0.capacity


def test_select_cmp_numeric():
    s = R.select(T0, "score", ">=", 0.5)
    assert sorted(s.to_pydict()["id"]) == [2, 3, 4]
    s2 = R.select(T0, "id", "!=", 3)
    assert len(s2) == 4


def test_select_missing_string_matches_nothing():
    s = R.select(T0, "tag", "==", "rust")
    assert len(s) == 0


def test_order():
    o = R.order(T0, ["score"])
    assert o.to_pydict()["id"] == [1, 5, 3, 4, 2]
    o2 = R.order(T0, ["tag", "score"])
    assert o2.to_pydict()["tag"] == ["c", "java", "java", "py", "py"]


def test_project_and_rename():
    p = R.project(T0, ["tag", "id"])
    assert p.schema.names == ("tag", "id")
    r = p.renamed({"tag": "language"})
    assert r.schema.names == ("language", "id")
    assert r.strings("language")[0] == "java"


def test_join_counts_and_values():
    lt = Table.from_columns({"q": INT, "u": INT},
                            {"q": [1, 2, 3, 3], "u": [10, 20, 30, 40]})
    rt = Table.from_columns({"q": INT, "v": INT},
                            {"q": [3, 3, 1], "v": [7, 8, 9]})
    j = R.join(lt, rt, "q", "q")
    got = sorted(zip(j.to_pydict()["u"], j.to_pydict()["v"]))
    assert got == [(10, 9), (30, 7), (30, 8), (40, 7), (40, 8)]


def test_join_string_keys_different_dicts():
    lt = Table.from_columns({"k": STR, "x": INT},
                            {"k": ["a", "b", "c"], "x": [1, 2, 3]})
    rt = Table.from_columns({"k": STR, "y": INT},
                            {"k": ["c", "a", "z"], "y": [30, 10, 99]})
    j = R.join(lt, rt, "k", "k")
    got = sorted(zip(j.to_pydict()["x"], j.to_pydict()["y"]))
    assert got == [(1, 10), (3, 30)]


def test_group_by():
    g = R.group_by(T0, "tag", {"total": ("score", "sum"),
                               "n": ("id", "count"),
                               "hi": ("score", "max")})
    d = g.to_pydict()
    by = dict(zip(d["tag"], zip(d["total"], d["n"], d["hi"])))
    assert by["java"][1] == 2 and abs(by["java"][0] - 1.4) < 1e-5
    assert by["c"] == (pytest.approx(0.3), 1, pytest.approx(0.3))


def test_set_ops():
    lt = Table.from_columns({"k": INT}, {"k": [1, 2, 3, 4]})
    rt = Table.from_columns({"k": INT}, {"k": [3, 4, 5]})
    assert sorted(R.intersect(lt, rt, "k").to_pydict()["k"]) == [3, 4]
    assert sorted(R.difference(lt, rt, "k").to_pydict()["k"]) == [1, 2]
    u = R.union(lt, rt)
    assert sorted(u.to_pydict()["k"]) == [1, 2, 3, 3, 4, 4, 5]


def test_union_string_dictionary_merge():
    lt = Table.from_columns({"k": STR}, {"k": ["a", "b"]})
    rt = Table.from_columns({"k": STR}, {"k": ["b", "z"]})
    u = R.union(lt, rt)
    assert u.strings("k") == ["a", "b", "b", "z"]


def test_sim_join_band():
    lt = Table.from_columns({"x": FLOAT}, {"x": [0.0, 10.0]})
    rt = Table.from_columns({"y": FLOAT}, {"y": [1.0, 2.5, 9.0, 50.0]})
    sj = R.sim_join(lt, rt, "x", "y", threshold=2.0)
    got = sorted(zip(sj.to_pydict()["x"], sj.to_pydict()["y"]))
    assert got == [(0.0, 1.0), (10.0, 9.0)]


def test_next_k_successors():
    ev = Table.from_columns({"user": INT, "ts": INT},
                            {"user": [1, 1, 1, 2, 2], "ts": [5, 1, 3, 2, 9]})
    nk = R.next_k(ev, "user", "ts", k=1)
    got = sorted(zip(nk.to_pydict()["ts_1"], nk.to_pydict()["ts_2"]))
    assert got == [(1, 3), (2, 9), (3, 5)]


def test_row_id_tracking_through_select():
    s = R.select(T0, "tag", "==", "py")
    # persistent row ids: original rows 1 and 4
    assert sorted(np.asarray(s.row_ids[:len(s)]).tolist()) == [1, 4]


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

ints = st.lists(st.integers(-50, 50), min_size=0, max_size=40)


@settings(max_examples=25, deadline=None)
@given(ints, st.integers(-50, 50))
def test_prop_select_matches_python(xs, pivot):
    t = Table.from_columns({"x": INT}, {"x": xs})
    s = R.select(t, "x", "<", pivot)
    assert sorted(s.to_pydict()["x"]) == sorted([v for v in xs if v < pivot])


@settings(max_examples=25, deadline=None)
@given(ints, ints)
def test_prop_join_cardinality(lxs, rxs):
    lt = Table.from_columns({"k": INT}, {"k": lxs})
    rt = Table.from_columns({"k": INT}, {"k": rxs})
    j = R.join(lt, rt, "k", "k")
    from collections import Counter
    cl, cr = Counter(lxs), Counter(rxs)
    assert len(j) == sum(cl[k] * cr[k] for k in cl)


@settings(max_examples=25, deadline=None)
@given(ints)
def test_prop_order_is_sorted_permutation(xs):
    t = Table.from_columns({"x": INT}, {"x": xs})
    o = R.order(t, ["x"])
    assert o.to_pydict()["x"] == sorted(xs)


@settings(max_examples=25, deadline=None)
@given(ints)
def test_prop_group_count_sums_to_n(xs):
    t = Table.from_columns({"x": INT}, {"x": xs})
    g = R.group_by(t, "x", {"n": ("x", "count")})
    assert sum(g.to_pydict()["n"]) == len(xs)
    assert g.to_pydict()["x"] == sorted(set(xs))
