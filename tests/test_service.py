"""Interactive workspace service (serve/graph_service.py).

Covers the Ringo §2.1 serving contract: shared versioned workspace, session
isolation, declarative execution, the fusion scheduler (concurrent
single-source traversals -> one vmapped engine call), and the versioned
result cache (hits until a functional update bumps the version).
"""

import threading
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import algorithms as A
from repro.core import provenance as P
from repro.core.graph import EdgeDelta, Graph
from repro.core.table import INT, STR, Table
from repro.data.rmat import rmat_edges
from repro.serve.graph_service import (DeadlineExpired, GraphService,
                                       RejectedError, ServiceError, Workspace)
from repro.serve.policy import (AdmissionPolicy, BatchPolicy, FairSharePolicy,
                                SchedulerPolicy)


def rmat_graph(scale=7, edge_factor=4, seed=0):
    s, d = rmat_edges(scale, edge_factor=edge_factor, seed=seed)
    return Graph.from_edges(s, d)


def make_service(**kw):
    svc = GraphService(**kw)
    svc.workspace.put("g", rmat_graph())
    return svc


# ---------------------------------------------------------------------------
# workspace + sessions
# ---------------------------------------------------------------------------


def test_workspace_put_get_version():
    ws = Workspace()
    g = rmat_graph()
    v = ws.put("g", g)
    assert ws.get("g") is g
    assert ws.version("g") == v == g.version
    with pytest.raises(KeyError):
        ws.get("nope")


def test_workspace_update_is_functional_and_bumps_version():
    ws = Workspace()
    ws.put("g", Graph.from_edges([0, 1], [1, 2]))
    v0 = ws.version("g")
    v1 = ws.update("g", lambda g: g.add_edges([2], [0]))
    assert v1 != v0
    assert ws.get("g").n_edges == 3


def test_session_isolation():
    svc = make_service()
    s1, s2 = svc.session("alice"), svc.session("bob")
    s1.put("mine", Table.from_columns({"x": INT}, {"x": [1, 2]}))
    assert "mine" in s1.local_names()
    with pytest.raises(KeyError):
        s2.get("mine")                    # local writes don't leak
    # "as" bindings are session-local too
    s1.execute({"op": "pagerank", "graph": "g", "params": {"n_iter": 2},
                "as": "pr"})
    with pytest.raises(KeyError):
        s2.get("pr")
    # publish promotes to the shared workspace
    s1.publish("mine")
    assert s2.get("mine") is svc.workspace.get("mine")


def test_sessions_fall_through_to_workspace():
    svc = make_service()
    s = svc.session("alice")
    assert s.get("g") is svc.workspace.get("g")


# ---------------------------------------------------------------------------
# declarative execution
# ---------------------------------------------------------------------------


def test_execute_algorithm_and_table_pipeline():
    svc = GraphService()
    t = Table.from_columns(
        {"u": INT, "v": INT, "tag": STR},
        {"u": [0, 1, 2, 3], "v": [1, 2, 0, 0], "tag": ["a", "a", "a", "b"]})
    svc.workspace.put("edges", t)
    s = svc.session("alice")
    s.execute({"op": "select", "table": "edges",
               "params": {"col": "tag", "op": "==", "value": "a"},
               "as": "sel"})
    s.execute({"op": "to_graph", "table": "sel",
               "params": {"src_col": "u", "dst_col": "v"}, "as": "g"})
    pr = s.execute({"op": "pagerank", "graph": "g",
                    "params": {"n_iter": 5}, "as": "pr"})
    want = A.pagerank(s.get("g"), n_iter=5)
    np.testing.assert_array_equal(np.asarray(pr), np.asarray(want))
    # the result's provenance chain reaches back to the root table
    recs = P.records_of(s.get("pr"))
    assert [r.op for r in recs] == ["relational.select", "convert.to_graph",
                                    "algorithms.pagerank"]


def test_unknown_op_rejected_and_missing_slot_reported():
    svc = make_service()
    s = svc.session("alice")
    with pytest.raises(ServiceError):
        s.submit({"op": "frobnicate"})
    p = s.submit({"op": "pagerank"})      # missing "graph" slot
    svc.flush()
    with pytest.raises(ServiceError):
        p.result()


# ---------------------------------------------------------------------------
# fusion scheduler
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["sssp", "bfs", "personalized_pagerank"])
def test_fused_multi_source_parity_vs_sequential(op):
    svc = make_service()
    g = svc.workspace.get("g")
    sources = [0, 3, 7, 11]
    pending = [svc.session(f"u{i}").submit(
        {"op": op, "graph": "g", "params": {"source": s}})
        for i, s in enumerate(sources)]
    svc.flush()
    assert svc.stats["fused_calls"] == 1
    assert svc.stats["fused_requests"] == len(sources)
    assert svc.stats["engine_calls"] == 1
    fn = getattr(A, op)
    for p, s in zip(pending, sources):
        got = np.asarray(p.result())
        assert p.fused
        np.testing.assert_array_equal(got, np.asarray(fn(g, s)))


def test_fused_rows_carry_single_source_provenance():
    svc = make_service()
    pending = [svc.session(f"u{i}").submit(
        {"op": "sssp", "graph": "g", "params": {"source": s}})
        for i, s in enumerate([2, 5])]
    svc.flush()
    for p, s in zip(pending, [2, 5]):
        rec = P.records_of(p.result())[-1]
        assert rec.op == "algorithms.sssp"
        assert dict(rec.params)["source"] == s


def test_mixed_params_do_not_fuse_together():
    svc = make_service()
    a = svc.session("a").submit({"op": "sssp", "graph": "g",
                                 "params": {"source": 0}})
    b = svc.session("b").submit({"op": "personalized_pagerank", "graph": "g",
                                 "params": {"source": 0, "n_iter": 3}})
    svc.flush()
    assert svc.stats["fused_calls"] == 0    # different ops: nothing coalesced
    assert a.result().shape == b.result().shape


def test_fusion_disabled_runs_individually():
    svc = make_service(fuse=False)
    pending = [svc.session(f"u{i}").submit(
        {"op": "sssp", "graph": "g", "params": {"source": s}})
        for i, s in enumerate([0, 3])]
    svc.flush()
    assert svc.stats["fused_calls"] == 0
    assert svc.stats["engine_calls"] == 2
    g = svc.workspace.get("g")
    for p, s in zip(pending, [0, 3]):
        np.testing.assert_array_equal(np.asarray(p.result()),
                                      np.asarray(A.sssp(g, s)))


# ---------------------------------------------------------------------------
# cross-n_iter fusion: mixed depth limits coalesce into one engine call
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["bfs", "sssp"])
def test_mixed_depth_requests_fuse_into_one_call(op):
    svc = make_service()
    g = svc.workspace.get("g")
    cases = [(0, None), (3, 2), (7, 5), (11, None)]
    pending = [svc.session(f"u{i}").submit(
        {"op": op, "graph": "g",
         "params": {"source": s} if d is None
         else {"source": s, "n_iter": d}})
        for i, (s, d) in enumerate(cases)]
    svc.flush()
    assert svc.stats["fused_calls"] == 1
    assert svc.stats["fused_requests"] == len(cases)
    assert svc.stats["engine_calls"] == 1
    fn = getattr(A, op)
    for p, (s, d) in zip(pending, cases):
        want = fn(g, s) if d is None else fn(g, s, n_iter=d)
        assert p.fused
        np.testing.assert_array_equal(np.asarray(p.result()),
                                      np.asarray(want), err_msg=f"{s}/{d}")


def test_mixed_depth_ppr_fuses_with_default_n_iter():
    svc = make_service()
    g = svc.workspace.get("g")
    pending = [svc.session(f"u{i}").submit(
        {"op": "personalized_pagerank", "graph": "g", "params": pr})
        for i, pr in enumerate([{"source": 1}, {"source": 2, "n_iter": 3}])]
    svc.flush()
    assert svc.stats["fused_calls"] == 1
    np.testing.assert_array_equal(
        np.asarray(pending[0].result()),
        np.asarray(A.personalized_pagerank(g, 1)))
    np.testing.assert_array_equal(
        np.asarray(pending[1].result()),
        np.asarray(A.personalized_pagerank(g, 2, n_iter=3)))


def test_mixed_depth_rows_carry_per_request_provenance():
    svc = make_service()
    pending = [svc.session(f"u{i}").submit(
        {"op": "bfs", "graph": "g", "params": pr})
        for i, pr in enumerate([{"source": 2, "n_iter": 4}, {"source": 5}])]
    svc.flush()
    rec0 = P.records_of(pending[0].result())[-1]
    assert dict(rec0.params) == {"source": 2, "n_iter": 4}
    rec1 = P.records_of(pending[1].result())[-1]
    assert dict(rec1.params) == {"source": 5}   # no depth limit recorded


def test_result_cache_keys_on_per_request_n_iter():
    svc = make_service()
    s = svc.session("a")
    r2 = s.execute({"op": "bfs", "graph": "g",
                    "params": {"source": 3, "n_iter": 2}})
    assert svc.stats["cache_hits"] == 0
    # same source, same depth: a hit, no engine call
    calls = svc.stats["engine_calls"]
    r2b = s.execute({"op": "bfs", "graph": "g",
                     "params": {"source": 3, "n_iter": 2}})
    assert svc.stats["cache_hits"] == 1
    assert svc.stats["engine_calls"] == calls
    assert r2b is r2
    # same source, different depth: its own key, fresh execution
    r4 = s.execute({"op": "bfs", "graph": "g",
                    "params": {"source": 3, "n_iter": 4}})
    assert svc.stats["engine_calls"] == calls + 1
    assert r4 is not r2
    assert int(np.asarray(r4).max()) >= int(np.asarray(r2).max())


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------


def test_cache_hit_on_repeat_and_across_sessions():
    svc = make_service()
    req = {"op": "pagerank", "graph": "g", "params": {"n_iter": 4}}
    r1 = svc.session("a").execute(req)
    r2 = svc.session("b").execute(dict(req))
    assert r1 is r2                       # same object: served from cache
    assert svc.stats["cache_hits"] == 1
    assert svc.stats["engine_calls"] == 1


def test_cache_invalidates_on_functional_update():
    svc = GraphService()
    svc.workspace.put("g", Graph.from_edges([0, 1], [1, 2]))
    req = {"op": "pagerank", "graph": "g", "params": {"n_iter": 4}}
    s = svc.session("a")
    r1 = s.execute(req)
    svc.workspace.update("g", lambda g: g.add_edges([2], [0]))
    r2 = s.execute(dict(req))
    assert svc.stats["cache_hits"] == 0   # version bumped: the key changed
    assert r2 is not r1
    want = A.pagerank(svc.workspace.get("g"), n_iter=4)
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(want))


def test_cached_fused_row_hits_without_engine_call():
    svc = make_service()
    req = {"op": "sssp", "graph": "g", "params": {"source": 5}}
    svc.session("a").execute(req)
    calls = svc.stats["engine_calls"]
    out = svc.session("b").execute(dict(req))
    assert svc.stats["engine_calls"] == calls
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(A.sssp(svc.workspace.get("g"), 5)))


def test_cache_disabled_always_recomputes():
    svc = make_service(cache=False)
    req = {"op": "pagerank", "graph": "g", "params": {"n_iter": 2}}
    svc.session("a").execute(req)
    svc.session("a").execute(dict(req))
    assert svc.stats["cache_hits"] == 0
    assert svc.stats["engine_calls"] == 2


# ---------------------------------------------------------------------------
# delta-aware cache retention + warm-start recomputation
# ---------------------------------------------------------------------------


def _path_service():
    """0 -> 1 -> 2 -> 3: small enough to reason about retention by hand."""
    svc = GraphService()
    svc.workspace.put("p", Graph.from_edges([0, 1, 2], [1, 2, 3]))
    return svc


def test_retention_rebinds_unaffected_entries_across_delta():
    """A cached BFS stays served from cache after an insert that provably
    cannot shorten any distance (back edge 2->1: D[2]+1 >= D[1])."""
    svc = _path_service()
    s = svc.session("a")
    req = {"op": "bfs", "graph": "p", "params": {"source": 0}}
    r1 = s.execute(req)
    calls = svc.stats["engine_calls"]
    svc.workspace.apply_delta("p", EdgeDelta.inserts([2], [1]))
    r2 = s.execute(dict(req))
    assert svc.stats["engine_calls"] == calls      # no recompute at all
    assert svc.stats["retained"] >= 1
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(r1))
    np.testing.assert_array_equal(                 # and it is still correct
        np.asarray(r2), np.asarray(A.bfs(svc.workspace.get("p"), 0)))


def test_affected_query_warm_starts_and_stays_exact():
    """An insert that shortens a path (0->3) defeats retention; the engine
    warm-starts from the parent levels and matches the cold answer."""
    svc = _path_service()
    s = svc.session("a")
    req = {"op": "bfs", "graph": "p", "params": {"source": 0}}
    s.execute(req)
    svc.workspace.apply_delta("p", EdgeDelta.inserts([0], [3]))
    r2 = s.execute(dict(req))
    assert np.asarray(r2)[3] == 1                  # shortcut is visible
    assert svc.stats["retained"] == 0
    assert svc.stats["warm_starts"] >= 1
    np.testing.assert_array_equal(
        np.asarray(r2), np.asarray(A.bfs(svc.workspace.get("p"), 0)))
    # warm-started results carry cold-equivalent provenance, flagged
    rec = P.records_of(r2)[-1]
    assert rec.op == "algorithms.bfs"
    assert dict(rec.meta).get("incremental") is True


def test_deletions_fall_back_to_cold_recompute():
    svc = _path_service()
    s = svc.session("a")
    req = {"op": "bfs", "graph": "p", "params": {"source": 0}}
    s.execute(req)
    svc.workspace.apply_delta(
        "p", EdgeDelta(add_src=[2], add_dst=[0], del_src=[0], del_dst=[1]))
    r2 = s.execute(dict(req))
    assert svc.stats["retained"] == 0              # deletion: never retained
    assert svc.stats["incremental_fallbacks"] >= 1
    np.testing.assert_array_equal(
        np.asarray(r2), np.asarray(A.bfs(svc.workspace.get("p"), 0)))


def test_warm_pagerank_under_tol_matches_cold():
    svc = make_service()
    s = svc.session("a")
    req = {"op": "pagerank", "graph": "g", "params": {"tol": 1e-6}}
    s.execute(req)
    ids = np.asarray(svc.workspace.get("g").node_ids)[:8]
    svc.workspace.apply_delta("g", EdgeDelta.inserts(ids[:4], ids[4:8]))
    r2 = s.execute(dict(req))
    assert svc.stats["warm_starts"] >= 1
    np.testing.assert_allclose(
        np.asarray(r2),
        np.asarray(A.pagerank(svc.workspace.get("g"), tol=1e-6)), atol=1e-5)


def test_incremental_disabled_never_retains_or_warms():
    svc = GraphService(incremental=False)
    svc.workspace.put("p", Graph.from_edges([0, 1, 2], [1, 2, 3]))
    s = svc.session("a")
    req = {"op": "bfs", "graph": "p", "params": {"source": 0}}
    s.execute(req)
    calls = svc.stats["engine_calls"]
    svc.workspace.apply_delta("p", EdgeDelta.inserts([2], [1]))
    s.execute(dict(req))
    assert svc.stats["retained"] == 0
    assert svc.stats["warm_starts"] == 0
    assert svc.stats["engine_calls"] == calls + 1  # plain cold recompute


def test_session_stats_carry_cache_counters():
    svc = _path_service()
    a, b = svc.session("a"), svc.session("b")
    req = {"op": "connected_components", "graph": "p", "params": {}}
    a.execute(req)
    a.execute(dict(req))
    b.execute(dict(req))                           # b: pure cache hit
    st = svc.session_stats("a")
    assert st["cache_misses"] >= 1 and st["cache_hits"] >= 1
    assert st["retained"] == 0
    assert "completed" in st                       # scheduler fields coexist
    svc.workspace.apply_delta("p", EdgeDelta.inserts([2], [1]))
    a.execute(dict(req))                           # labels equal: retained
    assert svc.session_stats("a")["retained"] == 1
    assert svc.session_stats("b")["retained"] == 0  # counters are per-session


# ---------------------------------------------------------------------------
# concurrency smoke: many threads, one batching window
# ---------------------------------------------------------------------------


def test_threaded_submissions_are_safe():
    svc = make_service()
    g = svc.workspace.get("g")
    results = {}

    def worker(i):
        s = svc.session(f"u{i}")
        p = s.submit({"op": "bfs", "graph": "g", "params": {"source": i}})
        results[i] = p

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.flush()
    for i, p in results.items():
        np.testing.assert_array_equal(np.asarray(p.result()),
                                      np.asarray(A.bfs(g, i)))


# ---------------------------------------------------------------------------
# admission control: quotas, backpressure, deadlines
# ---------------------------------------------------------------------------


def test_inflight_quota_rejects_with_retry_after():
    svc = make_service(policy=SchedulerPolicy(
        admission=AdmissionPolicy(max_inflight=2)))
    s = svc.session("alice")
    req = {"op": "pagerank", "graph": "g", "params": {"n_iter": 2}}
    a = s.submit(dict(req))
    b = s.submit({**req, "params": {"n_iter": 3}})
    with pytest.raises(RejectedError) as ei:
        s.submit({**req, "params": {"n_iter": 4}})
    assert ei.value.retry_after > 0
    assert svc.stats["rejected"] == 1
    assert svc.session_stats("alice")["rejected"] == 1
    # draining frees the quota; the session may submit again
    svc.flush()
    a.result(), b.result()
    c = s.submit({**req, "params": {"n_iter": 4}})
    svc.flush()
    assert np.asarray(c.result()).shape == (svc.workspace.get("g").n_nodes,)


def test_quota_is_per_session_not_global():
    svc = make_service(policy=SchedulerPolicy(
        admission=AdmissionPolicy(max_inflight=1)))
    svc.session("a").submit({"op": "pagerank", "graph": "g",
                             "params": {"n_iter": 2}})
    # a different session has its own quota
    svc.session("b").submit({"op": "pagerank", "graph": "g",
                             "params": {"n_iter": 2}})
    with pytest.raises(RejectedError):
        svc.session("a").submit({"op": "pagerank", "graph": "g",
                                 "params": {"n_iter": 3}})
    svc.flush()


def test_queue_depth_backpressure_rejects_any_session():
    svc = make_service(policy=SchedulerPolicy(
        admission=AdmissionPolicy(max_inflight=64, max_queue_depth=2)))
    svc.session("a").submit({"op": "pagerank", "graph": "g",
                             "params": {"n_iter": 2}})
    svc.session("b").submit({"op": "pagerank", "graph": "g",
                             "params": {"n_iter": 3}})
    with pytest.raises(RejectedError) as ei:
        svc.session("c").submit({"op": "pagerank", "graph": "g",
                                 "params": {"n_iter": 4}})
    assert ei.value.retry_after > 0
    svc.flush()


def test_expired_deadline_never_reaches_the_engine():
    svc = make_service()
    s = svc.session("alice")
    p = s.submit({"op": "pagerank", "graph": "g", "params": {"n_iter": 5},
                  "deadline_ms": 0})
    svc.flush()
    assert svc.stats["engine_calls"] == 0       # dropped before execution
    assert svc.stats["expired"] == 1
    assert svc.session_stats("alice")["expired"] == 1
    assert svc.session_stats("alice")["completed"] == 0   # not double-counted
    with pytest.raises(DeadlineExpired):
        p.result()
    # a generous deadline executes normally
    out = s.execute({"op": "pagerank", "graph": "g", "params": {"n_iter": 5},
                     "deadline_ms": 60_000})
    assert svc.stats["engine_calls"] == 1
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(A.pagerank(svc.workspace.get("g"),
                                               n_iter=5)))


def test_expired_member_is_dropped_from_coalesced_batch():
    svc = make_service()
    live = svc.session("a").submit({"op": "bfs", "graph": "g",
                                    "params": {"source": 1}})
    stale = svc.session("b").submit({"op": "bfs", "graph": "g",
                                     "params": {"source": 2},
                                     "deadline_ms": 0})
    svc.flush()
    assert svc.stats["expired"] == 1
    with pytest.raises(DeadlineExpired):
        stale.result()
    np.testing.assert_array_equal(
        np.asarray(live.result()),
        np.asarray(A.bfs(svc.workspace.get("g"), 1)))


# ---------------------------------------------------------------------------
# fair share: a flooding session cannot starve interactive ones
# ---------------------------------------------------------------------------


def _submit_overload(svc, n_flood=8, n_interactive=3):
    """One flooding session (non-fusable pageranks, submitted FIRST) and one
    interactive session (single-source bfs that coalesce into one call)."""
    flood = svc.session("flood")
    inter = svc.session("inter")
    flood_pending = [flood.submit({"op": "pagerank", "graph": "g",
                                   "params": {"n_iter": 2 + i}})
                     for i in range(n_flood)]
    inter_pending = [inter.submit({"op": "bfs", "graph": "g",
                                   "params": {"source": i}})
                     for i in range(n_interactive)]
    return flood_pending, inter_pending


def test_fair_share_serves_interactive_ahead_of_flood_backlog():
    svc = make_service(cache=False, policy=SchedulerPolicy(mode="fair"))
    flood_pending, inter_pending = _submit_overload(svc)
    # two scheduling decisions: one flood request, then the whole
    # interactive batch — the flood's 7-deep backlog is still queued
    svc.scheduler.step()
    svc.scheduler.step()
    assert all(p.done for p in inter_pending)
    assert sum(p.done for p in flood_pending) <= 2
    assert svc.scheduler.queued_count("flood") >= 6
    svc.flush()
    # everyone still completes (work-conserving), and the flood session was
    # charged the engine time it consumed
    assert all(p.done for p in flood_pending)
    # both sessions were charged the engine time they consumed (at this toy
    # scale jit compiles dominate, so only the accounting is asserted)
    assert svc.session_stats("flood")["engine_ms"] > 0
    assert svc.session_stats("inter")["engine_ms"] > 0


def test_fifo_mode_makes_interactive_wait_behind_flood():
    svc = make_service(cache=False, policy=SchedulerPolicy(mode="fifo"))
    flood_pending, inter_pending = _submit_overload(svc)
    svc.scheduler.step()
    svc.scheduler.step()
    # strict arrival order: the flood's backlog runs first
    assert not any(p.done for p in inter_pending)
    assert sum(p.done for p in flood_pending) == 2
    svc.flush()
    assert all(p.done for p in inter_pending)


def test_fair_share_completion_share_tracks_weights():
    """With the flood queued deep, interactive completions never fall below
    the share its weight entitles it to (here: it finishes first)."""
    svc = make_service(cache=False, policy=SchedulerPolicy(
        mode="fair", fair=FairSharePolicy(weights={"inter": 2.0})))
    flood_pending, inter_pending = _submit_overload(svc, n_flood=10,
                                                    n_interactive=4)
    done_after = []
    for _ in range(4):
        svc.scheduler.step()
        done_after.append(sum(p.done for p in inter_pending))
    # all interactive requests completed within the first few decisions
    assert done_after[-1] == len(inter_pending)
    svc.flush()


# ---------------------------------------------------------------------------
# negative-weight SSSP: never coalesced (|V|-round bound assumes w >= 0)
# ---------------------------------------------------------------------------


def _weighted_path_service(weights):
    svc = GraphService()
    svc.workspace.put("g", Graph.from_edges([0, 1, 2], [1, 2, 3]))
    return svc, jnp.asarray(weights, jnp.float32)


def test_negative_weight_sssp_requests_split_out_of_fusion():
    svc, w = _weighted_path_service([1.0, -1.0, 2.0])
    g = svc.workspace.get("g")
    pending = [svc.session(f"u{i}").submit(
        {"op": "sssp", "graph": "g",
         "params": {"source": s, "weights": w}})
        for i, s in enumerate([0, 1])]
    svc.flush()
    assert svc.stats["fused_calls"] == 0        # split: one call per request
    assert svc.stats["engine_calls"] == 2
    for p, s in zip(pending, [0, 1]):
        assert not p.fused
        np.testing.assert_allclose(np.asarray(p.result()),
                                   np.asarray(A.sssp(g, s, weights=w)))


def test_non_negative_weight_sssp_requests_still_fuse():
    svc, w = _weighted_path_service([1.0, 0.5, 2.0])
    g = svc.workspace.get("g")
    pending = [svc.session(f"u{i}").submit(
        {"op": "sssp", "graph": "g",
         "params": {"source": s, "weights": w}})
        for i, s in enumerate([0, 1])]
    svc.flush()
    assert svc.stats["fused_calls"] == 1        # the regression guard's dual
    assert svc.stats["engine_calls"] == 1
    for p, s in zip(pending, [0, 1]):
        np.testing.assert_allclose(np.asarray(p.result()),
                                   np.asarray(A.sssp(g, s, weights=w)))


# ---------------------------------------------------------------------------
# batching windows + worker mode + scheduling metadata
# ---------------------------------------------------------------------------


def test_effective_window_is_zero_when_idle_and_scales_with_load():
    bp = BatchPolicy(window_ms=10.0, load_full_at=4)
    assert bp.effective_window_s(0) == 0.0      # idle: no added latency
    assert 0 < bp.effective_window_s(1) < bp.effective_window_s(4)
    assert bp.effective_window_s(4) == pytest.approx(0.010)
    assert bp.effective_window_s(400) == pytest.approx(0.010)  # capped


def test_batch_window_coalesces_late_arrival_under_load():
    svc = make_service(policy=SchedulerPolicy(
        batch=BatchPolicy(window_ms=400.0, load_full_at=1)))
    early = svc.session("a").submit({"op": "bfs", "graph": "g",
                                     "params": {"source": 0}})
    # unrelated queued work puts the scheduler "under load", opening the
    # window when the bfs is dispatched
    other = svc.session("b").submit({"op": "pagerank", "graph": "g",
                                     "params": {"n_iter": 2}})
    t = threading.Thread(
        target=lambda: svc.scheduler.step(allow_wait=True), daemon=True)
    t.start()
    time.sleep(0.08)                           # well inside the 0.4s window
    late = svc.session("c").submit({"op": "bfs", "graph": "g",
                                    "params": {"source": 3}})
    t.join(timeout=10)
    svc.flush()
    assert svc.stats["batch_windows"] >= 1
    assert early.fused and late.fused          # the window caught the burst
    assert svc.stats["fused_requests"] >= 2
    other.result()


def test_worker_mode_executes_without_flush():
    svc = make_service(workers=1)
    try:
        g = svc.workspace.get("g")
        pending = [svc.session(f"u{i}").submit(
            {"op": "bfs", "graph": "g", "params": {"source": i}})
            for i in range(3)]
        for i, p in enumerate(pending):        # no flush() anywhere
            np.testing.assert_array_equal(np.asarray(p.result(timeout=120)),
                                          np.asarray(A.bfs(g, i)))
    finally:
        svc.close()


def test_results_carry_queueing_and_coalescing_metadata():
    svc = make_service()
    pending = [svc.session(f"u{i}").submit(
        {"op": "sssp", "graph": "g", "params": {"source": s}})
        for i, s in enumerate([0, 5])]
    svc.flush()
    for p in pending:
        meta = dict(P.records_of(p.result())[-1].meta)
        assert meta["batch"] == 2
        assert meta["sched_mode"] == "fair"
        assert meta["queued_ms"] >= 0
    # non-fused path is annotated too
    out = svc.session("solo").execute({"op": "pagerank", "graph": "g",
                                       "params": {"n_iter": 3}})
    meta = dict(P.records_of(out)[-1].meta)
    assert meta["batch"] == 1
    # ...and the metadata never leaks into replay (same program as an
    # un-scheduled run)
    recs = P.records_of(out)
    replayed = P.replay(recs[-1:], {recs[-1].inputs[0][1]:
                                    svc.workspace.get("g")})
    np.testing.assert_array_equal(np.asarray(replayed), np.asarray(out))


# ---------------------------------------------------------------------------
# service -> provenance export (the full §4 loop)
# ---------------------------------------------------------------------------


def test_service_results_export_and_rebuild():
    svc = GraphService()
    t = Table.from_columns({"u": INT, "v": INT},
                           {"u": [0, 1, 2, 3, 0], "v": [1, 2, 3, 0, 2]})
    svc.workspace.put("edges", t)
    s = svc.session("alice")
    s.execute({"op": "to_graph", "table": "edges",
               "params": {"src_col": "u", "dst_col": "v"}, "as": "g"})
    s.execute({"op": "pagerank", "graph": "g", "params": {"n_iter": 6},
               "as": "pr"})
    tbl = s.execute({"op": "table_from_map", "graph": "g", "scores": "pr",
                     "params": {"key_name": "node", "value_name": "score"},
                     "as": "ranked"})
    script = P.export_script(tbl)
    ns = {}
    exec(compile(script, "<service-export>", "exec"), ns)
    rebuilt = ns["rebuild"]()
    np.testing.assert_array_equal(rebuilt.column_np("score"),
                                  tbl.column_np("score"))


# ---------------------------------------------------------------------------
# workspace thread-safety under concurrent connections (serving hardening)
# ---------------------------------------------------------------------------


def test_workspace_concurrent_updates_are_never_lost():
    """Two writers doing functional updates must both land (CAS retry).

    Regression for the read-modify-write race the socket server exposes:
    with last-writer-wins semantics, two connections updating one name
    concurrently silently dropped one side's edges.
    """
    ws = Workspace()
    ws.put("g", Graph.from_edges([0], [1]))
    n_threads, n_updates = 4, 6
    errs = []

    def bump(tid):
        try:
            for i in range(n_updates):
                # every thread adds a unique edge; dedupe can't collapse them
                ws.update("g", lambda g, t=tid, k=i:
                          g.add_edges([1000 + t], [2000 + t * 100 + k]))
        except Exception as e:                      # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=bump, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    assert not errs
    final = ws.get("g")
    assert final.n_edges == 1 + n_threads * n_updates
    # name->version map stays consistent with the object it names
    assert ws.version("g") == P.version_of(final)


def test_workspace_update_restarts_against_fresh_object():
    """A CAS loser re-runs fn against the winner's object, not the stale
    snapshot it originally read."""
    ws = Workspace()
    ws.put("t", Table.from_columns({"x": INT}, {"x": [1]}))
    seen = []
    started = threading.Event()
    proceed = threading.Event()

    def slow_fn(t):
        seen.append(t.n_valid)
        started.set()
        proceed.wait(30)                 # hold the update open...
        return t.with_column_added("y", INT, np.zeros(t.n_valid, np.int32)) \
            if "y" not in t.schema else t

    slow = threading.Thread(target=lambda: ws.update("t", slow_fn))
    slow.start()
    started.wait(30)
    # ...while a fast update wins the race
    ws.update("t", lambda t: Table.from_columns({"x": INT}, {"x": [1, 2]}))
    proceed.set()
    slow.join(60)
    assert seen[0] == 1 and seen[-1] == 2    # fn re-ran on the fresh table
    assert ws.get("t").n_valid == 2


def test_close_resolves_outstanding_requests():
    """close() must drain what the dying workers left queued — a caller
    blocked in result() against a worker-backed service would otherwise
    wait forever (workers alive => no inline drain in _ensure_progress)."""
    svc = make_service(workers=1)
    s = svc.session("a")
    ps = [s.submit({"op": "pagerank", "graph": "g",
                    "params": {"n_iter": n}}) for n in (2, 3, 4, 5)]
    svc.close()
    for p in ps:
        assert p.result(timeout=30) is not None


# ---------------------------------------------------------------------------
# stats consistency under concurrent workers
# ---------------------------------------------------------------------------


def test_stats_counters_exact_under_two_workers():
    """Hammer a worker-backed service from two threads: ``stats`` counters
    are mutated from submitters, scheduler workers and drain callers — the
    dedicated stats lock must make every increment land (no lost updates),
    and the obs mirror must agree."""
    from repro import obs

    svc = make_service(workers=2)
    n_per_thread = 200
    obs_before = obs.counter("service.requests").value
    errors = []

    def hammer(tag):
        s = svc.session(f"hammer-{tag}")
        try:
            for i in range(n_per_thread):
                while True:
                    try:
                        p = s.submit({"op": "bfs", "graph": "g",
                                      "params": {"source": i % 7}})
                        break
                    except RejectedError as e:
                        time.sleep(min(e.retry_after, 0.005))
                p.result(timeout=60)
        except Exception as e:            # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in "ab"]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.close()
    assert not errors
    total = 2 * n_per_thread
    assert svc.stats["requests"] == total
    assert obs.counter("service.requests").value - obs_before == total
    # every request either hit the cache or reached the engine exactly once
    served = (svc.stats["cache_hits"] + svc.stats["engine_calls"]
              + svc.stats["fused_requests"] - svc.stats["fused_calls"]
              + svc.stats["retained"])
    assert svc.stats["cache_hits"] + svc.stats["cache_misses"] >= \
        svc.stats["engine_calls"]
    assert served >= svc.stats["engine_calls"]
