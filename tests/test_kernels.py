"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bsr_spmv import bsr_spmv
from repro.kernels.bsr_tricount import bsr_tricount
from repro.kernels.segment_sum import segment_sum_chunked


def _random_bsr(rng, n_row_blocks, n_col_blocks, b, nnzb, dtype):
    rows = np.sort(rng.integers(0, n_row_blocks, nnzb)).astype(np.int32)
    # ensure every row block appears (kernel contract)
    rows[:n_row_blocks] = np.arange(n_row_blocks)
    rows = np.sort(rows)
    cols = rng.integers(0, n_col_blocks, nnzb).astype(np.int32)
    tiles = rng.normal(size=(nnzb, b, b)).astype(dtype)
    return jnp.asarray(tiles), jnp.asarray(rows), jnp.asarray(cols)


@pytest.mark.parametrize("b", [8, 16, 128])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_bsr_spmv_sweep(rng, b, dtype):
    nrb, ncb, nnzb = 4, 3, 10
    tiles, rows, cols = _random_bsr(rng, nrb, ncb, b, nnzb, np.float32)
    tiles = tiles.astype(dtype)
    x = jnp.asarray(rng.normal(size=(ncb, b)).astype(np.float32))
    y = bsr_spmv(tiles, rows, cols, x, nrb, interpret=True)
    y_ref = ref.bsr_spmv_ref(tiles, rows, cols, x, nrb)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("b", [8, 128])
def test_bsr_spmv_duplicate_tiles_accumulate(rng, b):
    # two tiles on the same (row, col) must sum
    tiles = jnp.asarray(rng.normal(size=(2, b, b)).astype(np.float32))
    rows = jnp.asarray([0, 0], jnp.int32)
    cols = jnp.asarray([0, 0], jnp.int32)
    x = jnp.asarray(rng.normal(size=(1, b)).astype(np.float32))
    y = bsr_spmv(tiles, rows, cols, x, 1, interpret=True)
    np.testing.assert_allclose(np.asarray(y)[0],
                               np.asarray((tiles[0] + tiles[1]) @ x[0]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,b", [(64, 8), (300, 16), (260, 128)])
def test_bsr_tricount_sweep(rng, n, b):
    # random symmetric simple graph
    m = n * 4
    s = rng.integers(0, n, m)
    d = rng.integers(0, n, m)
    keep = s != d
    s, d = s[keep], d[keep]
    src = np.concatenate([s, d])
    dst = np.concatenate([d, s])
    tiles, rows, cols, nb = ops.edges_to_bsr(src, dst, n, block=b)
    tiles = jnp.minimum(tiles, 1.0)
    tij, tik, tkj = ops.build_block_triples(np.asarray(rows), np.asarray(cols))
    six_t = bsr_tricount(tiles, tij, tik, tkj, interpret=True)
    want = ref.bsr_tricount_ref(tiles, rows, cols, nb)
    assert int(round(float(six_t))) == int(round(float(want)))


@pytest.mark.parametrize("e,n_seg,chunk", [(100, 40, 16), (1000, 700, 64),
                                           (5000, 260, 512)])
def test_segment_sum_sweep(rng, e, n_seg, chunk):
    seg = np.sort(rng.integers(0, n_seg, e))
    vals = rng.normal(size=e).astype(np.float32)
    got = ops.segment_sum_sorted(jnp.asarray(vals), jnp.asarray(seg), n_seg,
                                 chunk=chunk, interpret=True)
    want = jax.ops.segment_sum(jnp.asarray(vals), jnp.asarray(seg),
                               num_segments=n_seg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_chunked_vs_chunked_ref(rng):
    c, l = 6, 32
    vals = jnp.asarray(rng.normal(size=(c, l)).astype(np.float32))
    lids = jnp.asarray(rng.integers(0, 129, size=(c, l)).astype(np.int32))
    blk = jnp.asarray(np.sort(rng.integers(0, 3, c)).astype(np.int32))
    blk = blk.at[:3].set(jnp.arange(3, dtype=jnp.int32))
    blk = jnp.sort(blk)
    got = segment_sum_chunked(vals, lids, blk, 3, interpret=True)
    want = ref.segment_sum_chunked_ref(vals, lids, blk, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_empty_graph_tricount():
    assert ops.triangle_count_bsr(
        __import__("repro.core.graph", fromlist=["Graph"]).Graph.from_edges(
            [0], [1]).to_undirected(), interpret=True) == 0


def test_edges_to_bsr_zero_nodes_keeps_grid_nonempty():
    # n=0 / zero-edge re-blocking must still emit a runnable tile stream
    e = np.zeros((0,), np.int32)
    tiles, rows, cols, nb = ops.edges_to_bsr(e, e, 0)
    assert nb == 1 and tiles.shape[0] == 1 and rows.shape == (1,)
    y = bsr_spmv(tiles, rows, cols, jnp.zeros((nb, tiles.shape[1])), nb,
                 interpret=True)
    assert not np.asarray(y).any()


# ---------------------------------------------------------------------------
# flash attention forward kernel (§Perf follow-up; serving path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,h,d,causal,chunk", [
    (2, 64, 3, 16, True, 16),
    (1, 128, 2, 32, False, 32),
    (2, 96, 1, 8, True, 32),      # non-pow2 seq: chunk auto-fits
])
def test_flash_attention_kernel_sweep(rng, b, s, h, d, causal, chunk):
    from repro.kernels.flash_attention import flash_attention_fwd
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    out = flash_attention_fwd(q, k, v, causal=causal, q_chunk=chunk,
                              k_chunk=chunk, interpret=True)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_kernel_bf16(rng):
    from repro.kernels.flash_attention import flash_attention_fwd
    b, s, h, d = 1, 64, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, h, d))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, h, d))).astype(jnp.bfloat16)
    out = flash_attention_fwd(q, k, v, causal=True, q_chunk=16, k_chunk=16,
                              interpret=True)
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) / (d ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), vf)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref), atol=0.06, rtol=0.06)
