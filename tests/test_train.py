"""Optimizers, compression, checkpointing, elastic coordination, pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (OptHyper, adamw_init, adamw_update,
                                   adafactor_init, adafactor_update,
                                   clip_by_global_norm, global_norm,
                                   zero1_extend_spec)
from repro.train.compress import (quantize_int8, dequantize_int8,
                                  init_error_feedback)
from repro.checkpoint.store import (save_checkpoint, load_checkpoint,
                                    latest_step, config_hash)
from repro.data.pipeline import SyntheticLM
from repro.data.rmat import rmat_edges
from repro.launch.elastic import ElasticCoordinator


def toy_problem():
    """Quadratic bowl: params should converge toward target."""
    target = {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray(0.5)}

    def loss(p):
        return (jnp.sum((p["w"] - target["w"]) ** 2)
                + (p["b"] - target["b"]) ** 2)

    params = {"w": jnp.zeros(3), "b": jnp.asarray(0.0)}
    return params, loss, target


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_descend(opt):
    params, loss, target = toy_problem()
    h = OptHyper(lr=0.1, weight_decay=0.0)
    state = adamw_init(params) if opt == "adamw" else adafactor_init(params)
    update = adamw_update if opt == "adamw" else adafactor_update
    l0 = float(loss(params))
    for i in range(200):
        g = jax.grad(loss)(params)
        params, state = update(params, g, state, jnp.int32(i), h)
    assert float(loss(params)) < 0.05 * l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(700.0), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=1000) * 5)
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6


def test_zero1_spec_extension():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class Shaped:
        def __init__(self, shape):
            self.shape = shape

    # free dim divisible -> data added once
    s = zero1_extend_spec(P(None, "model"), (16, 32), mesh, "data")
    assert s == P("data", "model")
    # already-used data axis -> unchanged
    s2 = zero1_extend_spec(P("data", "model"), (16, 32), mesh, "data")
    assert s2 == P("data", "model")


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"m": jnp.ones((2, 3))}}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree, meta={"config": "abc"})
    save_checkpoint(d, 9, tree, meta={"config": "abc"})
    assert latest_step(d) == 9
    step, restored, meta = load_checkpoint(d, tree)
    assert step == 9 and meta["config"] == "abc"
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_checkpoint_ignores_partial(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.ones(3)}
    save_checkpoint(d, 5, tree)
    os.makedirs(os.path.join(d, "step_00000009"))  # crashed save: no manifest
    assert latest_step(d) == 5


def test_checkpoint_checksum_detects_corruption(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.ones(4)}
    path = save_checkpoint(d, 3, tree)
    shard = os.path.join(path, "shard_0.npz")
    np.savez(shard, w=np.zeros(4, np.float32))   # corrupt payload
    with pytest.raises(IOError):
        load_checkpoint(d, tree)


def test_pipeline_determinism():
    src = SyntheticLM(vocab_size=100, batch=4, seq_len=8, seed=3)
    b1 = src.batch_at(17)
    b2 = src.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(src.batch_at(0)["tokens"][:, 1:],
                                  src.batch_at(0)["targets"][:, :-1])


def test_rmat_power_law():
    s, d = rmat_edges(scale=10, edge_factor=8, seed=1)
    assert len(s) == 8 * 1024
    deg = np.bincount(s, minlength=1024)
    # heavy tail: max degree far above mean
    assert deg.max() > 8 * deg.mean()


def test_elastic_straggler_detection():
    c = ElasticCoordinator(n_workers=8, hosts_per_tp_group=2,
                           straggler_factor=1.5, evict_after_flags=2)
    for step in range(25):
        for w in range(8):
            t = 1.0 if w != 3 else 2.5   # worker 3 lags
            c.heartbeat(w, t, now=float(step))
    lagging = c.stragglers()
    assert lagging == [3]


def test_elastic_remesh_on_death():
    c = ElasticCoordinator(n_workers=8, hosts_per_tp_group=2, dead_after=10.0)
    for w in range(8):
        c.heartbeat(w, 1.0, now=0.0)
    for w in range(7):                    # worker 7 goes silent
        c.heartbeat(w, 1.0, now=100.0)
    plan = c.plan(now=106.0)
    assert plan.restart_required
    assert 7 in plan.dropped_workers
    # 3 surviving TP groups -> dp rounds down to 2
    assert plan.mesh_shape == (2, 2)


def test_elastic_healthy_noop():
    c = ElasticCoordinator(n_workers=4, hosts_per_tp_group=2)
    for w in range(4):
        c.heartbeat(w, 1.0, now=1.0)
    plan = c.plan(now=2.0)
    assert not plan.restart_required
    assert plan.mesh_shape == (2, 2)


def test_ddp_compressed_matches_uncompressed():
    """int8-compressed DP gradients stay close to exact means (1 device)."""
    from repro.train.compress import compressed_psum
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    import functools
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=64)
                          .astype(np.float32))}
    r = init_error_feedback(g)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()))
    def run(gr, res):
        return compressed_psum(gr, res, "data")

    mean, new_r = run(g, r)
    err = np.abs(np.asarray(mean["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127.0
    assert err.max() <= scale * 0.51
    # error feedback holds the residual
    np.testing.assert_allclose(np.asarray(new_r["w"]),
                               np.asarray(g["w"] - mean["w"]), atol=1e-6)
