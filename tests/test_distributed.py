"""Distributed graph engine + DDP on the simulated 8-device host mesh.

When the test session itself already sees >= 8 devices (the `sharded-sim`
CI lane exports ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
before pytest starts), the suite runs **in-process** on the ambient mesh —
same interpreter, real coverage.  On a plain 1-device session the device
count can't be raised after jax initializes, so the same suite source is
re-run in a subprocess that sets the flag first; either way the seed
distributed tests actually execute instead of being skipped.
"""

import inspect
import os
import subprocess
import sys
import textwrap

import jax
import pytest


def _suite():
    import numpy as np
    import jax
    import jax.numpy as jnp
    assert len(jax.devices()) >= 8

    from repro.core.graph import Graph
    from repro.core import algorithms as A
    from repro.core.distributed import (make_graph_mesh, shard_graph,
                                        pagerank_distributed,
                                        distributed_to_graph,
                                        triangle_count_distributed,
                                        degrees_distributed)

    rng = np.random.default_rng(3)
    n, m = 400, 2400
    s = rng.integers(0, n, m)
    d = rng.integers(0, n, m)
    keep = s != d
    s, d = s[keep], d[keep]
    g = Graph.from_edges(s, d, dedupe=True)
    mesh = make_graph_mesh()

    # distributed pagerank == single-device pagerank
    dg = shard_graph(g, mesh)
    pr_d = np.asarray(pagerank_distributed(dg, mesh, n_iter=8))
    pr_s = np.asarray(A.pagerank(g, n_iter=8))
    assert np.abs(pr_d - pr_s).max() < 1e-6, "dist pagerank mismatch"

    # bf16-compressed collective stays close
    pr_c = np.asarray(pagerank_distributed(dg, mesh, n_iter=8,
                                           compress_bf16=True))
    assert np.abs(pr_c - pr_s).max() < 5e-5, "bf16 pagerank too lossy"

    # distributed conversion (sort-first + all_to_all) feeds pagerank
    sd, dd = (np.asarray(x) for x in g.out_edges())
    dg2 = distributed_to_graph(jnp.asarray(sd), jnp.asarray(dd),
                               g.n_nodes, mesh)
    pr_d2 = np.asarray(pagerank_distributed(dg2, mesh, n_iter=8))
    assert np.abs(pr_d2 - pr_s).max() < 1e-6, "dist conversion mismatch"

    deg = np.asarray(degrees_distributed(dg, mesh))
    assert np.array_equal(deg, np.asarray(g.in_degrees())), "degrees"

    u = g.to_undirected()
    t_d = triangle_count_distributed(u, mesh, edge_chunk=256)
    assert t_d == A.triangle_count(u), "dist triangles"

    # the "sharded" engine backend on the same mesh: bitwise vs "xla"
    np.testing.assert_array_equal(
        np.asarray(A.pagerank(g, n_iter=8, backend="sharded")),
        np.asarray(A.pagerank(g, n_iter=8, backend="xla")))
    np.testing.assert_array_equal(
        np.asarray(A.bfs(g, 0, backend="sharded")),
        np.asarray(A.bfs(g, 0, backend="xla")))

    # explicit DDP with int8 gradient compression trains
    from repro.configs.base import get_config, reduced
    from repro.train.step import make_ddp_step, init_train_state
    from repro.train.compress import init_error_feedback
    from repro.train.optimizer import OptHyper
    cfg = reduced(get_config("qwen2.5-3b"))
    mesh2 = jax.make_mesh((8,), ("data",))
    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = make_ddp_step(cfg, mesh2, OptHyper(lr=1e-3), compress=True,
                         attn_chunk=16)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (16, 16), 0,
                                          cfg.vocab_size),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (16, 16), 0,
                                           cfg.vocab_size)}
    res = init_error_feedback(params)
    losses = []
    for i in range(4):
        params, opt_state, loss, res = step(params, opt_state, batch,
                                            jnp.int32(i), res)
        losses.append(float(loss))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], f"no descent: {losses}"

    print("DISTRIBUTED-OK")


# subprocess fallback: same source, device flag set before jax imports
SCRIPT = ('import os\n'
          'os.environ["XLA_FLAGS"] = '
          '"--xla_force_host_platform_device_count=8"\n'
          + textwrap.dedent(inspect.getsource(_suite))
          + '\n_suite()\n')


@pytest.mark.slow
def test_distributed_suite(capsys):
    if len(jax.devices()) >= 8:
        _suite()            # ambient simulated host mesh: run in-process
        assert "DISTRIBUTED-OK" in capsys.readouterr().out
        return
    # 1-device session: XLA_FLAGS can't change after jax init -> isolate.
    # JAX_PLATFORMS=cpu skips libtpu's minutes-long TPU-metadata probe.
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-W", "ignore", "-c", SCRIPT],
                          capture_output=True, text=True, timeout=1200,
                          env=env, cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert "DISTRIBUTED-OK" in proc.stdout, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
