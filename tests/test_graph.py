"""Graph structure: sort-first construction, conversions, functional updates."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.graph import Graph, INVALID_ID
from repro.core.table import Table, INT
from repro.core.convert import (to_graph, graph_to_edge_table,
                                graph_to_node_table, table_from_map)
from conftest import random_digraph


def test_construction_and_degrees():
    g = Graph.from_edges([10, 10, 20, 30], [20, 30, 30, 10])
    assert g.n_nodes == 3 and g.n_edges == 4
    assert np.asarray(g.out_degrees()).tolist() == [2, 1, 1]
    assert np.asarray(g.in_degrees()).tolist() == [1, 1, 2]


def test_adjacency_sorted_within_rows():
    g = Graph.from_edges([0, 0, 0, 1], [5, 3, 9, 7])
    nbrs = np.asarray(g.neighbors_out(0))
    assert nbrs.tolist() == sorted(nbrs.tolist())


def test_dense_renumbering_lookup():
    g = Graph.from_edges([100, 7, 100], [7, 55, 55])
    ids = np.asarray(g.node_ids[:g.n_nodes])
    assert ids.tolist() == [7, 55, 100]
    assert np.asarray(g.dense_of([55, 100, 7])).tolist() == [1, 2, 0]
    assert np.asarray(g.original_of([0, 1, 2])).tolist() == [7, 55, 100]


def test_dedupe_and_self_loops():
    g = Graph.from_edges([1, 1, 1, 2], [2, 2, 1, 1], dedupe=True,
                         drop_self_loops=True)
    assert g.n_edges == 2  # (1,2) and (2,1)


def test_edge_table_round_trip(rng):
    s, d = random_digraph(rng, n=80, m=500, seed=7)
    g = Graph.from_edges(s, d)
    et = graph_to_edge_table(g)
    got = set(zip(et.to_pydict()["src"], et.to_pydict()["dst"]))
    assert got == set(zip(s.tolist(), d.tolist()))


def test_to_graph_from_table():
    t = Table.from_columns({"s": INT, "d": INT},
                           {"s": [5, 5, 9], "d": [9, 6, 6]})
    g = to_graph(t, "s", "d")
    assert g.n_nodes == 3 and g.n_edges == 3


def test_to_graph_string_columns():
    from repro.core.table import STR
    t = Table.from_columns({"a": STR, "b": STR},
                           {"a": ["u1", "u2", "u1"], "b": ["u2", "u3", "u3"]})
    g = to_graph(t, "a", "b")
    assert g.n_nodes == 3 and g.n_edges == 3


def test_add_delete_edges():
    g = Graph.from_edges([1, 2], [2, 3])
    g2 = g.add_edges([3], [1])
    assert g2.n_edges == 3
    g3 = g2.delete_edges([3, 1], [1, 2])
    got = graph_to_edge_table(g3).to_pydict()
    assert list(zip(got["src"], got["dst"])) == [(2, 3)]


def test_to_undirected_symmetry(rng):
    s, d = random_digraph(rng, n=40, m=200, seed=3)
    u = Graph.from_edges(s, d).to_undirected()
    es, ed = (np.asarray(x) for x in u.out_edges())
    pairs = set(zip(es.tolist(), ed.tolist()))
    assert all((b, a) in pairs for a, b in pairs)
    assert not any(a == b for a, b in pairs)


def test_node_table_and_score_map():
    g = Graph.from_edges([10, 20], [20, 30])
    import jax.numpy as jnp
    scores = jnp.asarray([0.1, 0.9, 0.5])
    t = table_from_map(g, scores, "node", "score")
    d = t.to_pydict()
    assert d["node"] == [20, 30, 10]      # sorted by score desc
    assert d["score"] == pytest.approx([0.9, 0.5, 0.1])


def test_empty_graph():
    g = Graph.from_edges([], [])
    assert g.n_nodes == 0 and g.n_edges == 0


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 30)),
                min_size=1, max_size=60))
def test_prop_construction_round_trip(edges):
    edges = [(a, b) for a, b in edges]
    s = np.asarray([e[0] for e in edges], np.int32)
    d = np.asarray([e[1] for e in edges], np.int32)
    g = Graph.from_edges(s, d, dedupe=True)
    et = graph_to_edge_table(g)
    got = set(zip(et.to_pydict()["src"], et.to_pydict()["dst"]))
    assert got == set(edges)
    # node set = union of endpoints
    assert g.n_nodes == len(set(s.tolist()) | set(d.tolist()))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 20), st.integers(0, 20)),
                min_size=1, max_size=40))
def test_prop_degree_sum_equals_edges(edges):
    s = np.asarray([e[0] for e in edges], np.int32)
    d = np.asarray([e[1] for e in edges], np.int32)
    g = Graph.from_edges(s, d, dedupe=True)
    assert int(np.asarray(g.out_degrees()).sum()) == g.n_edges
    assert int(np.asarray(g.in_degrees()).sum()) == g.n_edges
