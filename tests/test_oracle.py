"""Cross-backend differential oracle: NumPy references vs every backend.

The engine contract is that backend choice never changes results — "xla",
"pallas", "bsr" and the sparse "frontier" path must agree with each other
AND with an independent pure-NumPy implementation on every graph shape,
including the degenerate ones (star, path, disconnected with isolated
vertices, self-loops, zero-edge).  Each algorithm is checked differentially
over the whole corpus x backend matrix, plus seeded randomized graphs via
the hypothesis shim.
"""

import collections

import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import algorithms as A
from repro.core import engine
from repro.core.graph import EdgeDelta, Graph
from repro.data.rmat import rmat_edges

BACKENDS = list(engine.BACKENDS)          # xla, pallas, bsr, frontier


# ---------------------------------------------------------------------------
# the corpus — every entry is (name, graph) with dense ids 0..n-1
# ---------------------------------------------------------------------------


def _zero_edge(n):
    e = jnp.zeros((0,), jnp.int32)
    return Graph.from_dense_edges(e, e, n)


def _corpus():
    out = []
    s, d = rmat_edges(6, edge_factor=4, seed=5)
    out.append(("rmat", Graph.from_edges(s, d)))
    n = 33
    out.append(("star", Graph.from_edges(np.zeros(n - 1, np.int32),
                                         np.arange(1, n, dtype=np.int32))))
    out.append(("path", Graph.from_edges(np.arange(0, 40, dtype=np.int32),
                                         np.arange(1, 41, dtype=np.int32))))
    # two components + isolated vertices (ids 20..23 have no edges at all)
    ds, dd = rmat_edges(4, edge_factor=3, seed=9)
    src = np.concatenate([ds % 8, ds % 6 + 10]).astype(np.int32)
    dst = np.concatenate([dd % 8, dd % 6 + 10]).astype(np.int32)
    out.append(("disconnected",
                Graph.from_dense_edges(jnp.asarray(src), jnp.asarray(dst), 24)))
    out.append(("self_loop", Graph.from_edges(
        np.asarray([0, 1, 2, 2, 3], np.int32),
        np.asarray([0, 2, 2, 3, 1], np.int32))))
    out.append(("zero_edge", _zero_edge(8)))
    return out


CORPUS = _corpus()
CASES = [(name, backend) for name, _ in CORPUS for backend in BACKENDS]
GRAPHS = dict(CORPUS)


def edge_list(g):
    s, d = (np.asarray(a) for a in g.out_edges())
    return list(zip(s.tolist(), d.tolist()))


def undirected_simple(edges):
    """Symmetrized, deduped, self-loop-free adjacency (to_undirected dual)."""
    adj = collections.defaultdict(set)
    for a, b in edges:
        if a != b:
            adj[a].add(b)
            adj[b].add(a)
    return adj


# ---------------------------------------------------------------------------
# pure-NumPy references
# ---------------------------------------------------------------------------


def np_pagerank(edges, n, n_iter=10, damping=0.85):
    pr = np.full(n, 1.0 / n, np.float64)
    outdeg = np.zeros(n)
    for s, _ in edges:
        outdeg[s] += 1
    for _ in range(n_iter):
        new = np.full(n, (1.0 - damping) / n)
        new += damping * pr[outdeg == 0].sum() / n
        for s, t in edges:
            new[t] += damping * pr[s] / outdeg[s]
        pr = new
    return pr


def np_bfs(edges, n, source):
    adj = collections.defaultdict(list)
    for s, t in edges:
        adj[s].append(t)
    level = np.full(n, -1, np.int64)
    level[source] = 0
    q = collections.deque([source])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if level[v] < 0:
                level[v] = level[u] + 1
                q.append(v)
    return level


def np_sssp(edges, n, source, w=None):
    """Bellman-Ford over the edge list (matches the engine's relaxation)."""
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    w = np.ones(len(edges)) if w is None else np.asarray(w, np.float64)
    for _ in range(max(n, 1)):
        changed = False
        for (s, t), wv in zip(edges, w):
            if dist[s] + wv < dist[t]:
                dist[t] = dist[s] + wv
                changed = True
        if not changed:
            break
    return dist


def np_connected_components(edges, n):
    """Min dense id per weakly-connected component (isolated = own id)."""
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
    return np.asarray([find(i) for i in range(n)])


def np_k_core(edges, n, k):
    """Iterative peeling on the undirected simple view (alive mask)."""
    adj = undirected_simple(edges)
    alive = np.ones(n, bool)
    while True:
        deg = np.asarray([sum(alive[v] for v in adj[u]) if alive[u] else 0
                          for u in range(n)])
        new = alive & (deg >= k)
        if (new == alive).all():
            return new
        alive = new


def np_triangle_count(edges, n):
    adj = undirected_simple(edges)
    total = 0
    for u in range(n):
        for v in adj[u]:
            if v > u:
                total += len(adj[u] & adj[v] - {u, v})
    return total // 3  # each triangle counted once per edge... (u<v per pair)


# ---------------------------------------------------------------------------
# the differential matrix: algorithm x corpus x backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,backend", CASES)
def test_pagerank_matrix(name, backend):
    g = GRAPHS[name]
    got = np.asarray(A.pagerank(g, n_iter=8, backend=backend, interpret=True))
    want = np_pagerank(edge_list(g), g.n_nodes, n_iter=8)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("name,backend", CASES)
def test_bfs_matrix(name, backend):
    g = GRAPHS[name]
    if g.n_nodes == 0:
        pytest.skip("bfs needs a source vertex")
    for source in {0, g.n_nodes // 2, g.n_nodes - 1}:
        got = np.asarray(A.bfs(g, source, backend=backend, interpret=True))
        np.testing.assert_array_equal(
            got, np_bfs(edge_list(g), g.n_nodes, source), err_msg=f"src={source}")


@pytest.mark.parametrize("name,backend", CASES)
def test_sssp_matrix(name, backend):
    g = GRAPHS[name]
    if g.n_nodes == 0:
        pytest.skip("sssp needs a source vertex")
    edges_in = list(zip(*(np.asarray(a).tolist() for a in g.in_edges()))) \
        if g.n_edges else []
    w = np.round(np.random.default_rng(7).uniform(0.5, 4.0, g.n_edges), 1)
    got = np.asarray(A.sssp(g, 0, weights=jnp.asarray(w, dtype=jnp.float32),
                            backend=backend, interpret=True))
    want = np_sssp(edges_in, g.n_nodes, 0, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,backend", CASES)
def test_connected_components_matrix(name, backend):
    g = GRAPHS[name]
    got = np.asarray(A.connected_components(g, backend=backend,
                                            interpret=True))
    np.testing.assert_array_equal(
        got, np_connected_components(edge_list(g), g.n_nodes))


@pytest.mark.parametrize("name,backend", CASES)
def test_k_core_matrix(name, backend):
    g = GRAPHS[name]
    for k in (0, 2, 3):
        got = np.asarray(A.k_core(g, k, backend=backend, interpret=True))
        np.testing.assert_array_equal(
            got, np_k_core(edge_list(g), g.n_nodes, k), err_msg=f"k={k}")


@pytest.mark.parametrize("name", [name for name, _ in CORPUS])
@pytest.mark.parametrize("backend", [None, "bsr"])
def test_triangle_count_matrix(name, backend):
    # triangle_count exposes the oriented-intersection and MXU-BSR paths
    # only; "pallas"/"frontier" are rejected by design (covered elsewhere)
    g = GRAPHS[name]
    got = A.triangle_count(g.to_undirected() if g.n_edges else g,
                           backend=backend, interpret=True)
    assert got == np_triangle_count(edge_list(g), g.n_nodes)


# ---------------------------------------------------------------------------
# sentinel consistency: bfs(-1) and sssp(inf) must mark the same vertices
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,backend", CASES)
def test_unreached_sentinels_consistent(name, backend):
    g = GRAPHS[name]
    if g.n_nodes == 0:
        pytest.skip("needs a source vertex")
    lev = np.asarray(A.bfs(g, 0, backend=backend, interpret=True))
    dist = np.asarray(A.sssp(g, 0, backend=backend, interpret=True))
    np.testing.assert_array_equal(lev < 0, np.isinf(dist))
    np.testing.assert_array_equal(lev[lev >= 0], dist[lev >= 0])


# ---------------------------------------------------------------------------
# seeded randomized graphs (hypothesis, or its deterministic fallback)
# ---------------------------------------------------------------------------


def _random_graph(n, m, seed):
    r = np.random.default_rng(seed)
    if m == 0:
        return _zero_edge(n)
    return Graph.from_dense_edges(jnp.asarray(r.integers(0, n, m), jnp.int32),
                                  jnp.asarray(r.integers(0, n, m), jnp.int32),
                                  n)


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 48), st.integers(0, 160), st.integers(0, 2 ** 20))
def test_random_graph_bfs_cc_all_backends(n, m, seed):
    g = _random_graph(n, m, seed)
    edges = edge_list(g)
    want_bfs = np_bfs(edges, n, 0)
    want_cc = np_connected_components(edges, n)
    for backend in ("xla", "frontier"):
        np.testing.assert_array_equal(
            np.asarray(A.bfs(g, 0, backend=backend)), want_bfs)
        np.testing.assert_array_equal(
            np.asarray(A.connected_components(g, backend=backend)), want_cc)


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 40), st.integers(1, 120), st.integers(0, 2 ** 20))
def test_random_graph_sssp_frontier_vs_dense(n, m, seed):
    g = _random_graph(n, m, seed)
    w = jnp.asarray(np.random.default_rng(seed + 1).uniform(0.5, 3.0,
                                                            g.n_edges),
                    dtype=jnp.float32)
    dense = np.asarray(A.sssp(g, 1 % n, weights=w, backend="xla"))
    sparse = np.asarray(A.sssp(g, 1 % n, weights=w, backend="frontier"))
    np.testing.assert_array_equal(dense, sparse)


# ---------------------------------------------------------------------------
# regressions for the edge cases the corpus surfaced
# ---------------------------------------------------------------------------


def test_zero_edge_plan_builds_empty_sorted_arrays():
    # plan construction must survive sorting/bincounting 0-length edge arrays
    plan = _zero_edge(8).plan()
    assert plan.in_src.shape == (0,) and plan.out_src.shape == (0,)
    assert np.asarray(plan.out_deg).sum() == 0
    ptr, _, deg_pad = plan.csr_out()
    assert ptr.shape == (9,) and int(ptr[-1]) == 0
    assert deg_pad.shape == (9,) and int(deg_pad[-1]) == 0
    assert plan.in_perm_out().shape == (0,)
    assert plan.oriented()[2].shape[0] == 8   # padded adjacency still built


def test_zero_edge_degree_normalization_no_nan():
    g = _zero_edge(6)
    pr = np.asarray(A.pagerank(g, n_iter=4))
    assert np.isfinite(pr).all() and abs(pr.sum() - 1.0) < 1e-5
    assert np.isfinite(np.asarray(A.clustering_coefficient(g))).all()
    assert np.asarray(A.degree_centrality(g)).tolist() == [0.0] * 6


def test_isolated_vertices_map_back_from_undirected_view():
    # ids 3 and 4 have no (non-loop) edges: absent from to_undirected()
    g = Graph.from_dense_edges(jnp.asarray([0, 1, 4], jnp.int32),
                               jnp.asarray([1, 2, 4], jnp.int32), 5)
    assert np.asarray(A.connected_components(g)).tolist() == [0, 0, 0, 3, 4]
    assert np.asarray(A.k_core(g, 1)).tolist() == [True, True, True,
                                                   False, False]
    assert np.asarray(A.k_core(g, 0)).tolist() == [True] * 5
    assert np.asarray(A.core_numbers(g)).tolist() == [1, 1, 1, 0, 0]
    assert np.asarray(A.label_propagation(g)).tolist() == [0, 0, 0, 3, 4]


def test_empty_graph_all_algorithms_degrade():
    g = Graph.from_edges([], [])
    assert A.pagerank(g).shape == (0,)
    assert A.connected_components(g).shape == (0,)
    assert A.k_core(g, 2).shape == (0,)
    assert A.triangle_count(g) == 0
    for backend in BACKENDS:   # kernel backends must not re-block 0 rows
        assert engine.get_exec(g.plan(), backend,
                               interpret=True).n_nodes == 0


def test_frontier_zero_edge_returns_init_unchanged():
    g = _zero_edge(5)
    dist = np.asarray(A.sssp(g, 2, backend="frontier"))
    want = np.full(5, np.inf)
    want[2] = 0.0
    np.testing.assert_array_equal(dist, want)
    assert np.asarray(A.bfs(g, 2, backend="frontier")).tolist() \
        == [-1, -1, 0, -1, -1]


# ---------------------------------------------------------------------------
# incremental oracle: after a delta, warm-started results == from-scratch
# ---------------------------------------------------------------------------


def _delta_for(g, seed, mixed):
    """Random delta over the graph's existing node-id space.

    Inserts stay within known ids so the ``apply_delta`` fast path engages
    and the child keeps delta lineage; ``mixed`` additionally deletes a
    random subset of existing edges (original-id pairs).
    """
    r = np.random.default_rng(seed)
    ids = np.asarray(g.node_ids)[:g.n_nodes]
    k = max(2, g.n_nodes // 6)
    a_s = ids[r.integers(0, g.n_nodes, k)].astype(np.int32)
    a_d = ids[r.integers(0, g.n_nodes, k)].astype(np.int32)
    if not mixed or g.n_edges == 0:
        return EdgeDelta.inserts(a_s, a_d)
    es, ed = (np.asarray(x) for x in g.out_edges())
    pick = r.integers(0, g.n_edges, max(1, g.n_edges // 8))
    return EdgeDelta(a_s, a_d, ids[es[pick]].astype(np.int32),
                     ids[ed[pick]].astype(np.int32))


@pytest.mark.parametrize("name", [name for name, _ in CORPUS])
@pytest.mark.parametrize("mixed", [False, True])
def test_incremental_matches_from_scratch(name, mixed):
    """Every supported op: incremental == cold-on-child == lineage-free
    rebuild, for random insert-only and mixed deltas on every corpus graph.

    Monotone min-relaxations must match bit-for-bit; pagerank under ``tol``
    semantics gets a tolerance.  Mixed deltas must make the traversal/label
    helpers decline (deletions are unsound to warm) and still leave the
    cold path exact.
    """
    g = GRAPHS[name]
    if g.n_nodes == 0:
        pytest.skip("needs a source vertex")
    delta = _delta_for(g, seed=sum(map(ord, name)), mixed=mixed)
    n_lp = max(g.n_nodes, 1)
    parent = {
        "bfs": A.bfs(g, 0),
        "sssp": A.sssp(g, 0),
        "cc": A.connected_components(g),
        "lp": A.label_propagation(g, n_iter=n_lp),
        "pr": A.pagerank(g, tol=1e-6),
    }
    child = g.apply_delta(delta)
    assert child._delta is not None          # fast path engaged
    assert child._delta.insert_only == delta.insert_only
    # lineage-free rebuild of the same edge set in the same dense numbering
    cs, cd = child.out_edges()
    fresh = Graph.from_dense_edges(cs, cd, child.n_nodes)

    incs = {
        "bfs": A.incremental_bfs(child, 0, parent["bfs"]),
        "sssp": A.incremental_sssp(child, 0, parent["sssp"]),
        "cc": A.incremental_connected_components(child, parent["cc"]),
        "lp": A.incremental_label_propagation(child, parent["lp"],
                                              n_iter=n_lp),
    }
    colds = {
        "bfs": A.bfs(child, 0),
        "sssp": A.sssp(child, 0),
        "cc": A.connected_components(child),
        "lp": A.label_propagation(child, n_iter=n_lp),
    }
    scratch = {
        "bfs": A.bfs(fresh, 0),
        "sssp": A.sssp(fresh, 0),
        "cc": A.connected_components(fresh),
        "lp": A.label_propagation(fresh, n_iter=n_lp),
    }
    for op in colds:
        np.testing.assert_array_equal(
            np.asarray(colds[op]), np.asarray(scratch[op]),
            err_msg=f"{op}: patched-plan cold run != lineage-free rebuild")
        if delta.insert_only:
            if op in ("bfs", "sssp"):
                assert incs[op] is not None, f"{op} declined an " \
                    "insert-only delta"
            if incs[op] is not None:
                np.testing.assert_array_equal(
                    np.asarray(incs[op]), np.asarray(colds[op]),
                    err_msg=f"{op}: incremental != from-scratch")
        else:
            assert incs[op] is None, f"{op} warmed through deletions"

    warm_pr = A.pagerank(child, tol=1e-6, init=parent["pr"])
    cold_pr = A.pagerank(child, tol=1e-6)
    scratch_pr = A.pagerank(fresh, tol=1e-6)
    np.testing.assert_allclose(np.asarray(warm_pr), np.asarray(cold_pr),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(cold_pr), np.asarray(scratch_pr),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# sharded backend: device-count sweep + per-shard plan-cache semantics
#
# The "sharded" rows of the matrix tests above already run the backend at
# the ambient device count; this section pins the counts the tentpole
# promises ({1, 2, 8}), asserting bit-identity both against the NumPy
# references (exact for the integer algorithms) and against "xla" (the
# bitwise contract, meaningful for the float solves too).  Counts above
# the visible device pool skip — the sharded-sim CI lane exposes 8
# simulated host devices so all three run there.
# ---------------------------------------------------------------------------


SHARD_COUNTS = (1, 2, 8)


def _require_devices(d):
    import jax
    if d > len(jax.devices()):
        pytest.skip(f"needs {d} devices, have {len(jax.devices())} "
                    "(run under XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=8)")


@pytest.mark.parametrize("name", [name for name, _ in CORPUS])
@pytest.mark.parametrize("d", SHARD_COUNTS)
def test_sharded_device_sweep_bit_identical(name, d, monkeypatch):
    _require_devices(d)
    monkeypatch.setenv("REPRO_SHARD_COUNT", str(d))
    g = GRAPHS[name]
    edges = edge_list(g)

    # integer algorithms: exact NumPy references, so "bit-identical" is
    # directly checkable against the independent implementation
    got_cc = np.asarray(A.connected_components(g, backend="sharded"))
    np.testing.assert_array_equal(
        got_cc, np_connected_components(edges, g.n_nodes),
        err_msg=f"cc d={d}")
    if g.n_nodes:
        got_bfs = np.asarray(A.bfs(g, 0, backend="sharded"))
        np.testing.assert_array_equal(got_bfs, np_bfs(edges, g.n_nodes, 0),
                                      err_msg=f"bfs d={d}")

    # float solves + label propagation: bitwise against "xla" (the tentpole
    # contract — shard count must never change a single mantissa bit), and
    # numerically against the float64 NumPy reference
    got_pr = np.asarray(A.pagerank(g, n_iter=8, backend="sharded"))
    np.testing.assert_array_equal(
        got_pr, np.asarray(A.pagerank(g, n_iter=8, backend="xla")),
        err_msg=f"pagerank d={d} diverges from xla")
    np.testing.assert_allclose(got_pr, np_pagerank(edges, g.n_nodes,
                                                   n_iter=8), atol=2e-5)
    np.testing.assert_array_equal(
        np.asarray(A.label_propagation(g, n_iter=6, backend="sharded")),
        np.asarray(A.label_propagation(g, n_iter=6, backend="xla")),
        err_msg=f"lp d={d} diverges from xla")
    if g.n_nodes:
        w = jnp.asarray(np.round(np.random.default_rng(7).uniform(
            0.5, 4.0, g.n_edges), 1), dtype=jnp.float32)
        got_ss = np.asarray(A.sssp(g, 0, weights=w, backend="sharded"))
        np.testing.assert_array_equal(
            got_ss, np.asarray(A.sssp(g, 0, weights=w, backend="xla")),
            err_msg=f"sssp d={d} diverges from xla")


def test_sharded_plan_family_memoized_and_byte_accounted(monkeypatch):
    from repro.core.plan import EVICTABLE_FAMILIES
    monkeypatch.setenv("REPRO_SHARD_COUNT", "1")
    g = GRAPHS["rmat"]
    plan = g.plan()
    sp = plan.sharded(1)
    assert plan.sharded(1) is sp              # identity-memoized per count
    assert "sharded" in EVICTABLE_FAMILIES
    assert plan.nbytes_by_family()["sharded"] > 0   # MemoryPolicy sees it
    baseline = np.asarray(A.pagerank(g, n_iter=8, backend="sharded"))
    freed = plan.evict("sharded")
    assert freed > 0
    assert plan.nbytes_by_family()["sharded"] == 0
    assert not plan.execs                     # stale Execs dropped with it
    sp2 = plan.sharded(1)
    assert sp2 is not sp                      # cold rebuild, not a resurrect
    np.testing.assert_array_equal(np.asarray(sp.pull.gather_idx),
                                  np.asarray(sp2.pull.gather_idx))
    np.testing.assert_array_equal(np.asarray(sp.push.seg_local),
                                  np.asarray(sp2.push.seg_local))
    rebuilt = np.asarray(A.pagerank(g, n_iter=8, backend="sharded"))
    np.testing.assert_array_equal(baseline, rebuilt)


def test_sharded_plan_invalidated_on_apply_delta():
    g = GRAPHS["rmat"]
    plan = g.plan()
    parent_sp = plan.sharded(1)
    ids = np.asarray(g.node_ids)[:g.n_nodes]
    child = g.apply_delta(EdgeDelta.inserts(ids[:3].astype(np.int32),
                                            ids[3:6].astype(np.int32)))
    cp = child.plan()
    assert cp is not plan
    assert not cp._sharded                    # child starts cold: no stale
    child_sp = cp.sharded(1)                  # per-shard arrays can leak in
    assert child_sp is not parent_sp
    assert plan._sharded[1] is parent_sp      # parent cache untouched
    # the child's sharded answers track the NEW edge set, bitwise vs xla
    np.testing.assert_array_equal(
        np.asarray(A.pagerank(child, n_iter=8, backend="sharded")),
        np.asarray(A.pagerank(child, n_iter=8, backend="xla")))
    np.testing.assert_array_equal(
        np.asarray(A.connected_components(child, backend="sharded")),
        np.asarray(A.connected_components(child, backend="xla")))


def test_sharded_exec_cache_keyed_on_shard_count(monkeypatch):
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    g = GRAPHS["disconnected"]
    plan = g.plan()
    monkeypatch.setenv("REPRO_SHARD_COUNT", "1")
    ex1 = engine.get_exec(plan, "sharded")
    monkeypatch.setenv("REPRO_SHARD_COUNT", "2")
    ex2 = engine.get_exec(plan, "sharded")
    assert ex1 is not ex2 and ex1.d == 1 and ex2.d == 2
    # one ShardPlan per count (other counts may already be cached by the
    # device-sweep tests — GRAPHS entries are module-shared)
    assert {1, 2} <= set(plan._sharded)
    monkeypatch.setenv("REPRO_SHARD_COUNT", "1")
    assert engine.get_exec(plan, "sharded") is ex1   # memoized round trip


def test_incremental_cc_engages_on_plain_graph():
    # the und-view patch carries lineage whenever all insert endpoints are
    # non-isolated in the parent — assert the warm path actually fires
    # somewhere, so the matrix above can't silently pass on all-fallbacks
    g = GRAPHS["rmat"]
    es, ed = (np.asarray(x) for x in g.out_edges())
    ids = np.asarray(g.node_ids)[:g.n_nodes]
    delta = EdgeDelta.inserts(ids[es[:4]], ids[ed[2:6]])
    child = g.apply_delta(delta)
    inc = A.incremental_connected_components(
        child, A.connected_components(g))
    assert inc is not None
    np.testing.assert_array_equal(np.asarray(inc),
                                  np.asarray(A.connected_components(child)))
