"""Wire codec (serve/wire.py): framing, value round-trips, typed errors.

Every frame/value kind the protocol defines round-trips bit-exactly; the
reader rejects truncated frames and unknown protocol versions instead of
guessing at byte alignment; typed error payloads rebuild the service's
exception vocabulary (RejectedError keeps retry_after, DeadlineExpired stays
catchable) on the far side.
"""

import struct

import numpy as np
import pytest

from repro.core import provenance as P
from repro.core.graph import Graph
from repro.core.table import FLOAT, INT, STR, Table
from repro.serve import wire
from repro.serve.policy import (DeadlineExpired, RejectedError, RemoteError,
                                ServiceError, error_from_wire, error_to_wire)


def roundtrip(v, ftype=wire.FrameType.REQUEST, req_id=9):
    chunks = wire.encode_frame(ftype, req_id, v)
    ft, rid, out = wire.decode_frame(b"".join(bytes(c) for c in chunks))
    assert ft == ftype and rid == req_id
    return out


# ---------------------------------------------------------------------------
# scalar / container values
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("v", [
    None, True, False, 0, -1, 2**62, 3.25, float("inf"), "", "héllo wörld",
    b"", b"\x00\xffraw", [], [1, "two", None], (),
    (1, (2, "x")), {}, {"op": "pagerank", "params": {"n_iter": 20}},
])
def test_value_roundtrip(v):
    assert roundtrip(v) == v


def test_tuple_list_distinction_survives():
    out = roundtrip({"t": (1, 2), "l": [1, 2]})
    assert isinstance(out["t"], tuple) and isinstance(out["l"], list)


def test_int_overflow_refused():
    with pytest.raises(wire.WireError, match="int64"):
        wire.encode_frame(1, 1, 2**70)


def test_non_string_dict_keys_refused():
    with pytest.raises(wire.WireError, match="keys must be str"):
        wire.encode_frame(1, 1, {1: "x"})


def test_unencodable_type_refused():
    with pytest.raises(wire.WireError, match="no wire form"):
        wire.encode_frame(1, 1, object())


# ---------------------------------------------------------------------------
# arrays: empty, >1MB, dtypes, zero-copy semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arr", [
    np.zeros((0,), np.float32),
    np.zeros((0, 4), np.int64),
    np.arange(7, dtype=np.int32),
    np.asarray(3.5, dtype=np.float64),               # 0-d scalar array
    np.random.default_rng(0).normal(size=(513, 300)),  # > 1 MB float64
    np.array([True, False, True]),
])
def test_array_roundtrip(arr):
    out = roundtrip(arr)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_big_array_is_zero_copy_on_both_sides():
    arr = np.random.default_rng(1).normal(size=(1 << 17,))  # 1 MiB
    chunks = wire.encode_frame(2, 1, arr)
    # encoder: the array's buffer is passed through as its own chunk
    assert any(isinstance(c, memoryview) and c.nbytes == arr.nbytes
               for c in chunks)
    _, _, out = wire.decode_frame(b"".join(bytes(c) for c in chunks))
    # decoder: the result aliases the frame buffer, hence read-only
    assert not out.flags.writeable
    np.testing.assert_array_equal(out, arr)


def test_object_dtype_refused():
    with pytest.raises(wire.WireError, match="no wire form"):
        wire.encode_frame(1, 1, np.array(["a", "b"], dtype=object))


def test_jax_array_encodes_as_array():
    import jax.numpy as jnp
    out = roundtrip(jnp.arange(5, dtype=jnp.float32))
    assert isinstance(out, np.ndarray)
    np.testing.assert_array_equal(out, np.arange(5, dtype=np.float32))


# ---------------------------------------------------------------------------
# tables (incl. string columns) and graphs
# ---------------------------------------------------------------------------


def test_table_roundtrip_string_columns():
    t = Table.from_columns(
        {"id": INT, "score": FLOAT, "tag": STR},
        {"id": [3, 1, 2], "score": [0.5, 1.5, -2.0],
         "tag": ["java", "python", "java"]})
    out = roundtrip(t)
    assert isinstance(out, Table)
    assert out.schema.fields == t.schema.fields
    assert out.to_pydict() == t.to_pydict()
    np.testing.assert_array_equal(out.column_np("id"), t.column_np("id"))
    assert out.strings("tag") == ["java", "python", "java"]
    assert out.next_row_id == t.next_row_id
    np.testing.assert_array_equal(np.asarray(out.row_ids[:3]),
                                  np.asarray(t.row_ids[:3]))


def test_empty_table_roundtrip():
    t = Table.from_columns({"x": INT, "s": STR}, {"x": [], "s": []})
    out = roundtrip(t)
    assert len(out) == 0 and out.schema.names == ("x", "s")


def test_graph_roundtrip():
    src = np.array([0, 7, 7, 3], np.int32)
    dst = np.array([7, 3, 0, 0], np.int32)
    g = Graph.from_edges(src, dst)
    out = roundtrip(g)
    assert out.n_nodes == g.n_nodes and out.n_edges == g.n_edges
    np.testing.assert_array_equal(np.asarray(out.node_ids),
                                  np.asarray(g.node_ids))
    s1, d1 = g.out_edges()
    s2, d2 = out.out_edges()
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d1))


# ---------------------------------------------------------------------------
# typed error frames
# ---------------------------------------------------------------------------


def test_rejected_error_keeps_retry_after():
    e = RejectedError("session 'u1' is at its in-flight quota (8)", 0.125)
    out = error_from_wire(roundtrip(error_to_wire(e),
                                    ftype=wire.FrameType.ERROR))
    assert isinstance(out, RejectedError)
    assert out.retry_after == pytest.approx(0.125)
    assert "quota" in str(out)


def test_deadline_expired_roundtrip():
    out = error_from_wire(roundtrip(error_to_wire(
        DeadlineExpired("spent its deadline in the queue"))))
    assert isinstance(out, DeadlineExpired)


def test_service_and_key_errors_roundtrip():
    out = error_from_wire(roundtrip(error_to_wire(
        ServiceError("unknown op 'frobnicate'"))))
    assert isinstance(out, ServiceError) and not isinstance(
        out, (RejectedError, DeadlineExpired))
    key = error_from_wire(roundtrip(error_to_wire(KeyError("posts"))))
    assert isinstance(key, KeyError) and key.args == ("posts",)
    # messages containing quotes round-trip verbatim (str(KeyError) is the
    # repr of its arg; the wire ships the arg itself)
    msg = "no workspace object 'x'; have ['g']"
    key2 = error_from_wire(roundtrip(error_to_wire(KeyError(msg))))
    assert key2.args == (msg,)


def test_unknown_exception_becomes_remote_error():
    out = error_from_wire(roundtrip(error_to_wire(
        ZeroDivisionError("division by zero"))))
    assert isinstance(out, RemoteError)
    assert "ZeroDivisionError" in str(out)


# ---------------------------------------------------------------------------
# framing: truncation, bad magic, unknown version, size bound
# ---------------------------------------------------------------------------


def full_frame(v=("x", [1, 2.5])):
    return b"".join(bytes(c) for c in wire.encode_frame(1, 3, v))


def test_truncated_frame_rejected():
    buf = full_frame(np.arange(100, dtype=np.float64))
    for cut in (4, 15, 17, len(buf) - 1):
        with pytest.raises(wire.WireError, match="truncated|short header"):
            wire.decode_frame(buf[:cut])


def test_trailing_garbage_rejected():
    with pytest.raises(wire.WireError):
        wire.decode_frame(full_frame() + b"\x00")


def test_bad_magic_rejected():
    buf = bytearray(full_frame())
    buf[0] ^= 0xFF
    with pytest.raises(wire.WireError, match="magic"):
        wire.decode_frame(bytes(buf))


def test_unknown_protocol_version_rejected():
    buf = bytearray(full_frame())
    buf[2] = wire.PROTOCOL_VERSION + 1    # version byte follows the magic
    with pytest.raises(wire.WireError, match="protocol version"):
        wire.decode_frame(bytes(buf))


def test_unknown_value_tag_rejected():
    head = struct.pack("!HBBQI", 0x5257, wire.PROTOCOL_VERSION, 1, 0, 1)
    with pytest.raises(wire.WireError, match="unknown value tag"):
        wire.decode_frame(head + b"\x7f")


# ---------------------------------------------------------------------------
# pack_object / unpack_object: provenance across the wire
# ---------------------------------------------------------------------------


def test_pack_object_ships_and_adopts_provenance():
    from repro.core import relational as R
    t = Table.from_columns({"x": INT}, {"x": [5, 1, 3]})
    ordered = R.order(t, "x")
    payload = roundtrip(wire.pack_object(ordered))
    out = wire.unpack_object(payload)
    assert out.to_pydict() == ordered.to_pydict()
    ops = [r.op for r in P.records_of(out)]
    assert ops == [r.op for r in P.records_of(ordered)]
    # the adopted copy answers to the producer's version token
    assert P.peek_version(out) == P.version_of(ordered)


def test_pack_object_fresh_root_ships_tokenless():
    t = Table.from_columns({"x": INT}, {"x": [1]})
    payload = wire.pack_object(t)
    assert payload["token"] is None       # receiver assigns the version
    assert payload["records"] == []


def test_pack_object_tuple_per_element_chains():
    from repro.core import relational as R
    t = Table.from_columns({"x": INT}, {"x": [2, 1]})
    a, b = R.order(t, "x"), t
    payload = roundtrip(wire.pack_object((a, b)))
    out = wire.unpack_object(payload)
    assert isinstance(out, tuple) and len(out) == 2
    assert [r.op for r in P.records_of(out[0])] == \
        [r.op for r in P.records_of(a)]


def test_opaque_params_survive_records_wire():
    rec = P.ProvRecord(op="x", inputs=(("t", "t1"),),
                       params=(("w", P.Opaque("array(9999,):f32")),),
                       outputs=("t2",), meta=())
    data = roundtrip(P.records_to_wire([rec]))
    back = P.records_from_wire(data)
    assert isinstance(back[0].params[0][1], P.Opaque)
    assert back[0].params[0][1].desc == "array(9999,):f32"
