"""Deterministic stand-ins for the ``hypothesis`` API used by the suite.

When hypothesis is not installed, ``@given(strategy, ...)`` replays the test
body over a fixed set of seeded random examples (no shrinking, same coverage
shape), so property tests still run instead of aborting collection.
"""

import numpy as np

N_EXAMPLES = 20


class _Integers:
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def example(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Tuples:
    def __init__(self, elems):
        self.elems = elems

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elems)


class _Lists:
    def __init__(self, elem, min_size, max_size):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng):
        k = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elem.example(rng) for _ in range(k)]


class strategies:
    @staticmethod
    def integers(lo, hi):
        return _Integers(lo, hi)

    @staticmethod
    def tuples(*elems):
        return _Tuples(elems)

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        return _Lists(elem, min_size, max_size)


def settings(**_kw):
    return lambda fn: fn


def given(*strats):
    def deco(fn):
        def wrapper():
            rng = np.random.default_rng(1234)
            for _ in range(N_EXAMPLES):
                fn(*(s.example(rng) for s in strats))

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
