"""Unified traversal engine: backend parity, plan caching, batched traversal.

Covers the plan/engine layering (core/plan.py + core/engine.py): the three
backends must agree bit-for-bit-ish on real algorithms over RMAT graphs, a
second call on the same Graph must reuse the cached plan (zero re-sorting),
and functional updates must invalidate by construction.
"""

import inspect

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import algorithms as A
from repro.core import engine
from repro.core.graph import EdgeDelta, Graph
from repro.data.rmat import rmat_edges

BACKENDS = ["xla", "pallas", "bsr", "frontier"]


def rmat_graph(scale=6, edge_factor=4, seed=0):
    s, d = rmat_edges(scale, edge_factor=edge_factor, seed=seed)
    return Graph.from_edges(s, d)


# ---------------------------------------------------------------------------
# primitive parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_pull_sum_matches_oracle(backend):
    g = rmat_graph(seed=1)
    plan = g.plan()
    x = jnp.arange(g.n_nodes, dtype=jnp.float32) + 1.0
    got = np.asarray(engine.pull(plan, x, "sum", backend=backend,
                                 interpret=True))
    s, d = (np.asarray(a) for a in g.in_edges())
    want = np.zeros(g.n_nodes, np.float32)
    np.add.at(want, d, np.asarray(x)[s])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_push_sum_matches_oracle(backend):
    g = rmat_graph(seed=2)
    plan = g.plan()
    x = jnp.arange(g.n_nodes, dtype=jnp.float32) + 1.0
    got = np.asarray(engine.push(plan, x, "sum", backend=backend,
                                 interpret=True))
    s, d = (np.asarray(a) for a in g.out_edges())
    want = np.zeros(g.n_nodes, np.float32)
    np.add.at(want, s, np.asarray(x)[d])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pull_sum_integer_dtype_neutral():
    # f32-only kernel paths must fall back so backend choice never changes
    # the result dtype or integer exactness
    g = rmat_graph(seed=41)
    plan = g.plan()
    x = jnp.ones((g.n_nodes,), jnp.int32)
    ref = engine.pull(plan, x, "sum", backend="xla")
    for be in ("pallas", "bsr"):
        got = engine.pull(plan, x, "sum", backend=be, interpret=True)
        assert got.dtype == ref.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_pull_min_max_all_backends_agree():
    g = rmat_graph(seed=3)
    plan = g.plan()
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=g.n_nodes).astype(np.float32))
    ref = np.asarray(engine.pull(plan, x, "min", backend="xla"))
    for be in ("pallas", "bsr"):   # non-sum combines fall back, same result
        np.testing.assert_array_equal(
            np.asarray(engine.pull(plan, x, "min", backend=be,
                                   interpret=True)), ref)


# ---------------------------------------------------------------------------
# algorithm parity across backends (RMAT graphs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_pagerank_backend_parity(backend):
    g = rmat_graph(scale=7, edge_factor=4, seed=5)
    ref = np.asarray(A.pagerank(g, n_iter=8, backend="xla"))
    got = np.asarray(A.pagerank(g, n_iter=8, backend=backend, interpret=True))
    np.testing.assert_allclose(got, ref, atol=1e-5)
    assert abs(got.sum() - 1.0) < 1e-4


@pytest.mark.parametrize("backend", BACKENDS)
def test_connected_components_backend_parity(backend):
    g = rmat_graph(scale=6, edge_factor=1, seed=7)   # sparse -> many comps
    ref = np.asarray(A.connected_components(g, backend="xla"))
    got = np.asarray(A.connected_components(g, backend=backend,
                                            interpret=True))
    np.testing.assert_array_equal(got, ref)


def test_triangle_count_backend_parity():
    u = rmat_graph(scale=6, edge_factor=4, seed=11).to_undirected()
    ref = A.triangle_count(u)
    assert A.triangle_count(u, backend="bsr", interpret=True) == ref


@pytest.mark.parametrize("backend", BACKENDS)
def test_hits_backend_parity(backend):
    g = rmat_graph(seed=13)
    hub_ref, auth_ref = (np.asarray(x) for x in A.hits(g, n_iter=10,
                                                       backend="xla"))
    hub, auth = (np.asarray(x) for x in A.hits(g, n_iter=10, backend=backend,
                                               interpret=True))
    np.testing.assert_allclose(hub, hub_ref, atol=1e-5)
    np.testing.assert_allclose(auth, auth_ref, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_k_core_backend_parity(backend):
    g = rmat_graph(seed=17)
    ref = np.asarray(A.k_core(g, 3, backend="xla"))
    got = np.asarray(A.k_core(g, 3, backend=backend, interpret=True))
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# plan caching: repeated calls pay the sort cost once
# ---------------------------------------------------------------------------


def test_plan_is_memoized_by_identity():
    g = rmat_graph(seed=19)
    assert g.plan() is g.plan()
    ex = engine.get_exec(g.plan(), "xla")
    assert engine.get_exec(g.plan(), "xla") is ex


def test_repeated_pagerank_does_zero_resorting(monkeypatch):
    g = rmat_graph(seed=23)
    first = np.asarray(A.pagerank(g, n_iter=5))

    def boom(*a, **kw):  # any re-derivation of edge arrays would call these
        raise AssertionError("plan cache miss: graph re-sorted on 2nd call")

    monkeypatch.setattr(Graph, "in_edges", boom)
    monkeypatch.setattr(Graph, "out_edges", boom)
    monkeypatch.setattr(Graph, "out_degrees", boom)
    second = np.asarray(A.pagerank(g, n_iter=5))
    np.testing.assert_array_equal(first, second)


def test_plan_caches_undirected_and_oriented():
    g = rmat_graph(seed=29)
    plan = g.plan()
    assert plan.undirected() is plan.undirected()
    assert plan.oriented() is plan.oriented()
    assert plan.bsr() is plan.bsr()
    assert plan.bsr_t() is plan.bsr_t()
    assert plan.chunk_layout_in() is plan.chunk_layout_in()


def test_bsr_push_uses_transpose_tiles(monkeypatch):
    """push on "bsr" must take the SpMV path, not fall back to XLA."""
    g = rmat_graph(seed=43)
    plan = g.plan()
    ex = engine.get_exec(plan, "bsr", interpret=True)
    x = jnp.arange(g.n_nodes, dtype=jnp.float32)
    want = np.asarray(engine.push(plan, x, "sum", backend="xla"))

    def boom(self, edge_vals, combine="sum"):
        raise AssertionError("bsr push fell back to the XLA reduction")

    monkeypatch.setattr(engine.XlaExec, "reduce_out", boom)
    got = np.asarray(ex.push(x, "sum"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_functional_updates_invalidate_plan():
    g = Graph.from_edges([1, 2], [2, 3])
    p = g.plan()
    g2 = g.add_edges([3], [1])
    assert g2.plan() is not p
    # results reflect the new edge (3->1 closes the cycle)
    lab = np.asarray(A.connected_components(g2))
    assert len(set(lab.tolist())) == 1
    g3 = g2.delete_edges([3], [1])
    assert g3.plan() is not g2.plan()
    assert g3.n_edges == 2
    g.invalidate_plan()
    assert g.plan() is not p


# ---------------------------------------------------------------------------
# plan-cache semantics under deltas
# ---------------------------------------------------------------------------


def _known_id_delta(g, k=6, seed=0):
    r = np.random.default_rng(seed)
    ids = np.asarray(g.node_ids)[:g.n_nodes]
    return EdgeDelta.inserts(ids[r.integers(0, g.n_nodes, k)],
                             ids[r.integers(0, g.n_nodes, k)])


def test_delta_child_plan_patched_without_resorting(monkeypatch):
    """The child's plan derives from the parent's: memoized per child,
    linked to the parent plan, and built with zero edge re-derivation."""
    g = rmat_graph(seed=83)
    p = g.plan()
    child = g.apply_delta(_known_id_delta(g))
    assert child._delta is not None

    def boom(*a, **kw):
        raise AssertionError("patched plan re-derived edge arrays")

    monkeypatch.setattr(Graph, "in_edges", boom)
    monkeypatch.setattr(Graph, "out_edges", boom)
    cp = child.plan()
    assert child.plan() is cp                     # memoized per child
    assert cp._parent is p                        # lineage points at parent
    assert cp.dirty_vertices is not None and len(cp.dirty_vertices) > 0


def test_delta_leaves_parent_plan_untouched():
    g = rmat_graph(seed=89)
    p = g.plan()
    in_src0 = np.asarray(p.in_src).copy()
    child = g.apply_delta(_known_id_delta(g))
    child.plan()
    assert g.plan() is p                          # identity preserved
    assert g.n_edges == p.n_edges                 # parent graph unchanged
    np.testing.assert_array_equal(np.asarray(p.in_src), in_src0)


def test_patched_plan_matches_rederived():
    """Patched CSR arrays and degrees are bit-identical to a plan derived
    from scratch over the same edge set (insert-only and mixed)."""
    g = rmat_graph(seed=97)
    ids = np.asarray(g.node_ids)[:g.n_nodes]
    es, ed = (np.asarray(x) for x in g.out_edges())
    ins = _known_id_delta(g, seed=1)
    mixed = EdgeDelta(ins.add_src, ins.add_dst,
                      ids[es[:3]], ids[ed[:3]])
    for delta in (ins, mixed):
        child = g.apply_delta(delta)
        assert child._delta is not None
        cp = child.plan()
        ref = Graph.from_dense_edges(*child.out_edges(), child.n_nodes).plan()
        assert cp.n_edges == ref.n_edges
        for fld in ("in_src", "in_dst", "out_src", "out_dst",
                    "out_deg", "in_deg", "dangling"):
            np.testing.assert_array_equal(
                np.asarray(getattr(cp, fld))[:cp.n_edges],
                np.asarray(getattr(ref, fld))[:cp.n_edges],
                err_msg=f"{fld} (insert_only={delta.insert_only})")


def test_second_update_gets_its_own_plan():
    """A second delta on the child yields a fresh plan chained to the
    child's — earlier plans stay valid and unmodified."""
    g = rmat_graph(seed=101)
    c1 = g.apply_delta(_known_id_delta(g, seed=2))
    p1 = c1.plan()
    c2 = c1.apply_delta(_known_id_delta(g, seed=3))
    p2 = c2.plan()
    assert p2 is not p1 and c1.plan() is p1
    assert p2._parent is p1
    # results through the chained patch match a from-scratch derivation
    fresh = Graph.from_dense_edges(*c2.out_edges(), c2.n_nodes)
    np.testing.assert_array_equal(
        np.asarray(A.connected_components(c2)),
        np.asarray(A.connected_components(fresh)))


# ---------------------------------------------------------------------------
# batched multi-source traversal (vmap over the engine)
# ---------------------------------------------------------------------------


def test_batched_bfs_matches_single_source():
    g = rmat_graph(seed=31)
    sources = jnp.asarray([0, 1, 5], dtype=jnp.int32)
    batched = np.asarray(A.bfs(g, sources))
    assert batched.shape == (3, g.n_nodes)
    for i, s in enumerate([0, 1, 5]):
        np.testing.assert_array_equal(batched[i], np.asarray(A.bfs(g, s)))


def test_batched_sssp_weighted():
    g = Graph.from_edges([0, 1, 0], [1, 2, 2])
    # in-edge order (sorted by dst, then src): (0->1), (0->2), (1->2)
    w = jnp.asarray([1.0, 5.0, 1.0])
    d = np.asarray(A.sssp(g, jnp.asarray([0], dtype=jnp.int32), weights=w))
    assert d.shape == (1, 3)
    assert d[0, 2] == pytest.approx(2.0)   # 0->1->2 beats the heavy 0->2


# ---------------------------------------------------------------------------
# fixpoint driver + layering invariants
# ---------------------------------------------------------------------------


def _collatz_ish_body(ex, v):
    return jnp.minimum(v, ex.pull(v, "min"))


def test_fixpoint_max_iter_caps_rounds():
    g = Graph.from_edges(list(range(9)), list(range(1, 10)))  # path graph
    plan = g.plan()
    v0 = jnp.arange(g.n_nodes, dtype=jnp.int32)
    one = engine.fixpoint(plan, _collatz_ish_body, v0, max_iter=1)
    full = engine.fixpoint(plan, _collatz_ish_body, v0)
    assert int(np.asarray(one).max()) > 0       # capped: not yet converged
    assert np.asarray(full).max() == 0          # converged: all labels 0


def test_fixpoint_terminates_on_nan_state():
    # NaN != NaN must not spin the until-unchanged loop forever
    g = Graph.from_edges([0, 1], [1, 0])
    d = np.asarray(A.sssp(g, 0, weights=jnp.asarray([jnp.nan, 1.0])))
    assert d.shape == (2,)          # terminating at all is the assertion


def test_triangle_count_rejects_unknown_backend():
    u = Graph.from_edges([0, 1, 2], [1, 2, 0]).to_undirected()
    with pytest.raises(ValueError):
        A.triangle_count(u, backend="pallas")


def test_algorithms_route_through_engine_only():
    """Acceptance: no direct jax.ops.segment_* call sites in algorithms.py."""
    src = inspect.getsource(A)
    assert "jax.ops.segment_" not in src
    assert "segment_sum(" not in src


def test_select_backend_override_and_validation():
    g = rmat_graph(seed=37)
    assert engine.select_backend(g.plan(), "bsr") == "bsr"
    assert engine.select_backend(g.plan()) in engine.BACKENDS
    with pytest.raises(ValueError):
        engine.select_backend(g.plan(), "tpu_magic")


def test_select_backend_op_aware_fallback():
    """Unsupported op/backend combinations resolve to "xla", never fail."""
    plan = rmat_graph(seed=47).plan()
    for op in ("bfs", "sssp", "connected_components", "label_propagation"):
        assert engine.select_backend(plan, "frontier", op=op) == "frontier"
    for op in ("pagerank", "hits", "k_core", "triangle_count"):
        assert engine.select_backend(plan, "frontier", op=op) == "xla"
    # op-awareness never touches backends with generic primitives
    assert engine.select_backend(plan, "bsr", op="pagerank") == "bsr"
    assert engine.select_backend(plan, "xla", op="anything") == "xla"


# ---------------------------------------------------------------------------
# frontier backend: plan-cache structure + sparse/dense agreement
# ---------------------------------------------------------------------------


def test_frontier_csr_is_memoized_on_plan():
    plan = rmat_graph(seed=53).plan()
    assert plan.csr_out() is plan.csr_out()
    assert plan.csr_in() is plan.csr_in()
    assert plan.in_perm_out() is plan.in_perm_out()
    ex = engine.get_exec(plan, "frontier")
    assert engine.get_exec(plan, "frontier") is ex


def test_frontier_csr_invalidated_by_functional_update():
    g = Graph.from_edges([0, 1, 2], [1, 2, 3])
    ptr0, _, _ = g.plan().csr_out()
    g2 = g.add_edges([3], [0])
    assert g2.plan() is not g.plan()
    ptr2, _, _ = g2.plan().csr_out()
    assert ptr2 is not ptr0
    assert int(ptr2[-1]) == 4      # the fresh plan sees the new edge
    # and results computed through the frontier path reflect it
    assert np.asarray(A.bfs(g2, 3, backend="frontier"))[0] == 1


def test_frontier_weight_permutation_rekeys_in_order_weights():
    g = Graph.from_edges([0, 1, 0], [1, 2, 2])
    # in-edge order (sorted by dst, then src): (0->1), (0->2), (1->2)
    w = jnp.asarray([1.0, 5.0, 1.0])
    d = np.asarray(A.sssp(g, 0, weights=w, backend="frontier"))
    assert d[2] == pytest.approx(2.0)   # 0->1->2 beats the heavy 0->2


@pytest.mark.parametrize("seed,edge_factor", [(61, 1), (67, 4), (71, 8)])
def test_frontier_bfs_sssp_match_dense(seed, edge_factor):
    """Sparse push + direction-optimized dense pull == dense relaxation."""
    g = rmat_graph(scale=7, edge_factor=edge_factor, seed=seed)
    for src in (0, 3):
        np.testing.assert_array_equal(
            np.asarray(A.bfs(g, src, backend="frontier")),
            np.asarray(A.bfs(g, src, backend="xla")))
    w = jnp.asarray(np.random.default_rng(seed).uniform(
        0.1, 2.0, g.n_edges).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(A.sssp(g, 1, weights=w, backend="frontier")),
        np.asarray(A.sssp(g, 1, weights=w, backend="xla")))


def test_frontier_batched_multi_source_matches_single():
    g = rmat_graph(seed=73)
    sources = jnp.asarray([0, 2, 9], dtype=jnp.int32)
    batched = np.asarray(A.bfs(g, sources, backend="frontier"))
    assert batched.shape == (3, g.n_nodes)
    for i, s in enumerate([0, 2, 9]):
        np.testing.assert_array_equal(
            batched[i], np.asarray(A.bfs(g, s, backend="frontier")))


def test_capped_n_iter_matches_per_row_runs():
    g = rmat_graph(seed=79)
    sources = jnp.asarray([0, 4, 8], dtype=jnp.int32)
    caps = np.asarray([1, 3, 50], np.int32)
    for backend in ("xla", "frontier"):
        rows = np.asarray(A.bfs(g, sources, n_iter=caps, backend=backend))
        for i, (s, c) in enumerate(zip([0, 4, 8], caps)):
            np.testing.assert_array_equal(
                rows[i], np.asarray(A.bfs(g, int(s), n_iter=int(c),
                                          backend=backend)),
                err_msg=f"{backend} row {i}")
