"""End-to-end behaviour tests: the paper's workflow loop + training loop +
serving engine + dry-run machinery on a single device."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.table import Table, INT, STR
from repro.core import relational as R
from repro.core import algorithms as A
from repro.core.convert import to_graph, table_from_map, graph_to_edge_table


def test_stackoverflow_workflow_end_to_end():
    """Paper §4.1: select -> join -> ToGraph -> PageRank -> table."""
    P = Table.from_columns(
        {"PostId": INT, "Type": STR, "Tag": STR, "UserId": INT,
         "AnswerId": INT},
        {"PostId": [0, 1, 2, 3, 4, 5],
         "Type": ["question", "answer", "question", "answer", "question",
                  "answer"],
         "Tag": ["Java", "Java", "Java", "Java", "Python", "Python"],
         "UserId": [10, 20, 30, 20, 40, 50],
         "AnswerId": [1, -1, 3, -1, 5, -1]})
    JP = R.select(P, "Tag", "==", "Java")
    Q = R.select(JP, "Type", "==", "question")
    Ans = R.select(JP, "Type", "==", "answer")
    QA = R.join(Q, Ans, "AnswerId", "PostId")
    assert len(QA) == 2
    G = to_graph(QA, "UserId_1", "UserId_2")
    assert G.n_nodes == 3 and G.n_edges == 2   # 10->20, 30->20
    PR = A.pagerank(G, n_iter=20)
    S = table_from_map(G, PR, "User", "Scr")
    assert S.to_pydict()["User"][0] == 20      # the answerer wins


def test_training_decreases_loss_and_resumes(tmp_path):
    """Few steps of the real train step; checkpoint restart is exact."""
    from repro.configs.base import get_config, reduced
    from repro.train.step import init_train_state, make_train_step
    from repro.train.optimizer import OptHyper
    from repro.checkpoint.store import save_checkpoint, load_checkpoint
    from repro.data.pipeline import SyntheticLM

    cfg = reduced(get_config("qwen2.5-3b"))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, OptHyper(lr=1e-3), attn_chunk=32))
    src = SyntheticLM(cfg.vocab_size, batch=4, seq_len=32, seed=0)

    losses = []
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        params, opt, m = step(params, opt, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
        if i == 3:
            save_checkpoint(str(tmp_path), 4, {"p": params, "o": opt})
    assert losses[-1] < losses[0]

    # resume from step 4 and replay: states must match the original run
    _, state, _ = load_checkpoint(str(tmp_path),
                                  {"p": params, "o": opt})
    p2, o2 = state["p"], state["o"]
    for i in range(4, 8):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        p2, o2, m2 = step(p2, o2, batch, jnp.int32(i))
    final_delta = max(float(jnp.abs(a - b).max()) for a, b in
                      zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert final_delta < 1e-5, "restart is not bit-stable"


def test_serving_engine_greedy_decode():
    from repro.configs.base import get_config, reduced
    from repro.models.transformer import init_params
    from repro.serve.engine import Engine, ServeConfig

    cfg = reduced(get_config("qwen2.5-3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(batch=2, max_seq=48))
    outs = eng.generate([[1, 2, 3], [4, 5]], max_new_tokens=5)
    assert len(outs) == 2
    assert len(outs[0]) == 3 + 5 and len(outs[1]) == 2 + 5
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


@pytest.mark.slow
def test_dryrun_cell_machinery_subprocess():
    """A real (small-arch) dry-run cell lowers + compiles on 512 devices."""
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.dryrun import run_cell\n"
        "r = run_cell('xlstm-350m', 'decode_32k', False)\n"
        "assert r['status'] == 'ok', r\n"
        "assert r['flops_per_device'] > 0\n"
        "assert r['n_chips'] == 256\n"
        "print('DRYRUN-OK')\n")
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-W", "ignore", "-c", script],
                          capture_output=True, text=True, timeout=540,
                          env=env, cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert "DRYRUN-OK" in proc.stdout, proc.stderr[-2000:]


def test_graph_corpus_walks_are_edges():
    from repro.core.graph import Graph
    from repro.data.graph_corpus import RandomWalkCorpus
    g = Graph.from_edges([0, 1, 2, 3], [1, 2, 3, 0])  # cycle
    c = RandomWalkCorpus(g, batch=3, seq_len=8, seed=0)
    b = c.batch_at(0)
    toks, tgts = b["tokens"], b["targets"]
    # on a cycle, every transition must follow the unique out-edge
    assert np.array_equal((toks + 1) % 4, tgts)
