import os

# Tests run on the single real CPU device; only dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running end-to-end test (deselect with -m 'not slow')")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_digraph(rng, n=60, m=300, seed=None):
    """(src, dst) dense-id edge arrays without self loops, deduped."""
    r = np.random.default_rng(seed) if seed is not None else rng
    s = r.integers(0, n, m)
    d = r.integers(0, n, m)
    keep = s != d
    pairs = sorted(set(zip(s[keep].tolist(), d[keep].tolist())))
    return (np.asarray([p[0] for p in pairs], np.int32),
            np.asarray([p[1] for p in pairs], np.int32))
