"""Per-arch reduced-config smoke tests (deliverable (f)) + model invariants.

Each assigned architecture instantiates a REDUCED config of the same family
and runs one forward + one train step on CPU, asserting output shapes and
no NaNs.  Full configs are exercised only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs, reduced, runnable_shapes, SHAPES
from repro.models import transformer as T
from repro.train.step import make_train_step, init_train_state
from repro.train.optimizer import OptHyper

ARCHS = [a for a in list_archs() if a != "ringo-graph"]
KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def batch_for(cfg, key=KEY, b=B, s=S):
    out = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
           "targets": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = jax.random.normal(
            key, (b, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    if cfg.n_patches:
        out["patch_embeds"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, KEY)
    batch = batch_for(cfg)
    logits, aux = T.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    params0, opt_state = init_train_state(cfg, KEY)
    step = make_train_step(cfg, OptHyper(lr=1e-3), attn_chunk=S)
    new_params, new_opt, metrics = step(params0, opt_state, batch,
                                        jnp.int32(0))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b_.astype(jnp.float32)).sum())
                for a, b_ in zip(jax.tree.leaves(new_params),
                                 jax.tree.leaves(params0)))
    assert delta > 0, f"{arch}: optimizer produced no update"


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen3-moe-235b-a22b",
                                  "jamba-1.5-large-398b", "xlstm-350m",
                                  "whisper-small"])
def test_decode_matches_forward(arch):
    """Greedy decode after prefill == teacher-forced forward (no MoE drops)."""
    cfg = reduced(get_config(arch), capacity_factor=16.0)
    params = T.init_params(cfg, KEY)
    batch = batch_for(cfg)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = T._encoder_forward(params, cfg, batch["enc_embeds"])
    full_logits, _ = T.forward(params, cfg, batch)
    batch_m1 = dict(batch)
    batch_m1["tokens"] = batch["tokens"][:, :-1]
    _, cache = T.prefill(params, cfg, batch_m1, S + 4)
    pos = jnp.int32(S - 1 + (cfg.n_patches or 0))
    dec_logits, _ = T.decode_step(params, cfg, cache,
                                  batch["tokens"][:, -1:], pos,
                                  enc_out=enc_out)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-4, rtol=2e-3)


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention
    rng = np.random.default_rng(0)
    b, s, h, d = 2, 64, 3, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    for skip in (False, True):
        out = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16,
                              skip_upper_triangle=skip)
        # naive reference
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (d ** 0.5)
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_flash_attention_non_divisible_seq():
    from repro.models.attention import flash_attention
    q = jnp.ones((1, 24, 1, 4))   # 24 % 16 != 0 -> chunk auto-fits
    out = flash_attention(q, q, q, causal=True, q_chunk=16, k_chunk=16)
    assert out.shape == (1, 24, 1, 4)


def test_moe_combine_weights_sum_to_one():
    """Router weights renormalize over the selected top-k."""
    from repro.models import moe as M
    cfg = reduced(get_config("qwen3-moe-235b-a22b"), capacity_factor=16.0)
    p = M.moe_init(KEY, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.act,
                   jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    out, aux = M.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and uniform-ish routing, output stays finite and sane."""
    from repro.models import moe as M
    cfg = reduced(get_config("qwen3-moe-235b-a22b"), capacity_factor=1.0)
    p = M.moe_init(KEY, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.act,
                   jnp.float32)
    x = jax.random.normal(KEY, (4, 16, cfg.d_model))
    out, _ = M.moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(out).all())


def test_mamba_decode_matches_train_tail():
    """Mamba one-step decode continues the train-mode scan exactly."""
    from repro.models import ssm as S_
    cfg = reduced(get_config("jamba-1.5-large-398b"))
    p = S_.mamba_init(KEY, cfg.d_model, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model))
    y_full = S_.mamba_train(p, x, cfg, chunk=4)
    # replay decode over the sequence
    cache = S_.mamba_init_cache(2, cfg.d_model, cfg, jnp.float32)
    ys = []
    for t in range(12):
        y1, cache = S_.mamba_decode(p, x[:, t:t + 1], cfg, cache)
        ys.append(y1)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               atol=1e-4, rtol=1e-3)


def test_mlstm_decode_matches_train_tail():
    from repro.models import xlstm as X
    cfg = reduced(get_config("xlstm-350m"))
    p = X.mlstm_init(KEY, cfg.d_model, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    y_full = X.mlstm_train(p, x, cfg, chunk=4)
    cache = X.mlstm_init_cache(2, cfg.d_model, cfg, jnp.float32)
    ys = []
    for t in range(8):
        y1, cache = X.mlstm_decode(p, x[:, t:t + 1], cfg, cache)
        ys.append(y1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)


def test_slstm_decode_matches_train_tail():
    from repro.models import xlstm as X
    cfg = reduced(get_config("xlstm-350m"))
    p = X.slstm_init(KEY, cfg.d_model, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    y_full = X.slstm_train(p, x, cfg)
    cache = X.slstm_init_cache(2, cfg.d_model, cfg, jnp.float32)
    ys = []
    for t in range(8):
        y1, cache = X.slstm_decode(p, x[:, t:t + 1], cfg, cache)
        ys.append(y1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)


def test_runnable_shapes_policy():
    """long_500k only for sub-quadratic families (assignment spec)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        shapes = runnable_shapes(cfg)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_param_count_sane():
    """Config param counts are in the advertised ballpark."""
    expect = {
        "qwen2.5-3b": (2.5e9, 4.5e9),
        "starcoder2-15b": (13e9, 18e9),
        "mistral-nemo-12b": (10e9, 15e9),
        "grok-1-314b": (2.6e11, 3.6e11),
        "qwen3-moe-235b-a22b": (1.9e11, 2.8e11),
        "jamba-1.5-large-398b": (3.1e11, 4.4e11),
        "xlstm-350m": (2.4e8, 5.5e8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"
