"""Provenance layer (core/provenance.py): recording, export, replay.

Covers the Ringo §2.1/§4 contract: every tracked op appends a ProvRecord to
its outputs, chains merge across multi-input ops, export_script emits a
standalone program that rebuilds the object bit-for-bit, and replay
re-executes a chain against fresh roots.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import algorithms as A
from repro.core import provenance as P
from repro.core import relational as R
from repro.core.convert import table_from_map, to_graph
from repro.core.graph import Graph
from repro.core.table import INT, STR, Table


def posts_table(n=40, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_columns(
        {"id": INT, "ref": INT, "tag": STR},
        {"id": list(range(n)),
         "ref": rng.integers(0, n, n).tolist(),
         "tag": [("java" if i % 3 else "py") for i in range(n)]})


# ---------------------------------------------------------------------------
# recording
# ---------------------------------------------------------------------------


def test_ops_append_records():
    t = posts_table()
    s = R.select(t, "tag", "==", "java")
    recs = P.records_of(s)
    assert [r.op for r in recs] == ["relational.select"]
    assert recs[0].inputs == (("t", P.version_of(t)),)
    assert dict(recs[0].params)["value"] == "java"
    assert recs[0].outputs == (P.version_of(s),)


def test_chains_merge_across_two_input_ops():
    t = posts_table()
    a = R.select(t, "tag", "==", "java")
    b = R.select(t, "tag", "==", "py")
    j = R.join(a, b, "ref", "id")
    ops = [r.op for r in P.records_of(j)]
    assert ops.count("relational.select") == 2
    assert ops[-1] == "relational.join"


def test_nested_tracked_calls_record_once():
    t = posts_table()
    u = R.unique(t, "tag")          # unique is implemented via group_by
    assert [r.op for r in P.records_of(u)] == ["relational.unique"]
    s = R.select_inplace(t, "tag", "==", "java")   # implemented via select
    assert [r.op for r in P.records_of(s)] == ["relational.select_inplace"]


def test_version_tokens_are_stable_and_fresh_per_object():
    g = Graph.from_edges([0, 1], [1, 2])
    assert g.version == g.version
    g2 = g.add_edges([2], [0])
    assert g2.version != g.version
    assert [r.op for r in P.records_of(g2)] == ["graph.add_edges"]


def test_algorithm_results_carry_provenance():
    g = Graph.from_edges([0, 1, 2], [1, 2, 0])
    pr = A.pagerank(g, n_iter=3)
    recs = P.records_of(pr)
    assert recs[-1].op == "algorithms.pagerank"
    assert dict(recs[-1].params)["n_iter"] == 3


def test_tuple_outputs_get_distinct_versions():
    g = Graph.from_edges([0, 1, 2], [1, 2, 0])
    hub, auth = A.hits(g, n_iter=3)
    rh, ra = P.records_of(hub)[-1], P.records_of(auth)[-1]
    assert rh == ra and len(rh.outputs) == 2
    assert P.version_of(hub) != P.version_of(auth)
    assert set(rh.outputs) == {P.version_of(hub), P.version_of(auth)}


# ---------------------------------------------------------------------------
# export_script → exec → identical results (the §4 demo feature)
# ---------------------------------------------------------------------------


def _expert_pipeline(t):
    qa = R.join(R.select(t, "tag", "==", "java"), t, "ref", "id")
    g = to_graph(qa, "id_1", "id_2")
    pr = A.pagerank(g, n_iter=10)
    return g, table_from_map(g, pr, "node", "score")


def test_export_script_round_trips_identically():
    t = posts_table()
    _, scores = _expert_pipeline(t)
    script = P.export_script(scores)
    ns = {}
    exec(compile(script, "<prov-export>", "exec"), ns)
    rebuilt = ns["rebuild"]()
    assert rebuilt.schema.names == scores.schema.names
    np.testing.assert_array_equal(rebuilt.column_np("node"),
                                  scores.column_np("node"))
    np.testing.assert_array_equal(rebuilt.column_np("score"),
                                  scores.column_np("score"))


def test_export_script_with_root_args():
    t = posts_table()
    s = R.select(t, "tag", "==", "py")
    script = P.export_script(s, embed_roots=False)
    root = P.roots_of(P.records_of(s))[0]
    assert f"def rebuild({root}):" in script
    ns = {}
    exec(compile(script, "<prov-export>", "exec"), ns)
    rebuilt = ns["rebuild"](t)
    np.testing.assert_array_equal(rebuilt.column_np("id"), s.column_np("id"))


def test_export_refuses_rootless_objects():
    t = posts_table()
    with pytest.raises(P.ProvenanceError):
        P.export_script(t)          # a root has no records


# ---------------------------------------------------------------------------
# replay against fresh inputs
# ---------------------------------------------------------------------------


def test_replay_against_fresh_inputs():
    t = posts_table(seed=0)
    _, scores = _expert_pipeline(t)
    recs = P.records_of(scores)
    (root,) = P.roots_of(recs)
    # same input -> identical result
    same = P.replay(recs, {root: t})
    np.testing.assert_array_equal(same.column_np("score"),
                                  scores.column_np("score"))
    # different input -> the result of running the pipeline on it
    t2 = posts_table(seed=7)
    got = P.replay(recs, {root: t2})
    _, want = _expert_pipeline(t2)
    np.testing.assert_array_equal(got.column_np("score"),
                                  want.column_np("score"))


def test_replay_missing_root_raises():
    t = posts_table()
    s = R.select(t, "tag", "==", "java")
    with pytest.raises(P.ProvenanceError):
        P.replay(P.records_of(s), {})


# ---------------------------------------------------------------------------
# canonicalization corner cases
# ---------------------------------------------------------------------------


def test_canonical_small_arrays_round_trip_big_arrays_opaque():
    small = P.canonical_value(jnp.asarray([1, 2, 3], jnp.int32))
    assert small[0] == "array" and P.contains_opaque(small) is False
    big = P.canonical_value(jnp.zeros((100_000,), jnp.float32))
    assert P.contains_opaque(big)


def test_canonical_params_are_hashable_cache_keys():
    canon = P.canonical_params({"cols": ["a", "b"], "k": 3,
                                "aggs": {"n": ("id", "count")}})
    hash(canon)   # must not raise
    assert canon == P.canonical_params({"cols": ("a", "b"), "k": 3,
                                        "aggs": {"n": ("id", "count")}})
