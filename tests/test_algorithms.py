"""Graph algorithms vs brute-force numpy/python oracles (paper Tables 3/6)."""

import collections

import numpy as np
import pytest

from repro.core.graph import Graph
from repro.core import algorithms as A
from conftest import random_digraph


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------


def np_pagerank(edges, n, it=10, d=0.85):
    pr = np.full(n, 1.0 / n)
    outdeg = np.zeros(n)
    for s, _ in edges:
        outdeg[s] += 1
    for _ in range(it):
        new = np.full(n, (1 - d) / n)
        new += d * pr[outdeg == 0].sum() / n
        for s, t in edges:
            new[t] += d * pr[s] / outdeg[s]
        pr = new
    return pr


def canon(lbl):
    first, out = {}, []
    for x in lbl:
        out.append(first.setdefault(x, len(first)))
    return out


def kosaraju(edges, n):
    adj_f, adj_b = collections.defaultdict(list), collections.defaultdict(list)
    for a, b in edges:
        adj_f[a].append(b)
        adj_b[b].append(a)
    visited, order = [False] * n, []
    for u0 in range(n):
        if visited[u0]:
            continue
        stack = [(u0, 0)]
        visited[u0] = True
        while stack:
            v, i = stack.pop()
            if i < len(adj_f[v]):
                stack.append((v, i + 1))
                w = adj_f[v][i]
                if not visited[w]:
                    visited[w] = True
                    stack.append((w, 0))
            else:
                order.append(v)
    comp, c = [-1] * n, 0
    for u in reversed(order):
        if comp[u] != -1:
            continue
        stack = [u]
        comp[u] = c
        while stack:
            v = stack.pop()
            for w in adj_b[v]:
                if comp[w] == -1:
                    comp[w] = c
                    stack.append(w)
        c += 1
    return comp


def dense_edges(g):
    s, d = (np.asarray(x) for x in g.out_edges())
    return list(zip(s.tolist(), d.tolist()))


# ---------------------------------------------------------------------------
# tests (multiple seeds)
# ---------------------------------------------------------------------------

SEEDS = [1, 2, 5]


@pytest.mark.parametrize("seed", SEEDS)
def test_pagerank_matches_oracle(rng, seed):
    s, d = random_digraph(rng, n=50, m=260, seed=seed)
    g = Graph.from_edges(s, d)
    pr = np.asarray(A.pagerank(g, n_iter=10))
    oracle = np_pagerank(dense_edges(g), g.n_nodes)
    np.testing.assert_allclose(pr, oracle, atol=1e-6)
    assert abs(pr.sum() - 1.0) < 1e-4


@pytest.mark.parametrize("seed", SEEDS)
def test_triangles_match_oracle(rng, seed):
    s, d = random_digraph(rng, n=50, m=300, seed=seed)
    u = Graph.from_edges(s, d).to_undirected()
    es, ed = (np.asarray(x) for x in u.out_edges())
    und = set((min(a, b), max(a, b)) for a, b in zip(es.tolist(), ed.tolist()))
    adj = collections.defaultdict(set)
    for a, b in und:
        adj[a].add(b)
        adj[b].add(a)
    oracle = sum(len(adj[a] & adj[b]) for a, b in und) // 3
    assert A.triangle_count(u) == oracle


def test_per_node_triangles_and_clustering(rng):
    s, d = random_digraph(rng, n=40, m=250, seed=9)
    u = Graph.from_edges(s, d).to_undirected()
    es, ed = (np.asarray(x) for x in u.out_edges())
    und = set((min(a, b), max(a, b)) for a, b in zip(es.tolist(), ed.tolist()))
    adj = collections.defaultdict(set)
    for a, b in und:
        adj[a].add(b)
        adj[b].add(a)
    per = np.zeros(u.n_nodes, int)
    for a, b in und:
        for c in adj[a] & adj[b]:
            if b < c:
                per[a] += 1
                per[b] += 1
                per[c] += 1
    got = np.asarray(A.per_node_triangles(u))
    assert np.array_equal(got, per)
    cc = np.asarray(A.clustering_coefficient(u))
    deg = np.asarray(u.out_degrees())
    wedge = deg * (deg - 1) / 2
    expect = np.divide(per, np.maximum(wedge, 1), where=wedge > 0)
    np.testing.assert_allclose(cc[wedge > 0], expect[wedge > 0], atol=1e-6)


@pytest.mark.parametrize("seed", SEEDS)
def test_connected_components(rng, seed):
    s, d = random_digraph(rng, n=60, m=90, seed=seed)  # sparse -> many comps
    g = Graph.from_edges(s, d)
    lab = np.asarray(A.connected_components(g))
    parent = list(range(g.n_nodes))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in dense_edges(g):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    oracle = [find(i) for i in range(g.n_nodes)]
    assert canon(lab.tolist()) == canon(oracle)


@pytest.mark.parametrize("seed", SEEDS)
def test_scc_matches_kosaraju(rng, seed):
    s, d = random_digraph(rng, n=40, m=120, seed=seed)
    g = Graph.from_edges(s, d)
    got = np.asarray(A.strongly_connected_components(g))
    oracle = kosaraju(dense_edges(g), g.n_nodes)
    assert canon(got.tolist()) == canon(oracle)


@pytest.mark.parametrize("seed", SEEDS)
def test_sssp_bellman_ford(rng, seed):
    s, d = random_digraph(rng, n=50, m=200, seed=seed)
    g = Graph.from_edges(s, d)
    dist = np.asarray(A.sssp(g, 0))
    INF = float("inf")
    do = [INF] * g.n_nodes
    do[0] = 0
    for _ in range(g.n_nodes):
        for a, b in dense_edges(g):
            if do[a] + 1 < do[b]:
                do[b] = do[a] + 1
    got = np.where(np.isinf(dist), -1, dist)
    want = [-1 if x == INF else x for x in do]
    np.testing.assert_allclose(got, want)


def test_bfs_levels(rng):
    g = Graph.from_edges([0, 1, 2], [1, 2, 3])
    assert np.asarray(A.bfs(g, 0)).tolist() == [0, 1, 2, 3]


@pytest.mark.parametrize("k", [2, 3])
def test_k_core_peeling(rng, k):
    s, d = random_digraph(rng, n=50, m=300, seed=11)
    g = Graph.from_edges(s, d)
    u = g.to_undirected()
    es, ed = (np.asarray(x) for x in u.out_edges())
    adj = collections.defaultdict(set)
    for a, b in zip(es.tolist(), ed.tolist()):
        adj[a].add(b)
    alive = set(range(u.n_nodes))
    changed = True
    while changed:
        changed = False
        for v in list(alive):
            if len(adj[v] & alive) < k:
                alive.discard(v)
                changed = True
    got = np.asarray(A.k_core(g, k))
    uids = np.asarray(u.node_ids[:u.n_nodes])
    gids = np.asarray(g.node_ids[:g.n_nodes])
    want = np.isin(gids, uids[sorted(alive)]) if alive else \
        np.zeros(g.n_nodes, bool)
    assert np.array_equal(got, want)


def test_core_numbers_monotone(rng):
    s, d = random_digraph(rng, n=40, m=220, seed=13)
    g = Graph.from_edges(s, d)
    core = np.asarray(A.core_numbers(g))
    for k in range(1, int(core.max()) + 1):
        mask = np.asarray(A.k_core(g, k))
        assert np.array_equal(mask, core >= k)


def test_hits_finite_and_normalized(rng):
    s, d = random_digraph(rng, n=40, m=200, seed=17)
    g = Graph.from_edges(s, d)
    hub, auth = A.hits(g, n_iter=15)
    hub, auth = np.asarray(hub), np.asarray(auth)
    assert np.isfinite(hub).all() and np.isfinite(auth).all()
    assert abs(np.linalg.norm(hub) - 1.0) < 1e-4
    assert abs(np.linalg.norm(auth) - 1.0) < 1e-4


def test_degree_histogram(rng):
    g = Graph.from_edges([0, 0, 1], [1, 2, 2])
    hist = np.asarray(A.degree_histogram(g, "out"))
    assert hist.tolist() == [1, 1, 1]  # node2:0, node1:1, node0:2


def test_pagerank_bsr_kernel_path_agrees(rng):
    from repro.kernels import ops
    s, d = random_digraph(rng, n=90, m=400, seed=23)
    g = Graph.from_edges(s, d)
    pr_seg = np.asarray(A.pagerank(g, n_iter=5))
    pr_bsr = np.asarray(ops.pagerank_bsr(g, n_iter=5))
    np.testing.assert_allclose(pr_bsr, pr_seg, atol=1e-5)


def test_triangle_bsr_kernel_path_agrees(rng):
    from repro.kernels import ops
    s, d = random_digraph(rng, n=70, m=350, seed=29)
    u = Graph.from_edges(s, d).to_undirected()
    assert ops.triangle_count_bsr(u) == A.triangle_count(u)


def test_eigenvector_centrality_star():
    # star graph: center receives all edges -> dominant centrality
    g = Graph.from_edges([1, 2, 3, 4], [0, 0, 0, 0])
    x = np.asarray(A.eigenvector_centrality(g, n_iter=30))
    assert x[0] == x.max() and x[0] > 0


def test_degree_centrality():
    g = Graph.from_edges([0, 0, 1], [1, 2, 2])
    c = np.asarray(A.degree_centrality(g, "out"))
    assert c[0] == pytest.approx(1.0)     # deg 2 / (n-1)=2


def test_label_propagation_two_cliques():
    # two disconnected triangles -> two communities
    g = Graph.from_edges([0, 1, 2, 3, 4, 5], [1, 2, 0, 4, 5, 3])
    lab = np.asarray(A.label_propagation(g))
    assert len(set(lab[:3])) == 1 and len(set(lab[3:])) == 1
    assert lab[0] != lab[3]


def test_closeness_centrality_path():
    # path 0-1-2 (undirected edges both ways): middle node is closest
    g = Graph.from_edges([0, 1, 1, 2], [1, 0, 2, 1])
    c = np.asarray(A.closeness_centrality(g, sources=None, n_samples=3))
    assert c[1] == c.max()
